"""CP-attention comm scoreboard: prove the overlap-pipelined ulysses and
head-replicated MQA paths move exactly the bytes the comm model says,
from compiled post-SPMD HLO.

Two claims, each asserted via a declarative gate file (a regression
fails the bench, and CI):

* **overlap-pipelined ulysses** (gate ``cp_overlap``) — with
  ``overlap_chunks = c`` the K/V all-to-alls split into ``c`` per-chunk
  collectives: a2a count goes 4 → 2 + 2c, the smallest a2a payload
  shrinks ÷c, and total wire bytes are unchanged (the merge is
  online-softmax-exact, so comm granularity is the *only* change).
  XLA's collective-combiner passes must not have re-merged the chunks.
* **head-replicated MQA ulysses** (gate ``ulysses_mqa``) — at a shape
  where ``KV % cp != 0`` (H=8, KV=4, cp=8), replicating KV heads
  r = cp/gcd(KV, cp) = 2× and running plain ulysses moves half the wire
  bytes of the all-gather fallback, through all-to-alls only.

The analytic model (``repro.roofline.analysis.cp_attention_comm``) is
additionally calibrated against the measured HLO wire totals of all
four programs (±2%), so roofline projections for real shapes rest on a
model the compiler has countersigned.

Run via ``python benchmarks/run.py --cp-attention`` (subprocess with 8
virtual devices); the JSON lands in ``BENCH_cp_attention.json`` at the
repo root.  Numbers are per-device ring-model bytes (post-SPMD HLO).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo_gates
from repro.dist import context as cpx
from repro.roofline import analysis as ra

B, S, H, KV, D = 2, 64, 8, 4, 16
CHUNKS = 4


def make_qkv(seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    return q, k, v


def cp_hlo(cp: int, mode: str, chunks: int = 1) -> str:
    """Post-SPMD HLO of one cp_attention forward on a (cp,)-device
    ``seq`` mesh.  impl='ref' — the gates assert collective structure,
    which the in-shard kernel tier does not change."""
    mesh = jax.make_mesh((cp,), ("seq",))
    q, k, v = make_qkv()
    f = jax.jit(functools.partial(
        cpx.cp_attention, mesh=mesh, mode=mode, impl="ref",
        overlap_chunks=chunks, block_q=16, block_kv=16))
    with mesh:
        return f.lower(q, k, v).compile().as_text()


def _gate(name: str, programs: dict, symbols=None) -> dict:
    rep, measured = hlo_gates.evaluate_file(
        hlo_gates.GATES_DIR / f"{name}.json", programs, symbols=symbols)
    rep.raise_on_error(AssertionError)
    return measured


def _hlo_wire(text: str) -> float:
    return sum(ra.wire_bytes_by_dtype(text).values())


def _model_wire(mode: str, cp: int, chunks: int = 1) -> float:
    return ra.cp_attention_comm(mode, H=H, KV=KV, D=D, cp=cp, B=B, S=S,
                                itemsize=4, overlap_chunks=chunks
                                )["wire_bytes"]


def _calibrate(label: str, mode: str, cp: int, text: str,
               chunks: int = 1) -> dict:
    """Model wire bytes must match the compiled program's within 2%."""
    model = _model_wire(mode, cp, chunks)
    hlo = _hlo_wire(text)
    assert abs(hlo / model - 1.0) <= 0.02, (
        f"{label}: comm model predicts {model:g} wire B but the "
        f"compiled HLO moves {hlo:g}")
    return {"model_wire_bytes": model, "hlo_wire_bytes": hlo}


def overlap_claim() -> dict:
    """Chunked K/V a2as: count 2+2c, min payload ÷c, wire constant
    (gate: cp_overlap)."""
    cp = 4
    mono = cp_hlo(cp, "ulysses", 1)
    over = cp_hlo(cp, "ulysses", CHUNKS)
    m = _gate("cp_overlap", {"mono": mono, "overlap": over},
              symbols={"chunks": CHUNKS,
                       "overlap_a2as": 2 + 2 * CHUNKS})
    return {"cp": cp, "chunks": CHUNKS,
            "a2a_count_mono": m["mono_a2a_count"],
            "a2a_count_overlap": m["overlap_a2a_count"],
            "min_payload_ratio": m["min_payload_div_chunks"],
            "wire_ratio": m["wire_upper"],
            "mono": _calibrate("mono", "ulysses", cp, mono),
            "overlap": _calibrate("overlap", "ulysses", cp, over, CHUNKS)}


def mqa_claim() -> dict:
    """Head-replicated ulysses halves wire vs the all-gather fallback at
    KV % cp != 0 (gate: ulysses_mqa)."""
    cp = 8
    mqa = cp_hlo(cp, "ulysses_mqa")
    ag = cp_hlo(cp, "allgather")
    m = _gate("ulysses_mqa", {"mqa": mqa, "allgather": ag})
    import math
    return {"cp": cp, "kv_replication": cp // math.gcd(KV, cp),
            "wire_ratio_vs_allgather": m["mqa_wire_vs_allgather"],
            "a2a_count": m["mqa_a2a_count"],
            "model_ratio": (_model_wire("ulysses_mqa", cp)
                            / _model_wire("allgather", cp)),
            "mqa": _calibrate("mqa", "ulysses_mqa", cp, mqa),
            "allgather": _calibrate("allgather", "allgather", cp, ag)}


def main() -> None:
    out = {"shape": {"B": B, "S": S, "H": H, "KV": KV, "D": D,
                     "itemsize": 4},
           "overlap": overlap_claim(),
           "mqa": mqa_claim()}
    print(json.dumps(out))


if __name__ == "__main__":
    sys.exit(main())
