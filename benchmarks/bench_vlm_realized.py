"""Realized (executed, not simulated) wavefront-vs-FIFO comparison for
the MLLM compound workload — standalone subprocess: it needs 8 virtual
devices, which the in-process bench harness (1 device) cannot provide.

Runs the disaggregated MLLM runtime end to end twice over the same
batches — FIFO dispatch vs wavefront dispatch — and reports, FROM THE
EXECUTOR'S TIMELINE: per-iteration makespan, realized LLM-section
utilization, the number of ViT microbatches actually dispatched (the
dynamic-activation savings: wavefront clusters image samples so fewer
microbatches carry vision work), and the realized dispatch permutation.

    PYTHONPATH=src python benchmarks/bench_vlm_realized.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json

import numpy as np


def main(iters: int = 4) -> dict:
    import jax

    from repro.configs import get_reduced
    from repro.core.types import ParallelConfig
    from repro.data.synthetic import vlm_batches
    from repro.mllm.workload import MLLMRuntime
    from repro.models.vlm import vit_config

    B, S, K, MBS = 16, 64, 8, 4
    lm_cfg = get_reduced("pixtral-12b").replace(
        dtype="float32", vocab_size=256, vision_dim=64, max_image_tokens=K)
    vit_cfg = vit_config(num_layers=2, d_model=64, num_heads=4, d_ff=128,
                         patch_dim=32, downsample=4, out_dim=64,
                         name="vit-bench").replace(dtype="float32")
    rt = MLLMRuntime(vit_cfg, lm_cfg,
                     vit_parallel=ParallelConfig(dp=4),
                     lm_parallel=ParallelConfig(dp=4),
                     global_batch=B, seq_len=S, mbs=MBS, impl="ref")
    params0, opts0 = rt.init(jax.random.PRNGKey(0))
    data = vlm_batches(batch=B, seq_len=S, vocab=256, vision_ratio=0.5,
                       image_tokens=K, patch_dim=32, seed=0)
    batches = [next(data) for _ in range(iters)]

    out = {}
    example_order = None
    for policy in ("fifo", "wavefront"):
        p, o = params0, opts0
        mks, utils, vit_mbs, reordered = [], [], 0, 0
        for i, b in enumerate(batches):
            p, o, m = rt.train_iteration(p, o, b, i,
                                         reorder=policy == "wavefront")
            ex = m["execution"]
            mks.append(ex.makespan)
            utils.append(ex.utilization("llm"))
            vit_mbs += len(m["plan"].image_mbs)
            if tuple(m["plan"].order) != tuple(range(B)):
                reordered += 1
                if policy == "wavefront" and example_order is None:
                    example_order = list(m["plan"].order)
        out[policy] = {
            "makespan_mean_s": float(np.mean(mks[1:] or mks)),
            "llm_util_mean": float(np.mean(utils)),
            "vit_microbatches": int(vit_mbs),
            "reordered_iters": int(reordered),
        }
    rt.shutdown()
    out["realized_speedup"] = (out["fifo"]["makespan_mean_s"]
                               / max(out["wavefront"]["makespan_mean_s"],
                                     1e-12))
    out["example_wavefront_order"] = example_order
    return out


if __name__ == "__main__":
    print(json.dumps(main()))
