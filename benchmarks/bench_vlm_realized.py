"""Realized (executed, not simulated) benchmarks for the MLLM compound
workload — standalone subprocess: it needs 8 virtual devices, which the
in-process bench harness (1 device) cannot provide.

Two comparisons over the same batches, both FROM THE EXECUTOR'S
TIMELINE:

* FIFO vs wavefront dispatch: per-iteration makespan, realized
  LLM-section utilization, the number of ViT microbatches actually
  dispatched (the dynamic-activation savings: wavefront clusters image
  samples so fewer microbatches carry vision work), and the realized
  dispatch permutation.
* overlap OFF (lookahead=0, the old per-iteration barrier) vs overlap ON
  (lookahead=1, cross-iteration streaming with worker-side updates):
  multi-iteration wall clock, realized overlap seconds (sum of
  per-iteration spans minus wall — positive only if iterations actually
  interleaved), and wall-normalized section utilization.

    PYTHONPATH=src python benchmarks/bench_vlm_realized.py [--smoke]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys
import time

import numpy as np


def main(iters: int = 4, repeats: int = 2) -> dict:
    import jax

    from repro.configs import get_reduced
    from repro.core.types import ParallelConfig
    from repro.data.synthetic import vlm_batches
    from repro.mllm.workload import MLLMRuntime
    from repro.models.vlm import vit_config

    B, S, K, MBS = 16, 64, 8, 4
    lm_cfg = get_reduced("pixtral-12b").replace(
        dtype="float32", vocab_size=256, vision_dim=64, max_image_tokens=K)
    vit_cfg = vit_config(num_layers=2, d_model=64, num_heads=4, d_ff=128,
                         patch_dim=32, downsample=4, out_dim=64,
                         name="vit-bench").replace(dtype="float32")
    from repro.mllm.workload import init_compound_params

    rt = MLLMRuntime(vit_cfg, lm_cfg,
                     vit_parallel=ParallelConfig(dp=4),
                     lm_parallel=ParallelConfig(dp=4),
                     global_batch=B, seq_len=S, mbs=MBS, impl="ref")
    params_host = init_compound_params(vit_cfg, lm_cfg,
                                       jax.random.PRNGKey(0))
    data = vlm_batches(batch=B, seq_len=S, vocab=256, vision_ratio=0.5,
                       image_tokens=K, patch_dim=32, seed=0)
    batches = [next(data) for _ in range(iters)]

    out = {}
    example_order = None
    for policy in ("fifo", "wavefront"):
        # fresh placement per run: AdamW donates its optimizer-state
        # buffers, so a state may only ever enter one trajectory
        p, o = rt.place(params_host)
        mks, utils, vit_mbs, reordered = [], [], 0, 0
        for i, b in enumerate(batches):
            p, o, m = rt.train_iteration(p, o, b, i,
                                         reorder=policy == "wavefront")
            ex = m["execution"]
            mks.append(ex.makespan)
            utils.append(ex.utilization("llm"))
            vit_mbs += len(m["plan"].image_mbs)
            if tuple(m["plan"].order) != tuple(range(B)):
                reordered += 1
                if policy == "wavefront" and example_order is None:
                    example_order = list(m["plan"].order)
        out[policy] = {
            "makespan_mean_s": float(np.mean(mks[1:] or mks)),
            "llm_util_mean": float(np.mean(utils)),
            "vit_microbatches": int(vit_mbs),
            "reordered_iters": int(reordered),
        }
    out["realized_speedup"] = (out["fifo"]["makespan_mean_s"]
                               / max(out["wavefront"]["makespan_mean_s"],
                                     1e-12))
    out["example_wavefront_order"] = example_order

    # ---- overlap on vs off: the same streamed iterations with and
    # without the cross-iteration barrier (jits warm from the loops
    # above; best-of-repeats absorbs 1-core scheduling noise) ----------- #
    def run_overlap(depth: int) -> dict:
        rt.lookahead = depth
        rt.install(*rt.place(params_host))
        t0 = time.perf_counter()
        for i, b in enumerate(batches):
            rt.submit_iteration(b, i, reorder=True)
        ms = rt.drain()
        wall = time.perf_counter() - t0
        exs = [m["execution"] for m in ms]
        span_sum = sum(ex.makespan for ex in exs)
        return {
            "lookahead": depth,
            "wall_s": wall,
            "span_sum_s": span_sum,
            # > 0 only when iteration spans actually interleaved
            "overlap_s": span_sum - wall,
            # busy seconds normalized by the whole run's wall clock —
            # the multi-iteration utilization a barrier depresses
            "vit_util_wall": sum(ex.busy("vit") for ex in exs) / wall,
            "llm_util_wall": sum(ex.busy("llm") for ex in exs) / wall,
        }

    overlap = {}
    for depth in (0, 1):
        runs = [run_overlap(depth) for _ in range(repeats)]
        overlap[f"lookahead{depth}"] = min(runs, key=lambda r: r["wall_s"])
    off, on = overlap["lookahead0"], overlap["lookahead1"]
    overlap["wall_speedup"] = off["wall_s"] / max(on["wall_s"], 1e-12)
    overlap["vit_util_gain"] = (on["vit_util_wall"]
                                - off["vit_util_wall"])
    out["overlap"] = overlap
    rt.shutdown()
    return out


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv[1:]
    print(json.dumps(main(iters=2 if smoke else 4,
                          repeats=1 if smoke else 2)))
