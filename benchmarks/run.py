"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figure mapping:

* bench_scheduler — Fig. 7 + Algorithm 1 (§3.4)
* bench_vlm       — Fig. 8 (VLM training, §4.1)
* bench_distill   — Fig. 9 + Fig. 10 (distillation, §4.2)
* bench_kernels   — kernel layer (substrate)

``--smoke`` runs the cheap CI subset (scheduler only, capped sweep).
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import sys
import traceback
from pathlib import Path

# allow `python benchmarks/run.py` from anywhere: repo root (for the
# `benchmarks` namespace package) and src/ (for `repro`)
_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: scheduler benches only")
    args = ap.parse_args()

    names = ["scheduler"]
    if not args.smoke:
        names += ["vlm", "distill", "kernels"]
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        # import inside the guard: a collection-time failure in one bench
        # module must not take down the others (or the smoke subset)
        try:
            mod = importlib.import_module(f"benchmarks.bench_{name}")
            kw = {}
            if "smoke" in inspect.signature(mod.run).parameters:
                kw["smoke"] = args.smoke
            for row in mod.run(**kw):
                print(",".join(str(x) for x in row), flush=True)
        except Exception:
            failures += 1
            print(f"{name}_FAILED,0,0", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
