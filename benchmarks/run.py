"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figure mapping:

* bench_scheduler — Fig. 7 + Algorithm 1 (§3.4)
* bench_vlm       — Fig. 8 (VLM training, §4.1)
* bench_distill   — Fig. 9 + Fig. 10 (distillation, §4.2)
* bench_kernels   — kernel layer (substrate)

``--smoke`` runs the cheap CI subset (scheduler only, capped sweep).
``--vlm-realized`` runs the executed (multi-device subprocess) MLLM
bench and writes its JSON — wavefront-vs-FIFO plus overlap-on-vs-off —
to ``BENCH_vlm_realized.json`` at the repo root, where it is committed
so the realized-performance trajectory is tracked in-tree.
``--step-roofline`` runs the HLO-derived distributed-step scoreboard
(vocab-parallel CE FLOPs, TP-in-stage FLOPs, compressed DP all-reduce
wire bytes — each asserted via the declarative gate files, see
bench_step_roofline.py) and writes ``BENCH_step_roofline.json`` at the
repo root.
``--cp-attention`` runs the CP-attention comm scoreboard (overlap-
pipelined ulysses a2a chunking + head-replicated MQA wire reduction,
asserted via the ``cp_overlap`` / ``ulysses_mqa`` gate files against
compiled post-SPMD HLO — see bench_cp_attention.py) and writes
``BENCH_cp_attention.json`` at the repo root.
``--kernels`` runs the kernel micro-benchmarks alone and writes their
rows (wall time + derived GFLOP/s) to ``BENCH_kernels.json`` at the
repo root; with ``--smoke`` the shapes shrink and no JSON is written.
``--lint`` runs the static-analysis suite (``python -m repro.analysis``):
deadlock/donation passes over every registered workload spec plus a
schema check of the committed HLO gate files.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import subprocess
import sys
import traceback
from pathlib import Path

# allow `python benchmarks/run.py` from anywhere: repo root (for the
# `benchmarks` namespace package) and src/ (for `repro`)
_ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_ROOT / "src"))
sys.path.insert(0, str(_ROOT))


def vlm_realized(smoke: bool) -> None:
    """Run bench_vlm_realized in its own interpreter (it needs 8 virtual
    devices) and record the JSON at the repo root."""
    env = dict(os.environ, PYTHONPATH=str(_ROOT / "src"))
    cmd = [sys.executable, str(_ROOT / "benchmarks" /
                               "bench_vlm_realized.py")]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=1800)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        sys.exit(proc.returncode)
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    out = _ROOT / "BENCH_vlm_realized.json"
    out.write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {out}", flush=True)
    ov = data["overlap"]
    print(f"wavefront_vs_fifo_speedup,{data['realized_speedup']:.4f}",
          flush=True)
    print(f"overlap_wall_speedup,{ov['wall_speedup']:.4f}", flush=True)
    print(f"overlap_vit_util_gain,{ov['vit_util_gain']:.4f}", flush=True)


def step_roofline() -> None:
    """Run bench_step_roofline in its own interpreter (8 virtual devices)
    and record the scoreboard at the repo root.  The bench asserts the
    perf claims itself; a regression fails this command."""
    env = dict(os.environ, PYTHONPATH=str(_ROOT / "src"))
    cmd = [sys.executable, str(_ROOT / "benchmarks" /
                               "bench_step_roofline.py")]
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=1800)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        sys.exit(proc.returncode)
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    out = _ROOT / "BENCH_step_roofline.json"
    out.write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {out}", flush=True)
    print(f"vp_ce_unembed_flop_reduction,{data['vp_ce']['reduction']:.4f}",
          flush=True)
    print("tp_in_stage_ffn_flop_reduction,"
          f"{data['tp_in_stage']['reduction']:.4f}", flush=True)
    c = data["compress"]
    print(f"grad_wire_bf16_over_fp32,{c['bf16_over_fp32']:.4f}",
          flush=True)
    print(f"grad_wire_int8_over_fp32,{c['int8_over_fp32']:.4f}",
          flush=True)


def cp_attention() -> None:
    """Run bench_cp_attention in its own interpreter (8 virtual devices)
    and record the scoreboard at the repo root.  The bench asserts the
    comm claims via the cp_overlap / ulysses_mqa gate files; a
    regression fails this command."""
    env = dict(os.environ, PYTHONPATH=str(_ROOT / "src"))
    cmd = [sys.executable, str(_ROOT / "benchmarks" /
                               "bench_cp_attention.py")]
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=1800)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr)
        sys.exit(proc.returncode)
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    out = _ROOT / "BENCH_cp_attention.json"
    out.write_text(json.dumps(data, indent=2) + "\n")
    print(f"wrote {out}", flush=True)
    ov, mqa = data["overlap"], data["mqa"]
    print(f"overlap_a2a_count,{ov['a2a_count_overlap']:g}", flush=True)
    print(f"overlap_min_payload_ratio,{ov['min_payload_ratio']:.4f}",
          flush=True)
    print(f"overlap_wire_ratio,{ov['wire_ratio']:.4f}", flush=True)
    print(f"mqa_wire_vs_allgather,{mqa['wire_ratio_vs_allgather']:.4f}",
          flush=True)


def kernels(smoke: bool) -> None:
    """Run the kernel micro-benchmarks alone; record the rows at the
    repo root (full run only — smoke shapes aren't comparable)."""
    from benchmarks import bench_kernels
    rows = bench_kernels.run(smoke=smoke)
    print("name,us_per_call,gflops")
    for row in rows:
        print(",".join(str(x) for x in row), flush=True)
    if smoke:
        return
    out = _ROOT / "BENCH_kernels.json"
    out.write_text(json.dumps(
        {"rows": [{"name": n, "us_per_call": t, "gflops": g}
                  for n, t, g in rows]}, indent=2) + "\n")
    print(f"wrote {out}", flush=True)


def lint() -> None:
    """Run the static-analysis suite in its own interpreter (same entry
    point as ``python -m repro.analysis``)."""
    env = dict(os.environ, PYTHONPATH=str(_ROOT / "src"))
    proc = subprocess.run([sys.executable, "-m", "repro.analysis"],
                          env=env, timeout=900)
    sys.exit(proc.returncode)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: scheduler benches only")
    ap.add_argument("--vlm-realized", action="store_true",
                    help="run the executed MLLM bench (subprocess, 8 "
                         "virtual devices) and write "
                         "BENCH_vlm_realized.json at the repo root")
    ap.add_argument("--step-roofline", action="store_true",
                    help="run the HLO-derived distributed-step scoreboard "
                         "(subprocess, 8 virtual devices) and write "
                         "BENCH_step_roofline.json at the repo root")
    ap.add_argument("--cp-attention", action="store_true",
                    help="run the CP-attention comm scoreboard "
                         "(subprocess, 8 virtual devices; gate-asserted) "
                         "and write BENCH_cp_attention.json at the repo "
                         "root")
    ap.add_argument("--kernels", action="store_true",
                    help="run the kernel micro-benchmarks alone and "
                         "write BENCH_kernels.json at the repo root "
                         "(with --smoke: small shapes, no JSON)")
    ap.add_argument("--lint", action="store_true",
                    help="run the static-analysis suite (deadlock/"
                         "donation passes over registered workload specs "
                         "+ HLO gate schema checks)")
    args = ap.parse_args()

    if args.lint:
        lint()
        return
    if args.vlm_realized:
        vlm_realized(args.smoke)
        return
    if args.step_roofline:
        step_roofline()
        return
    if args.cp_attention:
        cp_attention()
        return
    if args.kernels:
        kernels(args.smoke)
        return

    names = ["scheduler"]
    if not args.smoke:
        names += ["vlm", "distill", "kernels"]
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        # import inside the guard: a collection-time failure in one bench
        # module must not take down the others (or the smoke subset)
        try:
            mod = importlib.import_module(f"benchmarks.bench_{name}")
            kw = {}
            if "smoke" in inspect.signature(mod.run).parameters:
                kw["smoke"] = args.smoke
            for row in mod.run(**kw):
                print(",".join(str(x) for x in row), flush=True)
        except Exception:
            failures += 1
            print(f"{name}_FAILED,0,0", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
