"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figure mapping:

* bench_scheduler — Fig. 7 + Algorithm 1 (§3.4)
* bench_vlm       — Fig. 8 (VLM training, §4.1)
* bench_distill   — Fig. 9 + Fig. 10 (distillation, §4.2)
* bench_kernels   — kernel layer (substrate)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_distill, bench_kernels, bench_scheduler,
                            bench_vlm)
    modules = [("scheduler", bench_scheduler), ("vlm", bench_vlm),
               ("distill", bench_distill), ("kernels", bench_kernels)]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for row in mod.run():
                print(",".join(str(x) for x in row), flush=True)
        except Exception:
            failures += 1
            print(f"{name}_FAILED,0,0", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
