"""Proxy configs + iteration-time models for the paper's evaluation
workloads (§4).

The paper benchmarks Qwen3.5-400B-A17B and Qwen3-Next-80B-A3B (unreleased
weights; public dims incomplete), so we use dimension-faithful *proxies*
matched on the quantities the cost model consumes: total params (memory),
active params (compute/token), and component asymmetry (ViT 0.4B @ 4× seq;
frozen teacher vs trainable student).

Baseline = Megatron-LM-style uniform config: every component runs on the
full cluster with the critical section's parallelism and micro-batch size,
serially within an iteration.  Maestro = two-stage planner output +
wavefront overlap (the makespan is cross-checked with the event simulator,
not assumed).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import cost_model as cmdl
from repro.core.graph import build_distill_graph, build_vlm_graph
from repro.core.planner import Plan, plan, _iter_time
from repro.core.scheduler import schedule_global_batch
from repro.core.simulator import Sample, simulate_fanout
from repro.core.types import ArchConfig, ParallelConfig
from repro.models.vlm import vit_config


def qwen35_400b_a17b_proxy() -> ArchConfig:
    """~430B total / ~16B active MoE (96e top-2)."""
    return ArchConfig(
        name="qwen3.5-400b-a17b-proxy", family="moe", num_layers=60,
        d_model=6144, num_heads=48, num_kv_heads=8, d_ff=4096,
        vocab_size=151936, head_dim=128, num_experts=96,
        experts_per_token=2)


def qwen3next_80b_a3b_proxy() -> ArchConfig:
    """~75B total / ~3.3B active MoE-hybrid (64e top-2, 1:3 attn)."""
    return ArchConfig(
        name="qwen3-next-80b-a3b-proxy", family="hybrid", num_layers=48,
        d_model=2048, num_heads=16, num_kv_heads=8, d_ff=5464,
        vocab_size=151936, head_dim=128, num_experts=64,
        experts_per_token=2, attn_period=4, attn_offset=3,
        moe_period=1, moe_offset=0, ssm_state=128, ssm_headdim=64)


def vit_04b(lm_dim: int) -> ArchConfig:
    """~0.4B ViT encoder, 4:1 token downsampling."""
    return vit_config(num_layers=20, d_model=1280, num_heads=16,
                      d_ff=5120, patch_dim=1176, downsample=4,
                      out_dim=lm_dim, name="vit-0.4b")


@dataclass
class WorkloadResult:
    baseline_iter: float
    maestro_iter: float
    baseline_gpus: int
    maestro_gpus: int
    relative_efficiency: float      # vs text-only critical-section time
    critical_utilization: float
    plan: Plan

    @property
    def speedup(self) -> float:
        return self.baseline_iter / self.maestro_iter

    @property
    def per_gpu_speedup(self) -> float:
        return self.speedup * self.baseline_gpus / self.maestro_gpus


def _uniform_component_time(cfg: ArchConfig, crit_parallel: ParallelConfig,
                            seq_len: int, samples: int, *,
                            trainable: bool) -> float:
    """Component executed with the critical section's (uniform) config on
    the full cluster — the Megatron-LM baseline behaviour."""
    return _iter_time(cfg, crit_parallel, seq_len, samples,
                      trainable=trainable, hw=cmdl.V5E)


def run_vlm_workload(lm: ArchConfig, *, gpus: int = 512,
                     global_batch: int = 512, seq_len: int = 32768,
                     vision_ratio: float = 0.25,
                     image_tokens: int = 6144,
                     baseline_pp_bubble: bool = True) -> WorkloadResult:
    """image_tokens: visual tokens the LM consumes per vision sample; the
    ViT attends over 4× that many raw patches (pre-downsampling) — at 32K
    multimodal contexts this quadratic term is what makes the ViT section
    non-negligible (paper §2.1)."""
    vit = vit_04b(lm.d_model)
    g = build_vlm_graph(vit, lm)
    # the ViT processes 4× the visual tokens the LM consumes
    g.sections["vit"] = g.sections["vit"].replace(
        seq_scale=4 * image_tokens / seq_len)
    p = plan(g, critical_gpus=gpus, seq_len=seq_len,
             global_batch=global_batch,
             activation_rates={"vit": vision_ratio})
    llm_p, vit_p = p.sections["llm"], p.sections["vit"]

    # ---- Megatron-style baseline: uniform config, serial components ----
    vit_seq = 4 * image_tokens
    n_vis_samples = max(int(global_batch * vision_ratio), 1)
    base_vit = _uniform_component_time(
        vit, llm_p.parallel, vit_seq, n_vis_samples, trainable=True)
    baseline_iter = llm_p.t_iter + base_vit
    if baseline_pp_bubble and llm_p.parallel.pp > 1:
        # data-dependent activation creates dynamic pipeline bubbles: each
        # vision microbatch inflates its stage time; every pipeline refill
        # (p−1 of them) pays roughly one average vision-delay (§2.1)
        n_micro = max(global_batch // (llm_p.parallel.dp
                                       * llm_p.parallel.mbs), 1)
        baseline_iter += (llm_p.parallel.pp - 1) * (base_vit / n_micro)

    # ---- Maestro: overlap, cross-checked with the wavefront simulator ----
    dp = llm_p.parallel.dp
    per_rank = global_batch // dp
    t_f_c = llm_p.t_iter / global_batch / 3            # fwd ≈ 1/3
    t_b_c = 2 * t_f_c
    vit_fwd = (vit_p.t_iter / max(int(global_batch * vision_ratio), 1)
               / 3)
    vit_bwd = 2 * vit_fwd
    samples = []
    n_vis = int(global_batch * vision_ratio)
    for i in range(global_batch):
        if i < n_vis:
            samples.append(Sample(i, vit_fwd, t_f_c, 0, 0, t_b_c, vit_bwd))
        else:
            samples.append(Sample(i, 0, t_f_c, 0, 0, t_b_c, 0))
    fanout = vit_p.fanout
    per_rank_scheds, _ = schedule_global_batch(samples[:per_rank * fanout],
                                               fanout)
    sim = simulate_fanout(per_rank_scheds)
    # scale the simulated group makespan back to full-iteration terms
    group_tokens = per_rank * fanout
    sim_iter = sim.makespan * (per_rank / (group_tokens / fanout))
    maestro_iter = max(llm_p.t_iter, vit_p.t_iter, sim_iter)
    text_only = llm_p.t_iter
    return WorkloadResult(
        baseline_iter, maestro_iter, gpus, gpus + vit_p.n_gpus,
        relative_efficiency=text_only / maestro_iter,
        critical_utilization=sim.critical_utilization, plan=p)


def run_distill_workload(teacher: ArchConfig, student: ArchConfig, *,
                         gpus: int = 512, global_batch: int = 512,
                         seq_len: int = 8192,
                         teacher_baseline_mbs: int = 1) -> WorkloadResult:
    """teacher_baseline_mbs: the micro-batch size the uniform baseline
    forces on the teacher (dictated by the *student's* memory constraint —
    the paper's §2.2 pathology; Fig. 9 shows the teacher wants ≥4)."""
    g = build_distill_graph(teacher, student)
    p = plan(g, critical_gpus=gpus, seq_len=seq_len,
             global_batch=global_batch)
    st, te = p.sections["student"], p.sections["teacher"]

    # baseline: teacher forward at the student's uniform config (including
    # the student's memory-constrained micro-batch size) then student step
    base_teacher = _uniform_component_time(
        teacher, st.parallel.replace(mbs=teacher_baseline_mbs), seq_len,
        global_batch, trainable=False)
    baseline_iter = st.t_iter + base_teacher

    maestro_iter = max(st.t_iter, te.t_iter)
    return WorkloadResult(
        baseline_iter, maestro_iter, gpus, gpus + te.n_gpus,
        relative_efficiency=st.t_iter / maestro_iter,
        critical_utilization=1.0 if te.t_iter <= st.t_iter else
        st.t_iter / te.t_iter, plan=p)
