"""Step-roofline scoreboard: prove the distributed step stopped paying
the known FLOP/comm waste, from compiled post-SPMD HLO.

Three claims, each asserted (a regression fails the bench, and CI):

* **vocab-parallel PP cross-entropy** — on the same ``pp`` mesh, the
  per-device unembed-projection dot FLOPs drop by ``pp×`` vs the masked
  full-vocab baseline, and NO full-vocab dot remains.
* **TP inside PP stages** — with ``tp=2`` carved into the stage bodies,
  the per-device FFN dot FLOPs halve (Megatron column/row sharding).
* **compressed DP grad all-reduce** — ring-model collective wire bytes
  of the bf16 / int8 steps are ≤ 0.55× / ≤ 0.35× the fp32 baseline, and
  the compressed payloads ship as 2-byte ``u16`` (bitcast bf16) /
  1-byte ``s8`` on the wire.

The expectations are no longer inline asserts: each claim is a
declarative gate file under ``repro/analysis/gates/`` (``vp_ce`` /
``tp_in_stage`` / ``compress``), evaluated by
``repro.analysis.hlo_gates`` — the scoreboard numbers below come from
the same evaluation that asserts them.

Run via ``python benchmarks/run.py --step-roofline`` (subprocess with 8
virtual devices); the JSON lands in ``BENCH_step_roofline.json`` at the
repo root.  Numbers are per-device (post-SPMD HLO shapes are local).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as ShdP

from repro.analysis import hlo_gates
from repro.configs import get_reduced
from repro.core.types import ParallelConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.dist.pipeline import build_pp_loss
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.models.model import build_model
from repro.optim import adamw
from repro.train import step as step_mod

GB, S, PP, TP = 8, 32, 4, 2
# dims chosen so the vocab shard (256) / full vocab (1024) / d_ff (160)
# collide with no other dot-output width in the program
CFG = get_reduced("qwen1.5-0.5b").replace(
    dtype="float32", num_layers=4, vocab_size=1024, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=160,
    tie_embeddings=False)


def make_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (GB, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (GB, S)),
                              jnp.int32),
        "loss_mask": jnp.ones((GB, S), jnp.float32)}


def pp_grad_hlo(cfg, mesh, *, vocab_parallel):
    loss_fn, _ = build_pp_loss(cfg, mesh, n_micro=2, impl="ref",
                               vocab_parallel=vocab_parallel)
    params = init_params(tf.lm_specs(cfg), jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    with mesh:
        return jax.jit(
            jax.value_and_grad(lambda p: loss_fn(p, batch))
        ).lower(params).compile().as_text()


def _gate(name: str, programs: dict, symbols=None) -> dict:
    """Evaluate one gate file; die on any ERROR finding; return the
    measurements keyed by check id (the scoreboard reads numbers from
    the same evaluation that asserted them)."""
    rep, measured = hlo_gates.evaluate_file(
        hlo_gates.GATES_DIR / f"{name}.json", programs, symbols=symbols)
    rep.raise_on_error(AssertionError)
    return measured


def vp_ce_claim() -> dict:
    """Unembed dot FLOPs no longer scale with pp (gate: vp_ce)."""
    mesh = jax.make_mesh((2, PP), ("data", "pipe"))
    m = _gate("vp_ce",
              {"masked": pp_grad_hlo(CFG, mesh, vocab_parallel=False),
               "vp": pp_grad_hlo(CFG, mesh, vocab_parallel=True)})
    return {"pp": PP, "full_vocab_dot_flops": m["baseline_full_vocab"],
            "vocab_shard_dot_flops": m["shard_present"],
            "reduction": m["reduction"]}


def tp_in_stage_claim() -> dict:
    """TP inside the stage bodies shards the FFN compute (gate:
    tp_in_stage; per-sample normalization for the dp-2 vs dp-1 meshes
    lives in the gate's num_scale/den_scale)."""
    m1 = jax.make_mesh((2, 2, 1), ("data", "pipe", "model"))
    m2 = jax.make_mesh((1, 2, TP), ("data", "pipe", "model"))
    m = _gate("tp_in_stage",
              {"tp1": pp_grad_hlo(CFG, m1, vocab_parallel=True),
               "tp2": pp_grad_hlo(CFG, m2, vocab_parallel=True)})
    return {"tp": TP,
            "ffn_dot_flops_tp1_per_sample": m["tp1_ffn_present"] / (GB // 2),
            "ffn_dot_flops_tp2_per_sample": m["tp2_shard_present"] / GB,
            "reduction": m["reduction"]}


def compressed_step_hlo(method: str) -> str:
    cfg = CFG.replace(num_layers=2)
    model = build_model(cfg, impl="ref")
    par = ParallelConfig(dp=8, mbs=1, zero_opt=False,
                         grad_compress=method)
    shape = ShapeConfig("t", "train", S, GB)
    mesh = shd.section_mesh(jax.devices(), par)
    step, sh = step_mod.build_train_step(model, mesh, par, shape,
                                         opt_cfg=adamw.AdamWConfig())
    params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                            sh["params"])
    opt = jax.device_put(adamw.init(params), sh["opt"])
    batch = make_batch(cfg)
    args = [params, opt, batch, jnp.int32(0)]
    if method != "none":
        args.append(sh["ef_init"](params))
    with mesh:
        return step.lower(*args).compile().as_text()


def grad_reduce_hlo(method: str) -> str:
    """HLO of the DP gradient reduction alone (exact psum vs compressed),
    over the real 2-layer gradient tree, so the wire ratio is not diluted
    by unrelated collectives XLA adds to the full step (it reshards the
    elementwise optimizer math over dp and all-gathers the result)."""
    from repro.optim import compression as gcomp
    cfg = CFG.replace(num_layers=2)
    g = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
        build_model(cfg, impl="ref").param_shapes())
    mesh = jax.make_mesh((8,), ("data",))

    if method == "none":
        def reduce_fn(grads, _ef):
            return jax.tree_util.tree_map(
                lambda x: jax.lax.psum(x, "data") / 8.0, grads)
    else:
        def reduce_fn(grads, ef):
            red, _ = gcomp.ef_compress_tree(
                grads, gcomp.ErrorFeedback(ef), "data", method)
            return red
    run = shd.shard_map(reduce_fn, mesh, (ShdP(), ShdP()), ShdP())
    with mesh:
        return jax.jit(run).lower(g, g).compile().as_text()


def compress_claim() -> dict:
    """Compressed DP grad all-reduce halves / quarters wire bytes
    (gate: compress — the isolated reduction's wire ratios, the
    compressed payload dtypes in the full step, and the f32 all-reduce
    residue are all declared there)."""
    programs = {}
    for meth in ("none", "bf16", "int8"):
        programs[f"red_{meth}"] = grad_reduce_hlo(meth)
        programs[f"step_{meth}"] = compressed_step_hlo(meth)
    m = _gate("compress", programs)
    return {"dp": 8,
            "bf16_over_fp32": m["bf16_over_fp32"],
            "int8_over_fp32": m["int8_over_fp32"],
            "step_u16_wire_bytes": m["bf16_ships_u16"],
            "step_s8_wire_bytes": m["int8_ships_s8"],
            "step_f32_allreduce_ratio": {"bf16": m["bf16_f32_ar_ratio"],
                                         "int8": m["int8_f32_ar_ratio"]}}


def main() -> None:
    out = {"vp_ce": vp_ce_claim(),
           "tp_in_stage": tp_in_stage_claim(),
           "compress": compress_claim()}
    print(json.dumps(out))


if __name__ == "__main__":
    sys.exit(main())
