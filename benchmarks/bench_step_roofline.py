"""Step-roofline scoreboard: prove the distributed step stopped paying
the known FLOP/comm waste, from compiled post-SPMD HLO.

Three claims, each asserted (a regression fails the bench, and CI):

* **vocab-parallel PP cross-entropy** — on the same ``pp`` mesh, the
  per-device unembed-projection dot FLOPs drop by ``pp×`` vs the masked
  full-vocab baseline, and NO full-vocab dot remains.
* **TP inside PP stages** — with ``tp=2`` carved into the stage bodies,
  the per-device FFN dot FLOPs halve (Megatron column/row sharding).
* **compressed DP grad all-reduce** — ring-model collective wire bytes
  of the bf16 / int8 steps are ≤ 0.55× / ≤ 0.35× the fp32 baseline, and
  the compressed payloads ship as 2-byte ``u16`` (bitcast bf16) /
  1-byte ``s8`` on the wire.

Run via ``python benchmarks/run.py --step-roofline`` (subprocess with 8
virtual devices); the JSON lands in ``BENCH_step_roofline.json`` at the
repo root.  Numbers are per-device (post-SPMD HLO shapes are local).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as ShdP

from repro.configs import get_reduced
from repro.core.types import ParallelConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.dist.pipeline import build_pp_loss
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.models.model import build_model
from repro.optim import adamw
from repro.roofline import analysis as ra
from repro.train import step as step_mod

GB, S, PP, TP = 8, 32, 4, 2
# dims chosen so the vocab shard (256) / full vocab (1024) / d_ff (160)
# collide with no other dot-output width in the program
CFG = get_reduced("qwen1.5-0.5b").replace(
    dtype="float32", num_layers=4, vocab_size=1024, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=160,
    tie_embeddings=False)


def make_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (GB, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (GB, S)),
                              jnp.int32),
        "loss_mask": jnp.ones((GB, S), jnp.float32)}


def pp_grad_hlo(cfg, mesh, *, vocab_parallel):
    loss_fn, _ = build_pp_loss(cfg, mesh, n_micro=2, impl="ref",
                               vocab_parallel=vocab_parallel)
    params = init_params(tf.lm_specs(cfg), jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    with mesh:
        return jax.jit(
            jax.value_and_grad(lambda p: loss_fn(p, batch))
        ).lower(params).compile().as_text()


def vp_ce_claim() -> dict:
    """Unembed dot FLOPs no longer scale with pp."""
    mesh = jax.make_mesh((2, PP), ("data", "pipe"))
    vs = CFG.padded_vocab // PP
    masked = pp_grad_hlo(CFG, mesh, vocab_parallel=False)
    vp = pp_grad_hlo(CFG, mesh, vocab_parallel=True)
    full = ra.dot_flops_matching(masked, CFG.padded_vocab)
    shard = ra.dot_flops_matching(vp, vs)
    leftover = ra.dot_flops_matching(vp, CFG.padded_vocab)
    assert full > 0, "baseline lost its full-vocab unembed dots"
    assert leftover == 0, \
        f"vocab-parallel CE still has full-vocab dots ({leftover:.3g})"
    ratio = full / shard
    assert 0.9 * PP <= ratio <= 1.1 * PP, \
        f"unembed FLOPs should drop {PP}x, got {ratio:.2f}x"
    return {"pp": PP, "full_vocab_dot_flops": full,
            "vocab_shard_dot_flops": shard, "reduction": ratio}


def tp_in_stage_claim() -> dict:
    """TP inside the stage bodies shards the FFN compute."""
    cfg = CFG
    m1 = jax.make_mesh((2, 2, 1), ("data", "pipe", "model"))
    m2 = jax.make_mesh((1, 2, TP), ("data", "pipe", "model"))
    t1 = pp_grad_hlo(cfg, m1, vocab_parallel=True)
    t2 = pp_grad_hlo(cfg, m2, vocab_parallel=True)
    ffn1 = ra.dot_flops_matching(t1, cfg.d_ff)
    ffn2 = ra.dot_flops_matching(t2, cfg.d_ff // TP)
    assert ffn1 > 0 and ffn2 > 0, (ffn1, ffn2)
    # meshes carry different dp (2 vs 1): normalize to per-sample FLOPs
    per1, per2 = ffn1 / (GB // 2), ffn2 / GB
    ratio = per1 / per2
    assert 0.9 * TP <= ratio <= 1.1 * TP, \
        f"FFN dot FLOPs should drop {TP}x under tp={TP}, got {ratio:.2f}x"
    leftover = ra.dot_flops_matching(t2, cfg.d_ff)
    assert leftover == 0, "tp=2 stage still computes full-width FFN dots"
    return {"tp": TP, "ffn_dot_flops_tp1_per_sample": per1,
            "ffn_dot_flops_tp2_per_sample": per2, "reduction": ratio}


def compressed_step_hlo(method: str) -> str:
    cfg = CFG.replace(num_layers=2)
    model = build_model(cfg, impl="ref")
    par = ParallelConfig(dp=8, mbs=1, zero_opt=False,
                         grad_compress=method)
    shape = ShapeConfig("t", "train", S, GB)
    mesh = shd.section_mesh(jax.devices(), par)
    step, sh = step_mod.build_train_step(model, mesh, par, shape,
                                         opt_cfg=adamw.AdamWConfig())
    params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                            sh["params"])
    opt = jax.device_put(adamw.init(params), sh["opt"])
    batch = make_batch(cfg)
    args = [params, opt, batch, jnp.int32(0)]
    if method != "none":
        args.append(sh["ef_init"](params))
    with mesh:
        return step.lower(*args).compile().as_text()


def grad_reduce_hlo(method: str) -> str:
    """HLO of the DP gradient reduction alone (exact psum vs compressed),
    over the real 2-layer gradient tree, so the wire ratio is not diluted
    by unrelated collectives XLA adds to the full step (it reshards the
    elementwise optimizer math over dp and all-gathers the result)."""
    from repro.optim import compression as gcomp
    cfg = CFG.replace(num_layers=2)
    g = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
        build_model(cfg, impl="ref").param_shapes())
    mesh = jax.make_mesh((8,), ("data",))

    if method == "none":
        def reduce_fn(grads, _ef):
            return jax.tree_util.tree_map(
                lambda x: jax.lax.psum(x, "data") / 8.0, grads)
    else:
        def reduce_fn(grads, ef):
            red, _ = gcomp.ef_compress_tree(
                grads, gcomp.ErrorFeedback(ef), "data", method)
            return red
    run = shd.shard_map(reduce_fn, mesh, (ShdP(), ShdP()), ShdP())
    with mesh:
        return jax.jit(run).lower(g, g).compile().as_text()


def compress_claim() -> dict:
    """Compressed DP grad all-reduce halves / quarters wire bytes."""
    # the reduction in isolation: ring-wire ratio vs the exact f32 psum
    red = {m: sum(ra.wire_bytes_by_dtype(grad_reduce_hlo(m)).values())
           for m in ("none", "bf16", "int8")}
    r_bf16, r_int8 = red["bf16"] / red["none"], red["int8"] / red["none"]
    assert r_bf16 <= 0.55, f"bf16 wire ratio {r_bf16:.3f} > 0.55"
    assert r_int8 <= 0.35, f"int8 wire ratio {r_int8:.3f} > 0.35"

    # the full train step: compressed payload dtypes actually reach the
    # wire and the fat f32 grad all-reduce is gone
    hlos = {m: compressed_step_hlo(m) for m in ("none", "bf16", "int8")}
    wires = {m: ra.wire_bytes_by_dtype(t) for m, t in hlos.items()}
    ar = {m: sum(op.wire_bytes for op in ra.collective_ops(t)
                 if op.family == "all-reduce" and op.dtype == "f32")
          for m, t in hlos.items()}
    assert ar["none"] > 0, "baseline step lost its f32 grad all-reduce"
    assert wires["bf16"].get("u16", 0) > 0, \
        "bf16 method must ship u16 (bitcast) payloads on the wire"
    assert wires["int8"].get("s8", 0) > 0, \
        "int8 method must ship s8 payloads on the wire"
    for m in ("bf16", "int8"):
        assert ar[m] <= 0.05 * ar["none"], \
            f"{m} step still all-reduces f32 ({ar[m]:.0f} wire bytes)"
    return {"dp": 8,
            "grad_reduce_wire_bytes": red,
            "bf16_over_fp32": r_bf16, "int8_over_fp32": r_int8,
            "step_wire_bytes_by_dtype": wires,
            "step_f32_allreduce_wire_bytes": ar}


def main() -> None:
    out = {"vp_ce": vp_ce_claim(),
           "tp_in_stage": tp_in_stage_claim(),
           "compress": compress_claim()}
    print(json.dumps(out))


if __name__ == "__main__":
    sys.exit(main())
