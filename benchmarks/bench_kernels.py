"""Kernel micro-benchmarks on CPU: jitted wall time of the memory-efficient
implementations vs naive materialization, plus derived FLOP rates.

(Pallas kernels execute in interpret mode on CPU — correctness is tested;
their perf story is the §Roofline/§Perf analysis, not CPU wall time.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.distill_kl import distill_kl_chunked_jnp
from repro.kernels.ssd_scan import ssd_chunked_jnp


def _time(fn, *args, n=5):
    # one warmup call: the old `isinstance(fn(*args), tuple)` probe
    # re-executed fn, dispatching the (possibly expensive) program twice
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n


def run(smoke: bool = False) -> list:
    """smoke=True shrinks every shape ~4× in the expensive dim so CI can
    exercise the whole bench in seconds; those numbers are not
    comparable to the committed full-shape rows."""
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 6)

    # flash attention vs naive
    B, S, H, KV, D = 1, (256 if smoke else 1024), 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, D), jnp.float32)
    flops = 4 * B * H * S * S * D / 2
    f_flash = jax.jit(lambda q, k, v: ref.flash_attention_jnp(
        q, k, v, causal=True, block_q=256, block_kv=256))
    f_naive = jax.jit(lambda q, k, v: ref.mha_reference(q, k, v,
                                                        causal=True))
    t1 = _time(f_flash, q, k, v)
    t2 = _time(f_naive, q, k, v)
    rows.append(("flash_jnp_1k", round(t1 * 1e6, 1),
                 round(flops / t1 / 1e9, 2)))
    rows.append(("naive_attn_1k", round(t2 * 1e6, 1),
                 round(flops / t2 / 1e9, 2)))

    # distill KL chunked vs naive (vocab 32k)
    N, Ds, V = 256, 512, (8192 if smoke else 32768)
    hs = jax.random.normal(ks[0], (N, Ds))
    ws = jax.random.normal(ks[1], (Ds, V)) * 0.05
    ht = jax.random.normal(ks[2], (N, Ds))
    wt = jax.random.normal(ks[3], (Ds, V)) * 0.05
    f_ch = jax.jit(lambda *a: distill_kl_chunked_jnp(*a, temperature=2.0,
                                                     block_v=2048))
    f_nv = jax.jit(lambda *a: ref.distill_kl_reference(*a,
                                                       temperature=2.0))
    t1 = _time(f_ch, hs, ws, ht, wt)
    t2 = _time(f_nv, hs, ws, ht, wt)
    kl_flops = 2 * 2 * N * Ds * V
    rows.append(("distill_kl_chunked_32kvocab", round(t1 * 1e6, 1),
                 round(kl_flops / t1 / 1e9, 2)))
    rows.append(("distill_kl_naive_32kvocab", round(t2 * 1e6, 1),
                 round(kl_flops / t2 / 1e9, 2)))

    # SSD chunked vs sequential scan
    b, s, h, p, n = 1, (512 if smoke else 2048), 8, 64, 64
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, s, n))
    Cm = jax.random.normal(ks[4], (b, s, n))
    Dm = jax.random.normal(ks[5], (h,))
    f_ch = jax.jit(lambda *a: ssd_chunked_jnp(*a, chunk=128))
    f_sq = jax.jit(ref.ssd_reference)
    t1 = _time(f_ch, x, dt, A, Bm, Cm, Dm)
    t2 = _time(f_sq, x, dt, A, Bm, Cm, Dm)
    ssd_flops = b * s * h * p * n * 6
    rows.append(("ssd_chunked_2k", round(t1 * 1e6, 1),
                 round(ssd_flops / t1 / 1e9, 2)))
    rows.append(("ssd_sequential_2k", round(t2 * 1e6, 1),
                 round(ssd_flops / t2 / 1e9, 2)))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
