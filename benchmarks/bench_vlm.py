"""Paper Fig. 8 — VLM training throughput, Maestro vs Megatron-uniform.

Three layers of evidence:

1. **Structural claim** (the paper's strongest): with sectioning + wavefront
   scheduling the ViT contributes ZERO critical-path overhead — relative
   efficiency vs text-only = 100% at every vision mix.  Reproduced exactly.
2. **Headline speedups** (1.4× / 1.20×): these depend on the baseline's
   effective ViT share, which for the stated dims (0.4B ViT vs 400B-A17B
   LLM) is FLOPs-bounded at ≈5% — the paper's production mix is visibly
   vision-heavier (long visual streams).  We therefore sweep the vision
   share and report (a) our prediction at the stated dims, (b) the share at
   which the paper's numbers are recovered.
3. **Realized execution** (``vlm_realized_*`` rows): the disaggregated
   MLLM runtime on the compound executor, wavefront vs FIFO dispatch —
   makespan and section utilization measured from the *executor's
   timeline*, not the simulator (subprocess: needs 8 virtual devices).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

from benchmarks.paper_workloads import (qwen35_400b_a17b_proxy,
                                        qwen3next_80b_a3b_proxy,
                                        run_vlm_workload)


def _realized_rows() -> list:
    """Run the executor-backed workload in a subprocess (8 virtual
    devices) and convert its JSON report into bench rows."""
    script = Path(__file__).with_name("bench_vlm_realized.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(script)],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"bench_vlm_realized failed:\n"
                           f"{proc.stderr[-2000:]}")
    rep = json.loads(proc.stdout.strip().splitlines()[-1])
    rows = []
    for pol in ("fifo", "wavefront"):
        rows.append((f"vlm_realized_{pol}_makespan_s", 0.0,
                     round(rep[pol]["makespan_mean_s"], 5)))
        rows.append((f"vlm_realized_{pol}_llm_util", 0.0,
                     round(rep[pol]["llm_util_mean"], 4)))
        rows.append((f"vlm_realized_{pol}_vit_microbatches", 0.0,
                     rep[pol]["vit_microbatches"]))
    rows.append(("vlm_realized_speedup", 0.0,
                 round(rep["realized_speedup"], 4)))
    rows.append(("vlm_realized_wavefront_reordered_iters", 0.0,
                 rep["wavefront"]["reordered_iters"]))
    ov = rep["overlap"]
    rows.append(("vlm_overlap_wall_speedup", 0.0,
                 round(ov["wall_speedup"], 4)))
    rows.append(("vlm_overlap_vit_util_gain", 0.0,
                 round(ov["vit_util_gain"], 4)))
    return rows


def run() -> list:
    rows = []
    t0 = time.perf_counter()

    # (a) stated-dims prediction
    for name, cfg, gpus in [("400b-a17b", qwen35_400b_a17b_proxy(), 1024),
                            ("80b-a3b", qwen3next_80b_a3b_proxy(), 512)]:
        r = run_vlm_workload(cfg, gpus=gpus, global_batch=512,
                             vision_ratio=0.25, image_tokens=6144)
        rows.append((f"vlm_{name}_speedup_e2e", 0.0, round(r.speedup, 4)))
        rows.append((f"vlm_{name}_speedup_per_gpu", 0.0,
                     round(r.per_gpu_speedup, 4)))
        rows.append((f"vlm_{name}_relative_efficiency", 0.0,
                     round(r.relative_efficiency, 4)))
        rows.append((f"vlm_{name}_extra_gpu_frac", 0.0,
                     round((r.maestro_gpus - r.baseline_gpus)
                           / r.baseline_gpus, 4)))

    # (b) vision-share sweep on the 80B-A3B (paper: 1.20× e2e, 1.067×/GPU)
    for ratio, img in [(0.25, 6144), (0.33, 8192), (0.5, 8192),
                       (0.5, 12288), (0.75, 16384)]:
        r = run_vlm_workload(qwen3next_80b_a3b_proxy(), gpus=512,
                             global_batch=512, vision_ratio=ratio,
                             image_tokens=img)
        share = 1 - 1 / r.speedup
        rows.append((f"vlm_sweep_r{ratio}_img{img}_speedup", 0.0,
                     round(r.speedup, 4)))
        rows.append((f"vlm_sweep_r{ratio}_img{img}_releff", 0.0,
                     round(r.relative_efficiency, 4)))

    # (c) realized executor timeline: wavefront vs FIFO dispatch
    rows += _realized_rows()
    dt = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    return [(n, round(dt, 1), d) for n, _, d in rows]


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
