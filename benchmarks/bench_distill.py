"""Paper Fig. 9 + Fig. 10 — distillation.

Fig. 9 (teacher micro-batch sweep) is REPRODUCED BY MEASUREMENT: a real
forward-only teacher jit on CPU, wall-clocked at mbs ∈ {1, 2, 4, 8}, with
peak memory from ``compiled.memory_analysis()`` — same methodology as the
paper, scaled to this container.  The analytic cost-model curve (calibrated
to the paper's 2.6× at mbs 4) is reported alongside.

Fig. 10 (distillation throughput): two-stage-planned Maestro vs the
Megatron-uniform baseline, with the baseline's teacher mbs forced to the
student's memory constraint; sensitivity over that constraint reported.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.paper_workloads import (qwen35_400b_a17b_proxy,
                                        qwen3next_80b_a3b_proxy,
                                        run_distill_workload)
from repro.configs import get_reduced
from repro.core import cost_model as cmdl
from repro.core.types import ParallelConfig
from repro.models.model import build_model


def _measure_teacher_mbs_sweep():
    """Real measurement: forward-only throughput + compile-time memory of
    a small dense teacher at different micro-batch sizes."""
    # weight-dominated regime (like a 400B teacher on real chips): model
    # weights ≫ per-sample activations, so mbs growth barely moves peak
    # memory — the mechanism behind the paper's "nearly flat" claim
    cfg = get_reduced("granite-3-8b").replace(
        dtype="float32", num_layers=6, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=8192)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 128
    out = []
    for mbs in (1, 2, 4, 8):
        toks = jnp.zeros((mbs, S), jnp.int32)
        fwd = jax.jit(lambda p, t: model.forward(p, {"tokens": t}))
        lowered = fwd.lower(params, toks)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        peak = (mem.temp_size_in_bytes + mem.argument_size_in_bytes
                + mem.output_size_in_bytes)
        compiled(params, toks)[0].block_until_ready()
        t0 = time.perf_counter()
        n = 6
        for _ in range(n):
            r = compiled(params, toks)
        jax.block_until_ready(r)
        dt = (time.perf_counter() - t0) / n
        out.append((mbs, mbs / dt, peak, dt))
    return out


def run() -> list:
    rows = []

    # ---- Fig. 9: measured ----
    sweep = _measure_teacher_mbs_sweep()
    base_thr = sweep[0][1]
    base_mem = sweep[0][2]
    for mbs, thr, peak, dt in sweep:
        rows.append((f"fig9_measured_mbs{mbs}_thr_norm", dt * 1e6,
                     round(thr / base_thr, 3)))
        rows.append((f"fig9_measured_mbs{mbs}_mem_norm", 0.0,
                     round(peak / base_mem, 3)))

    # ---- Fig. 9: cost-model curve (calibrated to the paper's 2.6×) ----
    cfg = qwen35_400b_a17b_proxy()
    t1 = cmdl.microbatch_time(cfg, ParallelConfig(tp=16, pp=4, mbs=1),
                              8192, forward_only=True)
    for mbs in (1, 2, 4, 8):
        tm = cmdl.microbatch_time(cfg, ParallelConfig(tp=16, pp=4,
                                                      mbs=mbs),
                                  8192, forward_only=True)
        rows.append((f"fig9_model_mbs{mbs}_thr_norm", 0.0,
                     round((mbs / tm) / (1 / t1), 3)))

    # ---- Fig. 10: Maestro vs uniform baseline ----
    for bmbs in (1, 2, 4):
        r = run_distill_workload(qwen35_400b_a17b_proxy(),
                                 qwen3next_80b_a3b_proxy(), gpus=1024,
                                 global_batch=512, seq_len=8192,
                                 teacher_baseline_mbs=bmbs)
        rows.append((f"fig10_speedup_e2e_bmbs{bmbs}", 0.0,
                     round(r.speedup, 3)))
        rows.append((f"fig10_speedup_per_gpu_bmbs{bmbs}", 0.0,
                     round(r.per_gpu_speedup, 3)))
    rows.append(("fig10_extra_gpu_frac", 0.0,
                 round((r.maestro_gpus - r.baseline_gpus)
                       / r.baseline_gpus, 3)))
    # v5e-realistic pairing from the assigned pool (the 442B-proxy teacher
    # over-allocates on 16-GiB chips just to fit weights — hardware
    # adaptation note in EXPERIMENTS.md)
    from repro.configs import get_config as _gc
    r2 = run_distill_workload(_gc("mixtral-8x22b"),
                              _gc("moonshot-v1-16b-a3b"), gpus=512,
                              global_batch=512, seq_len=8192,
                              teacher_baseline_mbs=1)
    rows.append(("fig10_assigned_pair_speedup_e2e", 0.0,
                 round(r2.speedup, 3)))
    rows.append(("fig10_assigned_pair_per_gpu", 0.0,
                 round(r2.per_gpu_speedup, 3)))
    rows.append(("fig10_assigned_pair_extra_gpu_frac", 0.0,
                 round((r2.maestro_gpus - r2.baseline_gpus)
                       / r2.baseline_gpus, 3)))
    # self-distillation: teacher overlaps with a fraction of the GPUs
    from repro.configs import get_config
    from repro.core.graph import build_distill_graph
    from repro.core.planner import plan
    g = build_distill_graph(get_config("granite-3-8b"),
                            get_config("granite-3-8b"))
    p = plan(g, critical_gpus=256, seq_len=4096, global_batch=256)
    rows.append(("self_distill_teacher_gpu_frac", 0.0,
                 round(p.sections["teacher"].n_gpus / 256, 3)))
    rows.append(("self_distill_fanout", 0.0,
                 p.sections["teacher"].fanout))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
