"""Paper Fig. 7 / Algorithm 1 — wavefront scheduling.

* Fig. 7 worked example: makespan == text-only bound, critical util 1.0.
* Makespan improvement vs FIFO across vision ratios / ViT weights.
* Scheduling overhead vs N (the paper: O(N²), negligible because it
  overlaps GPU execution) — measured wall time.
"""
from __future__ import annotations

import time

from repro.core.scheduler import schedule_global_batch, wavefront_schedule
from repro.core.simulator import Sample, simulate_fanout


def _mk_samples(n, vision_ratio, vit_f, vit_b, seed=0):
    import random
    rng = random.Random(seed)
    out = []
    for i in range(n):
        if rng.random() < vision_ratio:
            out.append(Sample(i, vit_f, 1.0, 0, 0, 2.0, vit_b))
        else:
            out.append(Sample(i, 0, 1.0, 0, 0, 2.0, 0))
    return out


def run(smoke: bool = False) -> list:
    rows = []

    # Fig. 7 exact example
    vis = lambda i, f, b: Sample(i, f, 1.0, 0, 0, 2.0, b)
    txt = lambda i: Sample(i, 0, 1.0, 0, 0, 2.0, 0)
    samples = [vis(0, 0.1, 0.2), txt(1), txt(2), vis(3, 0.2, 0.4),
               txt(4), txt(5), vis(6, 0.15, 0.3), txt(7), txt(8),
               vis(9, 0.25, 0.5), txt(10), txt(11)]
    scheds, _ = schedule_global_batch(samples, 4)
    res = simulate_fanout(scheds)
    rows.append(("fig7_makespan_vs_textonly_bound", 0.0,
                 round(res.makespan / 9.0, 4)))
    rows.append(("fig7_critical_utilization", 0.0,
                 round(res.critical_utilization, 4)))

    # improvement vs FIFO across regimes
    for ratio, vf, vb in [(0.25, 0.5, 1.0), (0.5, 1.0, 2.0),
                          (0.5, 2.0, 4.0), (0.75, 1.5, 3.0)]:
        s = _mk_samples(16, ratio, vf, vb)
        sch = wavefront_schedule(s)
        rows.append((f"alg1_r{ratio}_vit{vf}_makespan_vs_fifo",
                     sch.elapsed_s * 1e6,
                     round(sch.makespan / max(sch.fifo_makespan, 1e-9),
                           4)))
        rows.append((f"alg1_r{ratio}_vit{vf}_crit_util", 0.0,
                     round(sch.sim.critical_utilization, 4)))

    # overhead scaling (per-rank sample counts the paper cites: tens to
    # low hundreds; n=128/256 stress the pruned-insertion fast path)
    for n in (8, 16, 32, 64) if smoke else (8, 16, 32, 64, 128, 256):
        s = _mk_samples(n, 0.3, 0.5, 1.0)
        t0 = time.perf_counter()
        wavefront_schedule(s)
        dt = time.perf_counter() - t0
        rows.append((f"alg1_overhead_n{n}", round(dt * 1e6, 1),
                     round(dt, 5)))

    # fast path vs seed O(N^4) reference (identical schedules by
    # construction; see tests/test_scheduler_fast.py)
    from repro.core.scheduler import wavefront_schedule_reference
    s = _mk_samples(64, 0.3, 0.5, 1.0)
    t0 = time.perf_counter()
    mk_fast = wavefront_schedule(s).makespan
    t_fast = time.perf_counter() - t0
    t0 = time.perf_counter()
    mk_ref = wavefront_schedule_reference(s).makespan
    t_ref = time.perf_counter() - t0
    assert mk_fast == mk_ref, (mk_fast, mk_ref)
    rows.append(("alg1_n64_speedup_vs_reference", round(t_fast * 1e6, 1),
                 round(t_ref / max(t_fast, 1e-9), 1)))
    return rows


if __name__ == "__main__":
    for row in run():
        print(",".join(str(x) for x in row))
