"""Knowledge distillation, the Maestro way (paper §3.1/§4.2).

    PYTHONPATH=src python examples/distillation.py

* the two-stage planner sizes the teacher section (Stage 2: minimal GPUs
  that fully overlap the student);
* teacher and student run DISAGGREGATED on disjoint (virtual) device
  meshes with fan-out (DP^t × fanout = DP^s);
* only *hidden states* cross the section boundary (the teacher's output
  layer is colocated with the student; KL computed by the chunked-vocab
  kernel without materializing teacher logits).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core.graph import build_distill_graph
from repro.core.planner import plan
from repro.core.types import ParallelConfig
from repro.data.synthetic import lm_batches
from repro.distill.workload import DistillRuntime


def main():
    # ---- plan the real thing (paper-scale, analytic) --------------------
    g = build_distill_graph(get_config("mixtral-8x22b"),
                            get_config("moonshot-v1-16b-a3b"))
    p = plan(g, critical_gpus=512, seq_len=8192, global_batch=512)
    print("== two-stage plan (mixtral-8x22b -> moonshot, 512 chips) ==")
    print(p.summary())
    print()

    # ---- run a reduced version for real on 8 virtual devices ------------
    t_cfg = get_reduced("qwen2.5-32b").replace(dtype="float32",
                                               vocab_size=2048)
    s_cfg = get_reduced("qwen1.5-0.5b").replace(dtype="float32",
                                                vocab_size=2048)
    rt = DistillRuntime(t_cfg, s_cfg,
                        teacher_parallel=ParallelConfig(dp=2, tp=2),
                        student_parallel=ParallelConfig(dp=4, tp=1),
                        impl="ref", alpha=0.5, temperature=2.0, lr=2e-3)
    print(f"== disaggregated runtime: teacher mesh (2x2), student mesh "
          f"(4x1), fanout={rt.fanout} ==")
    params_t, params_s, opt = rt.init(jax.random.PRNGKey(0))
    w_t = rt.teacher_unembed(params_t)
    data = lm_batches(batch=8, seq_len=32, vocab=2048, seed=0)
    kls, ces = [], []
    for i in range(30):
        params_s, opt, m = rt.train_iteration(params_t, params_s, opt,
                                              next(data), i, w_t=w_t)
        kls.append(float(m["kl"]))
        ces.append(float(m["ce"]))
        if i % 10 == 0:
            print(f"iter {i:3d}: ce={ces[-1]:.4f} kl={kls[-1]:.4f}")
    print(f"ce {ces[0]:.3f} -> {ces[-1]:.3f}; kl {kls[0]:.4f} -> "
          f"{kls[-1]:.4f}")
    print("cross-section traffic:", rt.rt.queue.stats())
    assert ces[-1] < ces[0], "student did not learn"
    rt.shutdown()
    print("distillation example OK")


if __name__ == "__main__":
    main()
