"""Multi-teacher distillation, declared — the walkthrough workload for
the section-centric API (docs/workloads.md).

    PYTHONPATH=src python examples/multi_teacher_distillation.py

A generalist teacher sees every sample; a specialist teacher activates
only on samples whose ``domain`` flag routes to it.  The whole workload
is ONE declaration (``repro.distill.multi_teacher.multi_teacher_spec``,
~60 lines: three SectionSpecs + two typed ports) run by the generic
``repro.core.workload.CompoundRuntime`` — no bespoke runtime class.  The
wavefront scheduler groups specialist samples into fewer microbatches,
and all-generalist microbatches never touch the specialist's mesh."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core import workload as wl
from repro.core.types import ParallelConfig
from repro.data.synthetic import routed_lm_batches
from repro.distill.multi_teacher import multi_teacher_spec, teacher_unembed


def main():
    B, S, MBS = 16, 32, 4
    ta_cfg = get_reduced("qwen2.5-32b").replace(dtype="float32",
                                                vocab_size=1024)
    tb_cfg = get_reduced("granite-3-8b").replace(
        dtype="float32", vocab_size=1024, d_model=64, head_dim=16,
        d_ff=128)
    s_cfg = get_reduced("qwen1.5-0.5b").replace(dtype="float32",
                                                vocab_size=1024)
    spec = multi_teacher_spec(
        ta_cfg, tb_cfg, s_cfg,
        ta_parallel=ParallelConfig(dp=2),
        tb_parallel=ParallelConfig(dp=2),
        s_parallel=ParallelConfig(dp=4),
        global_batch=B, seq_len=S, mbs=MBS, impl="ref")
    rt = wl.CompoundRuntime(spec, impl="ref")
    print("== multi-teacher distillation: generalist (dp=2) + routed "
          "specialist (dp=2) -> student (dp=4) ==")
    params, opts = rt.init(jax.random.PRNGKey(0))
    smesh = rt.rt.mesh("student")
    consts = {"student": {
        "w_a": teacher_unembed(params["teacher_a"], ta_cfg, smesh),
        "w_b": teacher_unembed(params["teacher_b"], tb_cfg, smesh)}}
    data = routed_lm_batches(batch=B, seq_len=S, vocab=1024,
                             specialist_ratio=0.3, seed=0)
    ces, kbs = [], []
    for i in range(25):
        params, opts, m = rt.train_iteration(params, opts, next(data), i,
                                             consts=consts)
        ces.append(float(m["ce"]))
        kbs.append(float(m["kl_b"]))
        if i % 8 == 0:
            n_spec = len(m["plan"].activation["teacher_b"].active_mbs)
            print(f"iter {i:3d}: ce={ces[-1]:.4f} kl_a={float(m['kl_a']):.4f} "
                  f"kl_b={kbs[-1]:.4f} specialist-mbs={n_spec}/{rt.n_mb} "
                  f"student-util={m['execution'].utilization('student'):.3f}")
    print(f"ce {ces[0]:.3f} -> {ces[-1]:.3f}")
    print("cross-section traffic:", rt.rt.queue.stats())
    assert ces[-1] < ces[0], "student did not learn"
    rt.shutdown()
    print("multi_teacher_distillation example OK")


if __name__ == "__main__":
    main()
