"""Compound VLM training (paper §2.1/§4.1): ViT section + LLM section on
mixed text/vision batches with wavefront scheduling.

    PYTHONPATH=src python examples/vlm_training.py

* builds the section graph (ViT → LLM) and shows the planner's per-section
  configs for the paper-scale workload;
* trains a reduced compound model (real ViT encoder + LM with image-slot
  injection) end-to-end — both sections learn jointly;
* runs the wavefront scheduler on each global batch and reports the
  critical section's simulated utilization (Fig. 7 semantics).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core import cost_model as cmdl
from repro.core.graph import build_vlm_graph
from repro.core.planner import plan
from repro.core.scheduler import schedule_global_batch
from repro.core.simulator import Sample, simulate_fanout
from repro.data.synthetic import vlm_batches
from repro.models import common as cm
from repro.models import transformer as tf
from repro.models.model import build_model
from repro.models.vlm import vit_config, vit_encode, vit_specs
from repro.optim import adamw


def main():
    # ---- paper-scale plan (analytic) ------------------------------------
    g = build_vlm_graph(vit_config(out_dim=5120), get_config("qwen2.5-32b"))
    p = plan(g, critical_gpus=256, seq_len=8192, global_batch=256,
             activation_rates={"vit": 0.3})
    print("== two-stage plan (ViT + qwen2.5-32b, 256 chips) ==")
    print(p.summary())
    print()

    # ---- reduced compound model, trained for real ------------------------
    lm_cfg = get_reduced("pixtral-12b").replace(dtype="float32",
                                                vocab_size=1024,
                                                vision_dim=64,
                                                max_image_tokens=8)
    vit_cfg = vit_config(num_layers=2, d_model=64, num_heads=4, d_ff=128,
                         patch_dim=16, downsample=4, out_dim=64,
                         name="vit-tiny").replace(dtype="float32")
    lm = build_model(lm_cfg)
    v_specs = vit_specs(vit_cfg)
    params = {"vit": cm.init_params(v_specs, jax.random.PRNGKey(1)),
              "lm": lm.init(jax.random.PRNGKey(2))}
    opt = adamw.init(params)

    def loss_fn(params, batch):
        img_embeds = vit_encode(params["vit"], vit_cfg, batch["patches"])
        lm_batch = dict(batch)
        lm_batch["image_embeds"] = img_embeds
        return lm.loss(params["lm"], lm_batch)

    @jax.jit
    def step(params, opt, batch):
        (loss, met), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        params, opt, gnorm = adamw.update(grads, opt, jnp.float32(2e-3))
        return params, opt, loss

    data = vlm_batches(batch=8, seq_len=48, vocab=1024, vision_ratio=0.5,
                       image_tokens=8, patch_dim=16, seed=0)

    # scheduler 6-tuples from the cost model (relative units)
    losses, utils = [], []
    for i in range(25):
        batch = next(data)
        has = np.asarray(batch["has_image"]).astype(bool)
        samples = [Sample(j, 0.4 if has[j] else 0.0, 1.0, 0, 0, 2.0,
                          0.8 if has[j] else 0.0) for j in range(8)]
        scheds, merged = schedule_global_batch(samples, 2)
        sim = simulate_fanout(scheds)
        utils.append(sim.critical_utilization)
        order = np.asarray([s.idx for r in scheds for s in r])
        batch = {k: v[order] for k, v in batch.items()}   # wavefront order
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
        if i % 8 == 0:
            print(f"iter {i:3d}: loss={losses[-1]:.4f} "
                  f"critical-util={utils[-1]:.3f}")
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"mean critical utilization {np.mean(utils):.3f}")
    assert losses[-1] < losses[0], "compound model did not learn"
    print("vlm_training example OK")


if __name__ == "__main__":
    main()
