"""Compound VLM training (paper §2.1/§4.1), the Maestro way: ViT section
and LLM section DISAGGREGATED on disjoint (virtual) device meshes, driven
by the compound executor with wavefront-scheduled microbatch dispatch.

    PYTHONPATH=src python examples/vlm_training.py

* builds the section graph (ViT → LLM) and shows the planner's per-section
  configs for the paper-scale workload;
* trains a reduced compound model for real through
  ``repro.mllm.workload.MLLMRuntime``: per iteration the cost model builds
  scheduler 6-tuples, Algorithm 1 reorders the samples, and the executor
  dispatches microbatches to the section workers — text-only microbatches
  never touch the ViT section (data-dependent activation);
* streams iterations with ``lookahead=1`` through the
  ``install / submit_iteration / retire`` API: optimizer updates run on
  the section workers, so iteration i+1 queues up behind each section's
  own update instead of a global barrier;
* reports the REALIZED (executed, from the executor timeline — not
  simulated) critical-section utilization and the wavefront-vs-FIFO
  makespan of the final iteration.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core.graph import build_vlm_graph
from repro.core.planner import plan
from repro.core.types import ParallelConfig
from repro.data.synthetic import vlm_batches
from repro.mllm.workload import MLLMRuntime
from repro.models.vlm import vit_config
from repro.optim import schedules


def main():
    # ---- paper-scale plan (analytic) ------------------------------------
    g = build_vlm_graph(vit_config(out_dim=5120), get_config("qwen2.5-32b"))
    p = plan(g, critical_gpus=256, seq_len=8192, global_batch=256,
             activation_rates={"vit": 0.3})
    print("== two-stage plan (ViT + qwen2.5-32b, 256 chips) ==")
    print(p.summary())
    print()

    # ---- reduced compound model, trained disaggregated for real ---------
    B, S, K, MBS = 16, 32, 8, 4
    lm_cfg = get_reduced("pixtral-12b").replace(
        dtype="float32", vocab_size=1024, vision_dim=64,
        max_image_tokens=K)
    vit_cfg = vit_config(num_layers=2, d_model=64, num_heads=4, d_ff=128,
                         patch_dim=16, downsample=4, out_dim=64,
                         name="vit-tiny").replace(dtype="float32")
    rt = MLLMRuntime(vit_cfg, lm_cfg,
                     vit_parallel=ParallelConfig(dp=4),
                     lm_parallel=ParallelConfig(dp=4),
                     global_batch=B, seq_len=S, mbs=MBS, impl="ref",
                     lr_schedule=functools.partial(schedules.constant,
                                                   peak_lr=2e-3),
                     lookahead=1)
    print(f"== disaggregated MLLM runtime: vit mesh (dp=4), llm mesh "
          f"(dp=4), mbs={MBS}, lookahead=1 ==")
    params, opts = rt.init(jax.random.PRNGKey(0))
    data = vlm_batches(batch=B, seq_len=S, vocab=1024, vision_ratio=0.5,
                       image_tokens=K, patch_dim=16, seed=0)

    # stream the training loop: submit_iteration enqueues i+1 while the
    # slowest section still drains i; retire() yields metrics in order
    rt.install(params, opts)
    losses, utils = [], []
    metrics = None
    done = 0

    def account(m):
        nonlocal metrics, done
        metrics = m
        ex = m["execution"]
        losses.append(float(m["loss"]))
        utils.append(ex.utilization("llm"))
        if done % 8 == 0:
            n_img = len(m["plan"].image_mbs)
            print(f"iter {done:3d}: loss={losses[-1]:.4f} "
                  f"realized-llm-util={utils[-1]:.3f} "
                  f"vit-mbs={n_img}/{rt.n_mb} "
                  f"makespan={ex.makespan*1e3:.0f}ms")
        done += 1

    batch = None
    for i in range(25):
        batch = next(data)
        rt.submit_iteration(batch, i)
        while rt.in_flight > 1:
            account(rt.retire())
    for m in rt.drain():
        account(m)
    params, opts = rt.state()

    # wavefront vs FIFO on the last batch, from the executor's timeline
    _, _, m_fifo = rt.train_iteration(params, opts, batch, 99,
                                      reorder=False)
    wf, ff = metrics["execution"].makespan, m_fifo["execution"].makespan
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"mean realized LLM utilization {np.mean(utils):.3f}")
    print(f"realized makespan: wavefront {wf*1e3:.0f}ms vs FIFO "
          f"{ff*1e3:.0f}ms (vit-mbs {len(metrics['plan'].image_mbs)} vs "
          f"{len(m_fifo['plan'].image_mbs)})")
    print("cross-section traffic:", rt.rt.queue.stats())
    assert losses[-1] < losses[0], "compound model did not learn"
    rt.shutdown()
    print("vlm_training example OK")


if __name__ == "__main__":
    main()
