"""Quickstart: train a small LM end-to-end with the framework's public API.

    PYTHONPATH=src python examples/quickstart.py

Covers: config → model → sharded train step → train loop with async
checkpointing + straggler monitoring → resume.
"""
import functools
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.types import ArchConfig, ParallelConfig, ShapeConfig
from repro.data.synthetic import lm_batches
from repro.models.model import build_model
from repro.optim import adamw, schedules
from repro.train import step as step_mod
from repro.train.loop import train


def main():
    cfg = ArchConfig(name="quickstart-lm", family="dense", num_layers=4,
                     d_model=256, num_heads=4, num_kv_heads=2, d_ff=704,
                     vocab_size=2048, head_dim=64, dtype="float32")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("quickstart", "train", 128, 8)
    model = build_model(cfg)
    step, shardings = step_mod.build_train_step(
        model, mesh, ParallelConfig(mbs=4), shape,
        lr_schedule=functools.partial(schedules.constant, peak_lr=3e-3))
    params = model.init(jax.random.PRNGKey(0))
    print(f"params: {sum(x.size for x in jax.tree_util.tree_leaves(params))/1e6:.2f}M")
    opt = adamw.init(params)

    with tempfile.TemporaryDirectory() as ckdir:
        ck = Checkpointer(ckdir, keep_last_n=2)
        with mesh:
            params = jax.device_put(params, shardings["params"])
            opt = jax.device_put(opt, shardings["opt"])
            res = train(step, params=params, opt_state=opt,
                        batches=lm_batches(batch=8, seq_len=128,
                                           vocab=2048, seed=0),
                        num_steps=40, checkpointer=ck,
                        checkpoint_every=20, log_every=10)
        print(f"loss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
              f"({res.steps_run} steps, {res.stragglers} stragglers, "
              f"checkpoints at {ck.all_steps()})")
        assert res.losses[-1] < res.losses[0], "did not learn!"
    print("quickstart OK")


if __name__ == "__main__":
    main()
