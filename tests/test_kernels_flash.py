"""Flash attention: Pallas (interpret) and jnp blockwise vs the naive
oracle, swept over shapes/dtypes/masking modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import (flash_attention,
                                           flash_attention_lse,
                                           merge_flash_partials)

SHAPES = [
    # B, S, T, H, KV, D
    (2, 128, 128, 4, 2, 16),      # GQA
    (1, 256, 256, 8, 8, 32),      # MHA
    (2, 128, 64, 4, 1, 16),       # MQA, cross lengths
    (1, 64, 64, 6, 3, 8),         # odd group
]


def _qkv(shape, dtype):
    B, S, T, H, KV, D = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, T, KV, D), dtype)
    return q, k, v


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 32)])
def test_pallas_fwd_sweep(shape, dtype, causal, window):
    q, k, v = _qkv(shape, dtype)
    o_ref = ref.mha_reference(q, k, v, causal=causal, window=window)
    o_pl = flash_attention(q, k, v, causal=causal, window=window,
                           interpret=True, block_q=64, block_kv=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("shape", SHAPES[:2])
def test_jnp_flash_fwd(shape):
    q, k, v = _qkv(shape, jnp.float32)
    o_ref = ref.mha_reference(q, k, v, causal=True)
    o = ref.flash_attention_jnp(q, k, v, causal=True, block_q=32,
                                block_kv=32)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


def test_pallas_grads_match_oracle():
    q, k, v = _qkv((2, 128, 128, 4, 2, 16), jnp.float32)

    def f_pl(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(
            q, k, v, causal=True, interpret=True, block_q=64,
            block_kv=64)))

    def f_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.mha_reference(q, k, v, causal=True)))

    g1 = jax.grad(f_pl, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-4)


def test_segment_ids_packed_sequences():
    B, S, H, KV, D = 2, 96, 4, 2, 16
    q, k, v = _qkv((B, S, S, H, KV, D), jnp.float32)
    seg = jnp.repeat(jnp.arange(3)[None], B, 0).repeat(S // 3, 1)
    o_ref = ref.mha_reference(q, k, v, causal=True, segment_q=seg,
                              segment_kv=seg)
    o = ref.flash_attention_jnp(q, k, v, causal=True, segment_q=seg,
                                segment_kv=seg, block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


def test_non_divisible_lengths_pad_path():
    """Odd lengths (e.g. whisper's 1500 frames) must pad, not shrink
    blocks."""
    q, k, v = _qkv((2, 150, 150, 4, 2, 16), jnp.float32)
    for causal in (True, False):
        o_ref = ref.mha_reference(q, k, v, causal=causal)
        o = ref.flash_attention_jnp(q, k, v, causal=causal, block_q=64,
                                    block_kv=64)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=2e-5, rtol=2e-5)


def test_q_offset_decode_semantics():
    """q_offset shifts the causal mask (CP shards / decode windows)."""
    B, S, H, KV, D = 1, 64, 2, 2, 8
    q, k, v = _qkv((B, 32, S, H, KV, D), jnp.float32)
    o_ref = ref.mha_reference(q, k, v, causal=True, q_offset=32)
    o = ref.flash_attention_jnp(q, k, v, causal=True, q_offset=32,
                                block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 24)])
@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2)])   # MHA / GQA
@pytest.mark.parametrize("q_offset", [0, 64])
def test_merge_matches_monolithic_contiguous(causal, window, H, KV,
                                             q_offset):
    """Splitting KV into contiguous chunks, flashing each with its
    positions, and merging via merge_flash_partials must equal the
    monolithic call (the overlap-pipelined CP invariant)."""
    B, S, T, D, C = 1, 64, 128, 16, 4
    q, k, v = _qkv((B, S, T, H, KV, D), jnp.float32)
    o_mono = flash_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, interpret=True,
                             block_q=16, block_kv=16)
    cl = T // C
    parts_o, parts_lse = [], []
    for j in range(C):
        pos = jnp.arange(j * cl, (j + 1) * cl, dtype=jnp.int32)
        oj, lj = flash_attention_lse(
            q, k[:, j * cl:(j + 1) * cl], v[:, j * cl:(j + 1) * cl],
            causal=causal, window=window, q_offset=q_offset,
            kv_positions=pos, interpret=True, block_q=16, block_kv=16)
        parts_o.append(oj)
        parts_lse.append(lj)
    o, _ = merge_flash_partials(parts_o, parts_lse)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_mono),
                               atol=2e-5, rtol=2e-5)


def test_merge_matches_monolithic_strided():
    """Strided chunk positions (the a2a-interleaved layout of the CP
    overlap path: chunk j holds positions d·shard + j·cl + [0, cl) for
    every device d) must also reproduce the monolithic result, forward
    and backward."""
    B, T, H, KV, D = 1, 128, 4, 2, 16
    cp, chunks = 4, 2
    shard, cl = T // cp, T // cp // chunks
    q, k, v = _qkv((B, T, T, H, KV, D), jnp.float32)

    def chunked(q, k, v):
        parts_o, parts_lse = [], []
        for j in range(chunks):
            pos = (np.arange(cp)[:, None] * shard + j * cl
                   + np.arange(cl)[None, :]).reshape(-1)
            sel = jnp.asarray(pos, jnp.int32)
            oj, lj = flash_attention_lse(
                q, jnp.take(k, sel, axis=1), jnp.take(v, sel, axis=1),
                causal=True, kv_positions=sel, interpret=True,
                block_q=16, block_kv=16)
            parts_o.append(oj)
            parts_lse.append(lj)
        return merge_flash_partials(parts_o, parts_lse)[0]

    def mono(q, k, v):
        return flash_attention(q, k, v, causal=True, interpret=True,
                               block_q=16, block_kv=16)

    np.testing.assert_allclose(np.asarray(chunked(q, k, v)),
                               np.asarray(mono(q, k, v)),
                               atol=2e-5, rtol=2e-5)
    loss = lambda fn: lambda *a: jnp.sum(jnp.sin(fn(*a)))
    g1 = jax.grad(loss(chunked), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss(mono), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_merge_all_masked_chunk_is_inert():
    """A chunk lying entirely in the causal future carries lse ≈ −1e30:
    the merge must weight it to zero (no NaN/garbage leakage) and its
    gradient contribution must be exactly zero."""
    B, S, H, KV, D = 1, 32, 2, 2, 8
    q, k, v = _qkv((B, S, 64, H, KV, D), jnp.float32)
    k_past, v_past = k[:, :32], v[:, :32]
    k_fut, v_fut = k[:, 32:], v[:, 32:]
    pos_past = jnp.arange(32, dtype=jnp.int32)
    pos_fut = jnp.arange(32, 64, dtype=jnp.int32)   # all > max q pos

    def merged(kf, vf):
        o1, l1 = flash_attention_lse(q, k_past, v_past, causal=True,
                                     kv_positions=pos_past,
                                     interpret=True, block_q=16,
                                     block_kv=16)
        o2, l2 = flash_attention_lse(q, kf, vf, causal=True,
                                     kv_positions=pos_fut,
                                     interpret=True, block_q=16,
                                     block_kv=16)
        return merge_flash_partials([o1, o2], [l1, l2])[0]

    o = merged(k_fut, v_fut)
    o_ref = ref.mha_reference(q, k_past, v_past, causal=True)
    assert bool(jnp.all(jnp.isfinite(o)))
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    gk, gv = jax.grad(lambda kf, vf: jnp.sum(merged(kf, vf) ** 2),
                      argnums=(0, 1))(k_fut, v_fut)
    np.testing.assert_array_equal(np.asarray(gk), 0.0)
    np.testing.assert_array_equal(np.asarray(gv), 0.0)


def test_hypothesis_like_random_sweep():
    rng = np.random.default_rng(42)
    for _ in range(6):
        H = int(rng.choice([2, 4, 8]))
        KV = int(rng.choice([g for g in [1, 2, 4, 8] if H % g == 0]))
        D = int(rng.choice([8, 16, 32]))
        S = int(rng.choice([32, 64, 96]))
        q, k, v = _qkv((1, S, S, H, KV, D), jnp.float32)
        o_ref = ref.mha_reference(q, k, v, causal=True)
        o = flash_attention(q, k, v, causal=True, interpret=True,
                            block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=5e-5, rtol=5e-5)
