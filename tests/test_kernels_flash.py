"""Flash attention: Pallas (interpret) and jnp blockwise vs the naive
oracle, swept over shapes/dtypes/masking modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention

SHAPES = [
    # B, S, T, H, KV, D
    (2, 128, 128, 4, 2, 16),      # GQA
    (1, 256, 256, 8, 8, 32),      # MHA
    (2, 128, 64, 4, 1, 16),       # MQA, cross lengths
    (1, 64, 64, 6, 3, 8),         # odd group
]


def _qkv(shape, dtype):
    B, S, T, H, KV, D = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, T, KV, D), dtype)
    return q, k, v


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 32)])
def test_pallas_fwd_sweep(shape, dtype, causal, window):
    q, k, v = _qkv(shape, dtype)
    o_ref = ref.mha_reference(q, k, v, causal=causal, window=window)
    o_pl = flash_attention(q, k, v, causal=causal, window=window,
                           interpret=True, block_q=64, block_kv=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o_pl, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("shape", SHAPES[:2])
def test_jnp_flash_fwd(shape):
    q, k, v = _qkv(shape, jnp.float32)
    o_ref = ref.mha_reference(q, k, v, causal=True)
    o = ref.flash_attention_jnp(q, k, v, causal=True, block_q=32,
                                block_kv=32)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


def test_pallas_grads_match_oracle():
    q, k, v = _qkv((2, 128, 128, 4, 2, 16), jnp.float32)

    def f_pl(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(
            q, k, v, causal=True, interpret=True, block_q=64,
            block_kv=64)))

    def f_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.mha_reference(q, k, v, causal=True)))

    g1 = jax.grad(f_pl, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-4)


def test_segment_ids_packed_sequences():
    B, S, H, KV, D = 2, 96, 4, 2, 16
    q, k, v = _qkv((B, S, S, H, KV, D), jnp.float32)
    seg = jnp.repeat(jnp.arange(3)[None], B, 0).repeat(S // 3, 1)
    o_ref = ref.mha_reference(q, k, v, causal=True, segment_q=seg,
                              segment_kv=seg)
    o = ref.flash_attention_jnp(q, k, v, causal=True, segment_q=seg,
                                segment_kv=seg, block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


def test_non_divisible_lengths_pad_path():
    """Odd lengths (e.g. whisper's 1500 frames) must pad, not shrink
    blocks."""
    q, k, v = _qkv((2, 150, 150, 4, 2, 16), jnp.float32)
    for causal in (True, False):
        o_ref = ref.mha_reference(q, k, v, causal=causal)
        o = ref.flash_attention_jnp(q, k, v, causal=causal, block_q=64,
                                    block_kv=64)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=2e-5, rtol=2e-5)


def test_q_offset_decode_semantics():
    """q_offset shifts the causal mask (CP shards / decode windows)."""
    B, S, H, KV, D = 1, 64, 2, 2, 8
    q, k, v = _qkv((B, 32, S, H, KV, D), jnp.float32)
    o_ref = ref.mha_reference(q, k, v, causal=True, q_offset=32)
    o = ref.flash_attention_jnp(q, k, v, causal=True, q_offset=32,
                                block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=2e-5,
                               rtol=2e-5)


def test_hypothesis_like_random_sweep():
    rng = np.random.default_rng(42)
    for _ in range(6):
        H = int(rng.choice([2, 4, 8]))
        KV = int(rng.choice([g for g in [1, 2, 4, 8] if H % g == 0]))
        D = int(rng.choice([8, 16, 32]))
        S = int(rng.choice([32, 64, 96]))
        q, k, v = _qkv((1, S, S, H, KV, D), jnp.float32)
        o_ref = ref.mha_reference(q, k, v, causal=True)
        o = flash_attention(q, k, v, causal=True, interpret=True,
                            block_q=32, block_kv=32)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   atol=5e-5, rtol=5e-5)
