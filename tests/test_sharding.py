"""Sharding rules: logical-axis mapping, divisibility fallbacks, ZeRO
extension, cache specs — checked against AbstractMesh (no devices)."""
import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist import sharding as shd
from repro.models.common import ParamSpec

# shd.abstract_mesh: AbstractMesh's constructor signature differs across
# jax releases; the helper normalizes it.
MESH = shd.abstract_mesh((16, 16), ("data", "model"))
MESH3 = shd.abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_dense_qkv_specs():
    cfg = get_config("granite-20b")       # 48 heads, kv=1 (MQA)
    wq = ParamSpec((6144, 48, 128), ("embed", "heads", "head_dim"))
    assert shd.spec_for(wq, MESH) == P("data", "model", None)
    wk = ParamSpec((6144, 1, 128), ("embed", "kv_heads", "head_dim"))
    # kv=1 cannot shard -> replicated over model (no contraction psum)
    assert shd.spec_for(wk, MESH) == P("data", None, None)


def test_embed_vocab_spec():
    emb = ParamSpec((49152, 6144), ("vocab", "embed"))
    assert shd.spec_for(emb, MESH) == P("model", "data")
    odd = ParamSpec((49155, 6144), ("vocab", "embed"))
    assert shd.spec_for(odd, MESH) == P(None, "data")   # 49155 % 16 != 0


def test_moe_expert_sharding_modes():
    # moonshot: 64 experts -> EP over model
    w = ParamSpec((64, 2048, 1408), ("experts", "embed", "mlp"))
    assert shd.spec_for(w, MESH) == P("model", "data", None)
    # mixtral: 8 experts -> per-expert mlp TP instead
    w8 = ParamSpec((8, 6144, 16384), ("experts", "embed", "mlp"))
    assert shd.spec_for(w8, MESH) == P(None, "data", "model")


def test_no_mesh_axis_used_twice():
    w = ParamSpec((64, 64), ("vocab", "heads"))      # both want "model"
    spec = shd.spec_for(w, MESH)
    flat = [a for e in spec if e is not None
            for a in (e if isinstance(e, tuple) else (e,))]
    assert len(flat) == len(set(flat))


def test_teacher_rules_drop_fsdp():
    cfg = get_config("granite-3-8b")
    rules = shd.rules_for(cfg, MESH, teacher=True)
    assert "embed" not in rules


def test_zero_extension():
    w = ParamSpec((4096, 32, 128), ("embed", "heads", "head_dim"))
    base = shd.spec_for(w, MESH3)                    # data, model used
    z = shd.zero_extend(w, base, MESH3)
    flat = [a for e in z if e is not None
            for a in (e if isinstance(e, tuple) else (e,))]
    assert "pod" in flat                             # opt state over pods


def test_head_pad_for():
    assert shd.head_pad_for(get_config("qwen2.5-32b"), 16) == 8   # 40->48
    assert shd.head_pad_for(get_config("granite-20b"), 16) == 0  # 48 ok
    assert shd.head_pad_for(get_config("whisper-small"), 16) == 36
    assert shd.head_pad_for(get_config("mamba2-130m"), 16) == 0


def test_batch_spec_fallbacks():
    assert shd.batch_spec(MESH, 256, 4096) == P(("data",), None)
    assert shd.batch_spec(MESH3, 256, 4096) == P(("pod", "data"), None)
    # B=1 long decode: shard seq instead
    assert shd.batch_spec(MESH3, 1, 524288) == P(None, ("pod", "data"))


def test_kv_cache_spec_fallbacks():
    # kv divisible -> heads sharded
    assert shd.kv_cache_spec(MESH, (128, 32768, 16, 128), "attn") == \
        P(("data",), None, "model", None)
    # kv=1 -> shard the sequence (flash-decoding split)
    assert shd.kv_cache_spec(MESH, (128, 32768, 1, 128), "attn") == \
        P(("data",), "model", None, None)


def test_param_shardings_tree():
    from repro.models import transformer as tf
    cfg = get_config("granite-3-8b")
    specs = tf.lm_specs(cfg)
    tree = shd.param_shardings(specs, MESH)
    leaves = jax.tree_util.tree_leaves(tree)
    assert all(hasattr(l, "spec") for l in leaves)
