"""Fast wavefront-schedule path: identical to the seed O(N⁴) reference on
the paper-like acceptance fixtures (tie-stable critical arithmetic — see
the equivalence contract in core/scheduler.py) and ≥5× faster at n=64.
The per-candidate evaluator itself must match the simulator on *all*
inputs, including adversarial general 6-tuples."""
import math
import random
import time

from repro.core.scheduler import (_greedy_makespan, wavefront_schedule,
                                  wavefront_schedule_reference)
from repro.core.simulator import Sample, simulate


def _mk_samples(n, ratio, vf, vb, seed=0):
    """Paper-like mix (matches benchmarks/bench_scheduler.py)."""
    rng = random.Random(seed)
    out = []
    for i in range(n):
        if rng.random() < ratio:
            out.append(Sample(i, vf * (0.5 + rng.random()), 1.0, 0, 0,
                              2.0, vb * (0.5 + rng.random())))
        else:
            out.append(Sample(i, 0, 1.0, 0, 0, 2.0, 0))
    return out


def test_greedy_makespan_matches_simulator_on_general_tuples():
    """All six phases (incl. nonzero ac) on random inputs with zeros."""
    for seed in range(120):
        rng = random.Random(1000 + seed)
        n = rng.randint(1, 14)
        samples = [Sample(i, *[rng.choice([0.0, round(rng.uniform(0, 3), 3)])
                               for _ in range(6)]) for i in range(n)]
        got = _greedy_makespan([s.tuple6 for s in samples])
        want = simulate(samples).makespan
        assert got == want, (seed, got, want)


def test_schedule_bit_identical_to_reference():
    for n, seed in [(1, 0), (8, 1), (12, 2), (16, 3), (24, 4)]:
        for ratio in (0.0, 0.3, 0.75):
            s = _mk_samples(n, ratio, 0.5, 1.0, seed)
            fast = wavefront_schedule(s)
            ref = wavefront_schedule_reference(s)
            assert [x.idx for x in fast.order] == \
                   [x.idx for x in ref.order], (n, seed, ratio)
            assert fast.makespan == ref.makespan
            assert fast.fifo_makespan == ref.fifo_makespan


def test_speedup_vs_reference_n64():
    """Acceptance: ≥5× on n=64 with identical makespans (fixed seed)."""
    s = _mk_samples(64, 0.3, 0.5, 1.0, seed=64)
    # best-of-3 for the fast path: a GC pause or noisy neighbor during a
    # ~50ms run must not fail the build (measured ~60× on this container)
    t_fast = math.inf
    for _ in range(3):
        t0 = time.perf_counter()
        fast = wavefront_schedule(s)
        t_fast = min(t_fast, time.perf_counter() - t0)
    t0 = time.perf_counter()
    ref = wavefront_schedule_reference(s)
    t_ref = time.perf_counter() - t0
    assert fast.makespan == ref.makespan
    assert [x.idx for x in fast.order] == [x.idx for x in ref.order]
    assert t_ref >= 5.0 * t_fast, (t_ref, t_fast)


def test_adversarial_float_ties_keep_divergence_contract():
    """Regression for the early-abort tie semantics, on inputs BUILT to
    accumulate float drift (0.1/0.2-style durations whose partial sums
    are not exactly representable, plus exact duplicates so insertion
    positions tie constantly).

    The equivalence contract (core/scheduler.py module docstring) does
    NOT promise bit-identical orders here — an ulp of accumulation drift
    may flip a tie and the two algorithms may commit different, equally
    scoring insertions.  What it does promise, and what this test pins
    down for both the fast path and the reference oracle:

    * the result is a permutation of the input (a valid schedule);
    * the reported makespan is exact: re-simulating the committed order
      reproduces it bit-for-bit (evaluator exactness on every input);
    * both report the identical fifo_makespan (same FIFO baseline);
    * neither is ever worse than FIFO (the never-worse guard).
    """
    for seed in range(8):
        rng = random.Random(7000 + seed)
        soup = [0.1, 0.2, 0.3, 0.7, 0.1 + 0.2, 1.0 - 0.7]
        samples = []
        for i in range(rng.randint(6, 14)):
            base = [rng.choice(soup) for _ in range(6)]
            samples.append(Sample(i, *base))
            if rng.random() < 0.5:          # exact-duplicate tie fodder
                samples.append(Sample(len(samples), *base))
        for i, s in enumerate(samples):
            samples[i] = Sample(i, *s.tuple6)
        fast = wavefront_schedule(samples)
        ref = wavefront_schedule_reference(samples)
        for tag, res in (("fast", fast), ("ref", ref)):
            assert sorted(x.idx for x in res.order) == \
                list(range(len(samples))), (seed, tag)
            assert res.makespan == simulate(res.order).makespan, \
                (seed, tag)
            assert res.makespan <= res.fifo_makespan, (seed, tag)
        assert fast.fifo_makespan == ref.fifo_makespan, seed


def test_early_abort_never_changes_empty_and_single():
    assert wavefront_schedule([]).makespan == 0.0
    one = [Sample(0, 1.0, 2.0, 0.5, 0.25, 3.0, 0.75)]
    assert wavefront_schedule(one).makespan == \
        wavefront_schedule_reference(one).makespan
