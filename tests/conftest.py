import jax
import jax.numpy as jnp
import numpy as np
import pytest

# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device.  Multi-device behaviour is exercised through
# subprocess drivers (tests/drivers/) which set XLA_FLAGS before importing
# jax.


def toy_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_frames, cfg.frontend_dim)),
            jnp.bfloat16)
    if cfg.vision_dim:
        K = min(cfg.max_image_tokens or 8, S)
        b["image_embeds"] = jnp.asarray(
            rng.normal(size=(B, K, cfg.vision_dim)), jnp.bfloat16)
        pos = np.stack([rng.choice(S, K, replace=False) for _ in range(B)])
        b["image_pos"] = jnp.asarray(pos, jnp.int32)
        b["image_valid"] = jnp.asarray(rng.integers(0, 2, (B, K)),
                                       jnp.int32)
    return b


@pytest.fixture
def rng_key():
    return jax.random.PRNGKey(0)
