"""Section-graph construction rules (§3.1): mutually-exclusive encoder
colocation, flag propagation, and the one-critical-section invariant."""

from repro.configs import get_reduced
from repro.core.graph import SectionGraph, build_distill_graph, \
    maybe_colocate_exclusive
from repro.core.types import ParallelConfig, SectionConfig


def _sec(name, cfg, critical=False, seq_scale=1.0):
    return SectionConfig(name, cfg, ParallelConfig(), trainable=True,
                         critical=critical, seq_scale=seq_scale)


def test_colocate_merges_exclusive_encoders():
    cfg = get_reduced("granite-3-8b")
    g = SectionGraph()
    g.add(_sec("audio", cfg, seq_scale=2.0))
    g.add(_sec("vision", cfg))
    g.add(_sec("llm", cfg, critical=True))
    g.connect("audio", "llm")
    g.connect("vision", "llm")
    out = maybe_colocate_exclusive(g, "audio", "vision",
                                   coactivation_rate=0.01)
    assert "audio+vision" in out.sections
    assert out.critical.name == "llm"
    assert out.sections["audio+vision"].seq_scale == 2.0
    # edges rehomed onto the merged section
    assert {e.src for e in out.producers_of("llm")} == {"audio+vision"}


def test_colocate_propagates_critical_flag():
    """Merging a critical section must keep the exactly-one-critical
    invariant (regression: the merged section used to drop the flag)."""
    cfg = get_reduced("granite-3-8b")
    g = SectionGraph()
    g.add(_sec("enc", cfg))
    g.add(_sec("llm", cfg, critical=True))
    g.connect("enc", "llm")
    out = maybe_colocate_exclusive(g, "enc", "llm", coactivation_rate=0.0)
    assert out.critical.name == "enc+llm"
    out.validate()


def test_colocate_rejected_on_high_coactivation():
    cfg = get_reduced("granite-3-8b")
    g = SectionGraph()
    g.add(_sec("a", cfg))
    g.add(_sec("b", cfg, critical=True))
    out = maybe_colocate_exclusive(g, "a", "b", coactivation_rate=0.5)
    assert out is g


def test_colocate_rejected_on_size_mismatch():
    big = get_reduced("granite-3-8b")
    small = big.replace(num_layers=2, d_model=32, d_ff=64, num_heads=2,
                        num_kv_heads=1, head_dim=16, vocab_size=64)
    g = SectionGraph()
    g.add(_sec("a", big))
    g.add(_sec("b", small, critical=True))
    out = maybe_colocate_exclusive(g, "a", "b", coactivation_rate=0.0)
    assert out is g


def test_distill_graph_shape():
    t = get_reduced("qwen2.5-32b")
    s = get_reduced("qwen1.5-0.5b")
    g = build_distill_graph(t, s, fanout=2)
    assert g.critical.name == "student"
    (edge,) = g.producers_of("student")
    assert edge.hidden_handoff and edge.fanout == 2
