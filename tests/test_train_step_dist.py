"""PP/CP dispatch in the step builders.

The numerical 8-way equivalence (pp=2 / cp=2 / pp×tp vs the monolithic
reference, exact MoE PP aux) runs in the subprocess driver
``tests/drivers/driver_train_step_dist.py`` — the main test process must
keep seeing exactly 1 device.  This file covers the guard rails: a
``ParallelConfig`` with cp/pp > 1 can no longer fall through to the
replicated step unannounced, and the microbatch split no longer silently
duplicates data.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

import repro.configs as cfgs
from repro.core.types import ParallelConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.models import attention as att
from repro.models.model import build_model
from repro.train import step as step_mod


def _tiny_model():
    cfg = cfgs.get_reduced("qwen1.5-0.5b").replace(
        dtype="float32", num_layers=2, vocab_size=64, d_ff=128)
    return build_model(cfg, impl="ref")


def test_pp_config_on_flat_mesh_raises():
    """The headline bug: pp>1 on a mesh without a pipe axis used to train
    silently replicated."""
    model = _tiny_model()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="pp"):
        step_mod.build_train_step(model, mesh, ParallelConfig(pp=2),
                                  ShapeConfig("t", "train", 16, 4))


def test_cp_config_on_flat_mesh_raises():
    model = _tiny_model()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="cp"):
        step_mod.build_train_step(model, mesh, ParallelConfig(cp=2),
                                  ShapeConfig("t", "train", 16, 4))


def test_mesh_pp_axis_without_config_raises():
    """The reverse mismatch: a carved pipe mesh with a pp=1 config."""
    mesh = shd.abstract_mesh((1, 2, 1, 1),
                             ("data", "pipe", "seq", "model"))
    with pytest.raises(ValueError, match="pipe"):
        step_mod.parallel_regime(mesh, ParallelConfig())


def test_pp_cp_composition_rejected():
    mesh = shd.abstract_mesh((1, 2, 2, 1),
                             ("data", "pipe", "seq", "model"))
    with pytest.raises(NotImplementedError, match="pp×cp"):
        step_mod.parallel_regime(mesh, ParallelConfig(pp=2, cp=2))


def test_parallel_regime_dispatch():
    axes = ("data", "pipe", "seq", "model")
    assert step_mod.parallel_regime(
        shd.abstract_mesh((2, 1, 1, 2), axes), ParallelConfig(dp=2, tp=2)
    ) == "plain"
    assert step_mod.parallel_regime(
        shd.abstract_mesh((1, 2, 1, 2), axes), ParallelConfig(pp=2, tp=2)
    ) == "pp"
    assert step_mod.parallel_regime(
        shd.abstract_mesh((2, 1, 2, 1), axes), ParallelConfig(dp=2, cp=2)
    ) == "cp"


def test_cp_on_attention_free_arch_raises():
    cfg = cfgs.get_reduced("mamba2-130m").replace(dtype="float32")
    model = build_model(cfg, impl="ref")
    mesh = shd.abstract_mesh((1, 1, 2, 1),
                             ("data", "pipe", "seq", "model"))
    with pytest.raises(NotImplementedError, match="attention-free"):
        step_mod.build_train_step(model, mesh, ParallelConfig(cp=2),
                                  ShapeConfig("t", "train", 16, 4))


def test_pp_rejects_sequence_parallel():
    model = _tiny_model()
    mesh = shd.abstract_mesh((1, 2, 1, 1),
                             ("data", "pipe", "seq", "model"))
    with pytest.raises(NotImplementedError, match="sequence_parallel"):
        step_mod.build_train_step(
            model, mesh, ParallelConfig(pp=2, sequence_parallel=True),
            ShapeConfig("t", "train", 16, 4))


def test_distill_cp_on_attention_free_arch_raises():
    from repro.distill.workload import build_colocated_step
    t_cfg = _tiny_model().cfg
    s_cfg = cfgs.get_reduced("mamba2-130m").replace(dtype="float32")
    mesh = shd.abstract_mesh((1, 1, 2, 1),
                             ("data", "pipe", "seq", "model"))
    with pytest.raises(NotImplementedError, match="attention-free"):
        build_colocated_step(t_cfg, s_cfg, mesh,
                             ShapeConfig("t", "train", 16, 4),
                             ParallelConfig(cp=2))


def test_serving_builders_reject_pp_mesh():
    model = _tiny_model()
    mesh = shd.abstract_mesh((1, 2, 1, 1),
                             ("data", "pipe", "seq", "model"))
    shape = ShapeConfig("t", "decode", 32, 4)
    with pytest.raises(NotImplementedError, match="serving|decode"):
        step_mod.build_decode_step(model, mesh, shape)
    with pytest.raises(NotImplementedError, match="serving|prefill"):
        step_mod.build_prefill_step(model, mesh,
                                    ShapeConfig("t", "prefill", 32, 4))


# ---- microbatch split: no silent duplication ------------------------------ #
def test_split_microbatches_rejects_remainder():
    batch = {"tokens": jnp.zeros((10, 4), jnp.int32)}
    with pytest.raises(ValueError, match="microbatch"):
        step_mod._split_microbatches(batch, 4, 1)


def test_split_microbatches_rejects_undersized_shards():
    batch = {"tokens": jnp.zeros((4, 4), jnp.int32)}
    with pytest.raises(ValueError, match="microbatch"):
        step_mod._split_microbatches(batch, 4, 2)


def test_num_microbatches_validates_at_build_time():
    mesh = shd.abstract_mesh((2, 1), ("data", "model"))
    with pytest.raises(ValueError, match="global_batch"):
        step_mod.num_microbatches(ShapeConfig("t", "train", 16, 10), mesh,
                                  ParallelConfig(mbs=2))
    # oversized-but-indivisible also raises, even at n_micro == 1
    with pytest.raises(ValueError, match="global_batch"):
        step_mod.num_microbatches(ShapeConfig("t", "train", 16, 6), mesh,
                                  ParallelConfig(mbs=2))
    # undersized global batches (n_micro == 1) stay legal: the batch is
    # replicated / seq-sharded, not microbatched
    assert step_mod.num_microbatches(
        ShapeConfig("t", "train", 16, 1), mesh, ParallelConfig(mbs=1)) == 1
    assert step_mod.num_microbatches(
        ShapeConfig("t", "train", 16, 8), mesh, ParallelConfig(mbs=2)) == 2


# ---- CP attention knobs / kernel dispatch --------------------------------- #
def test_parallel_regime_validates_cp_knobs():
    axes = ("data", "pipe", "seq", "model")
    mesh = shd.abstract_mesh((2, 1, 2, 1), axes)
    with pytest.raises(ValueError, match="cp_mode"):
        step_mod.parallel_regime(mesh, ParallelConfig(
            dp=2, cp=2, cp_mode="ring"))
    with pytest.raises(ValueError, match="cp_impl"):
        step_mod.parallel_regime(mesh, ParallelConfig(
            dp=2, cp=2, cp_impl="triton"))
    with pytest.raises(ValueError, match="cp_overlap_chunks"):
        step_mod.parallel_regime(mesh, ParallelConfig(
            dp=2, cp=2, cp_overlap_chunks=0))
    # chunking only exists on the ulysses a2a chain
    with pytest.raises(ValueError, match="cp_overlap_chunks"):
        step_mod.parallel_regime(mesh, ParallelConfig(
            dp=2, cp=2, cp_mode="allgather", cp_overlap_chunks=2))
    assert step_mod.parallel_regime(mesh, ParallelConfig(
        dp=2, cp=2, cp_mode="ulysses", cp_impl="pallas_interpret",
        cp_overlap_chunks=2)) == "cp"


def test_cp_attention_impl_errors_name_section():
    """CompoundRuntime installs cp_attention_impl with section=<name>;
    unsupported-feature errors must carry it (the impl raises before
    touching the mesh, so no devices are needed here)."""
    from repro.dist.context import cp_attention_impl, resolve_cp_mode
    impl = cp_attention_impl(None, section="vit_tower")
    q = jnp.zeros((1, 8, 4, 8))
    seg = jnp.zeros((1, 8), jnp.int32)
    with pytest.raises(NotImplementedError, match="vit_tower"):
        impl(q, q[:, :, :2], q[:, :, :2], segment_q=seg, segment_kv=seg)
    with pytest.raises(NotImplementedError, match="vit_tower"):
        impl(q, q[:, :4, :2], q[:, :4, :2])   # S_q != S_kv
    with pytest.raises(ValueError, match="vit_tower"):
        resolve_cp_mode("ulysses", H=8, KV=3, cp=4, section="vit_tower")


def test_resolve_cp_mode_auto():
    from repro.dist.context import resolve_cp_mode
    assert resolve_cp_mode("auto", H=8, KV=4, cp=4) == "ulysses"
    # KV % cp != 0 but replication is cheap: head-replicated ulysses
    assert resolve_cp_mode("auto", H=8, KV=4, cp=8) == "ulysses_mqa"
    # pure MQA: replication never beats gathering one KV head
    assert resolve_cp_mode("auto", H=8, KV=1, cp=8) == "allgather"


def test_kernel_impl_env_override(monkeypatch):
    from repro.kernels import ops as kops
    monkeypatch.delenv("REPRO_KERNEL_IMPL", raising=False)
    assert kops._resolve("ref") == "ref"
    assert kops._resolve("auto") in ("ref", "pallas")
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas_interpret")
    assert kops._resolve("ref") == "pallas_interpret"
    assert kops._resolve("auto") == "pallas_interpret"
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "cuda")
    with pytest.raises(ValueError, match="REPRO_KERNEL_IMPL"):
        kops._resolve("auto")


# ---- attention impl plumbing --------------------------------------------- #
def test_attention_impl_override_is_consulted():
    """models.attention routes full-sequence attention through the
    installed impl — the hook CP rides on."""
    import numpy as np
    cfg = _tiny_model().cfg
    from repro.models.attention import attn_specs
    from repro.models.common import init_params
    p = init_params(attn_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    calls = []

    def fake_impl(q, k, v, **kw):
        calls.append((q.shape, k.shape, kw["causal"]))
        return jnp.zeros_like(q)

    with att.attention_impl(fake_impl):
        out = att.attention(p, x, cfg, impl="ref")
    assert calls and calls[0][2] is True
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    # and it uninstalls on exit
    out2 = att.attention(p, x, cfg, impl="ref")
    assert float(jnp.max(jnp.abs(out2))) > 0


# ---- 8-way numerical equivalence (subprocess driver) ---------------------- #
def test_pp_cp_train_step_equivalence_8way():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    driver = Path(__file__).parent / "drivers" / "driver_train_step_dist.py"
    proc = subprocess.run([sys.executable, str(driver)],
                          capture_output=True, text=True, timeout=560,
                          env=env)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-3000:])
    assert "DRIVER_OK train_step_dist" in proc.stdout, proc.stdout[-2000:]
