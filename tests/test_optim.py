"""Optimizer + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:     # tier-1 must collect without hypothesis installed
    HAVE_HYPOTHESIS = False

from repro.optim import adamw
from repro.optim.compression import _dequant_int8, _quant_int8, ef_init, wire_bytes


def test_adamw_converges_on_quadratic():
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(weight_decay=0.0, clip_norm=0.0)
    for _ in range(300):
        grads = {"w": params["w"] - target}
        params, state, _ = adamw.update(grads, state, jnp.float32(0.05),
                                        cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, state2, gnorm = adamw.update(huge, state, jnp.float32(1e-3),
                                    adamw.AdamWConfig(clip_norm=1.0))
    assert float(gnorm) > 1.0
    # first moment reflects the clipped gradient
    assert float(jnp.max(jnp.abs(state2.mu["w"]))) < 1.0


def test_master_does_not_alias_params():
    params = {"w": jnp.ones(3, jnp.float32)}
    state = adamw.init(params)
    assert state.master["w"] is not params["w"]


def test_bf16_params_updated_from_fp32_master():
    params = {"w": jnp.ones(8, jnp.bfloat16)}
    state = adamw.init(params)
    grads = {"w": jnp.full(8, 1e-4, jnp.bfloat16)}
    p2, state2, _ = adamw.update(grads, state, jnp.float32(1e-3))
    assert p2["w"].dtype == jnp.bfloat16
    assert state2.master["w"].dtype == jnp.float32
    # master moved even though bf16 cast may round
    assert float(jnp.max(jnp.abs(state2.master["w"] - 1.0))) > 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                    min_size=1, max_size=64))
    def test_int8_quantization_error_bound(vals):
        x = jnp.asarray(vals, jnp.float32)
        q, scale = _quant_int8(x)
        err = jnp.max(jnp.abs(_dequant_int8(q, scale) - x))
        assert float(err) <= float(scale) * 0.5 + 1e-6
else:
    def test_int8_quantization_error_bound():
        pytest.importorskip("hypothesis")


def test_error_feedback_accumulates_residual():
    from repro.optim.compression import ef_compress_tree
    # single device: psum over a trivial axis via shard_map on 1 device
    mesh = jax.make_mesh((1,), ("d",))
    g = {"w": jnp.asarray([0.001, -0.002, 0.003], jnp.float32)}
    ef = ef_init(g)

    def run(g, ef):
        return ef_compress_tree(g, ef, "d", method="int8")

    from repro.dist.sharding import shard_map  # version-portable wrapper
    sm = shard_map(run, mesh, (jax.sharding.PartitionSpec(),) * 2,
                   (jax.sharding.PartitionSpec(),) * 2)
    total = jnp.zeros(3)
    for _ in range(20):
        red, ef = sm(g, ef)
        total = total + red["w"]
    # mean of compressed reductions converges to the true gradient
    np.testing.assert_allclose(np.asarray(total / 20),
                               np.asarray(g["w"]), rtol=0.05, atol=1e-5)


def test_wire_bytes():
    g = {"a": jnp.zeros((10, 10)), "b": jnp.zeros(50)}
    assert wire_bytes(g, "none") == 150 * 4
    assert wire_bytes(g, "bf16") == 150 * 2
    assert wire_bytes(g, "int8") == 150


def test_state_specs_structure():
    params = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
    sp = adamw.state_specs(params)
    assert sp.mu["w"].dtype == jnp.float32
    assert sp.master["w"].shape == (4, 4)


def test_gnorm_with_clipping_disabled_raises():
    """adamw.update(gnorm=) with clip_norm=0 used to silently ignore the
    precomputed joint norm; it must raise instead (the disaggregated
    runtimes only pass gnorm= when a clip threshold is active)."""
    params = {"w": jnp.ones((4,), jnp.float32)}
    state = adamw.init(params)
    grads = {"w": jnp.full((4,), 0.5, jnp.float32)}
    cfg = adamw.AdamWConfig(clip_norm=0.0)
    with pytest.raises(ValueError, match="clipping is disabled"):
        adamw.update(grads, state, jnp.float32(1e-3), cfg,
                     gnorm=jnp.float32(1.0))
    # without gnorm= the unclipped path still works
    new_p, _, gn = adamw.update(grads, state, jnp.float32(1e-3), cfg)
    assert np.isfinite(float(gn))
    # and with clipping enabled the override is honored
    cfg2 = adamw.AdamWConfig(clip_norm=0.1)
    _, _, gn2 = adamw.update(grads, state, jnp.float32(1e-3), cfg2,
                             gnorm=jnp.float32(42.0))
    assert float(gn2) == 42.0
