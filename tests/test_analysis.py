"""Static-analysis suite: finding/report core, the dispatch-graph
deadlock detector, the donation linter, the mesh-thread affinity checker
and the declarative HLO gate engine — including the negative paths: a
deliberately-cyclic WorkloadSpec and reused donated state must both be
rejected at build time with findings naming the section/edge."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.workload as wl
from repro.analysis import (AnalysisReport, Finding, PASSES, Severity,
                            affinity, check_events, check_spec,
                            hlo_gates, lint_spec, lint_state,
                            lint_step_fn, model_events)
from repro.analysis.deadlock import Event
from repro.configs import get_reduced
from repro.core.types import ParallelConfig
from repro.optim.adamw import DonatedStateError


def _cfg():
    return get_reduced("qwen1.5-0.5b").replace(
        dtype="float32", num_layers=2, vocab_size=64, d_ff=128)


def _producer(name="prod", port=None, mode="fwd_only", consumes=()):
    port = port or wl.Port("h", (wl.SEQ, 16), "float32")
    return wl.SectionSpec(
        name, _cfg(), ParallelConfig(),
        fn=lambda p, x: {"h": x["tokens"]}, params={},
        inputs={"tokens": wl.Field((wl.SEQ,), "int32")},
        emits=(port,), mode=mode, consumes=tuple(consumes))


def _loss(consumes=(), name="crit"):
    return wl.SectionSpec(
        name, _cfg(), ParallelConfig(),
        fn=lambda p, x: 0.0, params={},
        inputs={"tokens": wl.Field((wl.SEQ,), "int32")},
        consumes=tuple(consumes), loss=True, critical=True)


def _spec(*sections):
    return wl.WorkloadSpec("t", tuple(sections), seq_len=8,
                           global_batch=4, mbs=2)


# --------------------------------------------------------------------- #
# core: findings, reports, registry
# --------------------------------------------------------------------- #
def test_report_partitions_and_raises():
    rep = AnalysisReport("p")
    rep.add(Severity.INFO, "x.i", "a", "fine")
    rep.add(Severity.WARNING, "x.w", "b", "meh")
    assert rep.ok and len(rep.warnings) == 1
    rep.add(Severity.ERROR, "x.e", "edge", "broken thing")
    assert not rep.ok and len(rep.errors) == 1
    with pytest.raises(RuntimeError, match=r"x\.e \(edge\): broken"):
        rep.raise_on_error(RuntimeError, "gate failed")
    assert "[ERROR] x.e (edge)" in str(Finding(
        Severity.ERROR, "x.e", "edge", "broken thing"))
    assert "1 error(s), 1 warning(s), 1 info" in rep.summary()
    assert "x.i" not in rep.render(min_severity=Severity.WARNING)


def test_pass_registry_contains_all_passes():
    assert {"deadlock", "donation", "affinity", "hlo"} <= set(PASSES)


# --------------------------------------------------------------------- #
# deadlock pass
# --------------------------------------------------------------------- #
def test_clean_spec_proven_deadlock_free():
    port = wl.Port("h", (wl.SEQ, 16), "float32")
    spec = _spec(_producer(port=port),
                 _loss(consumes=[wl.Consume("prod", port)]))
    for la in (0, 1):
        assert check_spec(spec, n_mb=2, lookahead=la).ok


def test_cyclic_spec_rejected_at_validate_naming_sections():
    """The ISSUE acceptance path: a deliberately-cyclic WorkloadSpec is
    rejected at build time, not by a hang in drain()."""
    pa = wl.Port("h", (wl.SEQ, 16), "float32")
    pb = wl.Port("g", (wl.SEQ, 16), "float32")
    a = _producer("a", port=pa, consumes=[wl.Consume("b", pb)])
    b = _producer("b", port=pb, consumes=[wl.Consume("a", pa)])
    spec = _spec(a, b, _loss(consumes=[wl.Consume("a", pa)]))
    with pytest.raises(ValueError, match="cycle"):
        spec.validate()
    # the analysis pass names the sections and the queue edges on the
    # wait cycle
    rep = check_spec(spec, n_mb=1)
    assert not rep.ok
    cyc = [f for f in rep.errors if f.check == "deadlock.cycle"]
    assert cyc, rep.render()
    assert "a" in cyc[0].subject and "b" in cyc[0].subject
    assert "s0/a.h.0" in cyc[0].message and "s0/b.g.0" in cyc[0].message


def test_unsatisfied_cotangent_pull_reported():
    """A trainable producer feeding a fwd_only consumer waits forever on
    a cotangent nobody pushes — the pass names the edge and the hang."""
    ph = wl.Port("h", (wl.SEQ, 16), "float32")
    pg = wl.Port("g", (wl.SEQ, 16), "float32")
    prod = _producer("prod", port=ph, mode="fwd_bwd")
    mid = _producer("mid", port=pg, mode="fwd_only",
                    consumes=[wl.Consume("prod", ph)])
    spec = _spec(prod, mid, _loss(consumes=[wl.Consume("mid", pg)]))
    rep = check_spec(spec, n_mb=1)
    bad = [f for f in rep.errors if f.check == "deadlock.unsatisfied-pull"]
    assert bad, rep.render()
    assert "hang in drain()" in bad[0].message
    assert "ct.prod.h" in bad[0].message


def test_rendezvous_modeled_and_acyclic():
    """Two trainable sections: the grad-norm rendezvous (push to every
    peer before pulling any) must appear in the model and stay acyclic
    even with lookahead chaining two scopes."""
    ph = wl.Port("h", (wl.SEQ, 16), "float32")
    prod = _producer("prod", port=ph, mode="fwd_bwd")
    spec = _spec(prod, _loss(consumes=[wl.Consume("prod", ph)]))
    chains = model_events(spec, 2, ["s0", "s1"])
    gnorm = [e for evs in chains.values() for e in evs
             if "gnorm" in e.key]
    assert {e.key for e in gnorm} == {"s0/gnorm.prod", "s0/gnorm.crit",
                                      "s1/gnorm.prod", "s1/gnorm.crit"}
    for evs in chains.values():
        ups = [e for e in evs if e.task == "upd"]
        assert [e.kind for e in ups] == ["push", "pull"] * 2
    assert check_spec(spec, n_mb=2, lookahead=1).ok


def test_check_events_reports_synthetic_cycle_and_duplicate_push():
    """The generic wait-graph checker on a hand-built bad event graph:
    two workers each blocking-pull what the other pushes only later."""
    chains = {
        "a": [Event("a", "t0", "pull", "b", "a", "s0/k1"),
              Event("a", "t0", "push", "a", "b", "s0/k2")],
        "b": [Event("b", "t0", "pull", "a", "b", "s0/k2"),
              Event("b", "t0", "push", "b", "a", "s0/k1"),
              Event("b", "t1", "push", "b", "a", "s0/k1")],
    }
    rep = check_events(chains)
    cyc = [f for f in rep.errors if f.check == "deadlock.cycle"]
    assert cyc and cyc[0].subject == "a,b"
    assert "s0/k1" in cyc[0].message and "s0/k2" in cyc[0].message
    dup = [f for f in rep.warnings if f.check == "deadlock.duplicate-push"]
    assert dup and "s0/k1" in dup[0].message


# --------------------------------------------------------------------- #
# donation pass
# --------------------------------------------------------------------- #
def test_donation_reuse_finding_names_tree_and_leaf():
    x = jnp.ones((4,), jnp.float32)
    x.delete()
    rep = lint_state({"s": {"w": x}}, {})
    bad = [f for f in rep.errors if f.check == "donation.reuse"]
    assert bad and bad[0].subject == "params[s]"
    assert "'w'" in bad[0].message


def test_donation_cross_section_alias_finding():
    shared = jnp.ones((4,), jnp.float32)
    rep = lint_state({}, {"a": {"mu": shared}, "b": {"mu": shared}})
    bad = [f for f in rep.errors
           if f.check == "donation.cross-section-alias"]
    assert bad, rep.render()
    assert "opts[a]" in bad[0].message or "opts[a]" in bad[0].subject


def test_donation_params_alias_finding():
    w = jnp.ones((4,), jnp.float32)
    rep = lint_state({"s": {"w": w}}, {"s": {"master": {"w": w}}})
    bad = [f for f in rep.errors if f.check == "donation.params-alias"]
    assert bad and "params[s]" in bad[0].message


def test_donation_clean_state_passes():
    p = {"s": {"w": jnp.ones((4,), jnp.float32)}}
    o = {"s": {"mu": jnp.zeros((4,), jnp.float32)}}
    assert lint_state(p, o).ok


def test_donation_step_fn_metadata():
    def step():
        pass
    step._donates = (0, 1)
    step._donates_label = "train_step(params, opt)"
    rep = lint_step_fn(step)
    assert rep.ok and "argnums (0, 1)" in rep.findings[0].message

    def bare():
        pass
    rep2 = lint_step_fn(bare)
    assert [f.check for f in rep2.warnings] == ["donation.undeclared"]


def test_donation_spec_signature():
    ph = wl.Port("h", (wl.SEQ, 16), "float32")
    prod = _producer("prod", port=ph, mode="fwd_bwd")
    spec = _spec(prod, _loss(consumes=[wl.Consume("prod", ph)]))
    rep = lint_spec(spec)
    by = {f.subject: f.message for f in rep.findings}
    assert "opt state" in by["prod"] and "opt state" in by["crit"]


def test_built_train_steps_declare_donation():
    from repro.core.types import ShapeConfig
    from repro.dist import sharding as shd
    from repro.models.model import build_model
    from repro.train import step as step_mod

    cfg = _cfg()
    model = build_model(cfg, impl="ref")
    par = ParallelConfig(mbs=4)
    mesh = shd.section_mesh(jax.devices()[:1], par)
    step, _ = step_mod.build_train_step(model, mesh, par,
                                        ShapeConfig("t", "train", 8, 4))
    rep = lint_step_fn(step)
    assert rep.ok and "argnums (0, 1)" in rep.findings[0].message


# --------------------------------------------------------------------- #
# affinity pass
# --------------------------------------------------------------------- #
class _FakeMesh:
    def __init__(self, ids):
        self.devices = np.array(ids)


class _FakeThread:
    def __init__(self, alive=True):
        self._alive = alive

    def is_alive(self):
        return self._alive


class _FakeWorker:
    def __init__(self, alive=True):
        self._thread = _FakeThread(alive)


class _FakeRT:
    def __init__(self, meshes, workers):
        self.meshes = meshes
        self.workers = workers


def test_affinity_wiring_clean():
    rt = _FakeRT({"a": _FakeMesh([0, 1]), "b": _FakeMesh([2, 3])},
                 {"a": _FakeWorker(), "b": _FakeWorker()})
    rep = affinity.check_wiring(rt)
    assert rep.ok
    assert [f.check for f in rep.findings] == ["affinity.wiring"]


def test_affinity_mesh_overlap_names_both_sections():
    rt = _FakeRT({"a": _FakeMesh([0, 1]), "b": _FakeMesh([1, 2])},
                 {"a": _FakeWorker(), "b": _FakeWorker()})
    rep = affinity.check_wiring(rt)
    bad = [f for f in rep.errors if f.check == "affinity.mesh-overlap"]
    assert bad and bad[0].subject == "a|b"
    assert "deadlock" in bad[0].message


def test_affinity_missing_and_dead_worker():
    rt = _FakeRT({"a": _FakeMesh([0]), "b": _FakeMesh([1])},
                 {"b": _FakeWorker(alive=False)})
    rep = affinity.check_wiring(rt)
    checks = {f.check for f in rep.errors}
    assert checks == {"affinity.no-worker", "affinity.dead-worker"}


def test_affinity_trace_attribution():
    ok = affinity.check_trace([("s", "section-s", "s"),
                               ("s", "section-s", "s")])
    assert ok.ok and "2 dispatches" in ok.findings[0].message
    bad = affinity.check_trace([("s", "MainThread", None)])
    fails = [f for f in bad.errors if f.check == "affinity.foreign-thread"]
    assert fails and "not a section worker" in fails[0].message
    multi = affinity.check_trace([("s", "section-s", "s"),
                                  ("s", "section-t", "t")])
    assert {f.check for f in multi.errors} == {"affinity.foreign-thread",
                                               "affinity.multiple-threads"}


def test_affinity_record_via_real_section_worker():
    """SectionWorker._run marks its thread; record() inside a task must
    attribute the dispatch to that section's own worker."""
    from repro.core.runtime import SectionWorker

    w = SectionWorker("vit")
    with affinity.tracking() as trace:
        w.submit("t0", lambda: affinity.record("vit"))
        w.drain(1)
        affinity.record("vit")          # main thread: foreign
    w.stop()
    rep = affinity.check_trace(trace)
    fails = [f for f in rep.errors if f.check == "affinity.foreign-thread"]
    assert fails, rep.render()          # the main-thread record
    assert ("vit", "section-vit", "vit") in trace


# --------------------------------------------------------------------- #
# end-to-end: a real CompoundRuntime is wired through all three passes
# --------------------------------------------------------------------- #
def _lm_spec():
    from repro.models.model import build_model
    cfg = _cfg()
    model = build_model(cfg, impl="ref")

    def lm_fn(p, x):
        return model.loss(p, {"tokens": x["tokens"],
                              "labels": x["labels"]})[0]

    sec = wl.SectionSpec(
        "lm", cfg, ParallelConfig(), fn=lm_fn, params=model.specs(),
        inputs={"tokens": wl.Field((wl.SEQ,), "int32"),
                "labels": wl.Field((wl.SEQ,), "int32")},
        loss=True, critical=True)
    return wl.WorkloadSpec("lm-only", (sec,), seq_len=8,
                           global_batch=4, mbs=2)


def test_runtime_install_rejects_donated_state_and_traces_clean():
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, 64, (4, 8)).astype(np.int32),
             "labels": rng.integers(0, 64, (4, 8)).astype(np.int32)}
    with wl.CompoundRuntime(_lm_spec()) as rt:
        # static wiring holds on the real runtime
        assert affinity.check_wiring(rt).ok
        p, o = rt.init(jax.random.PRNGKey(0))
        # dynamic affinity: every dispatch of one training iteration
        # runs on the lm section's own worker thread
        with affinity.tracking() as trace:
            rt.train_iteration(p, o, batch, 0)
        assert trace, "executor did not record any dispatches"
        assert affinity.check_trace(trace).ok
        # donated-state reuse is rejected at install time with a finding
        # naming the section
        p2, o2 = rt.init(jax.random.PRNGKey(1))
        jax.tree_util.tree_leaves(o2["lm"].mu)[0].delete()
        with pytest.raises(DonatedStateError,
                           match=r"donation\.reuse \(opts\[lm\]\)"):
            rt.install(p2, o2)


# --------------------------------------------------------------------- #
# HLO gate engine
# --------------------------------------------------------------------- #
_SYNTH_HLO = """\
HloModule synth

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[1024], p1: u16[128]) -> (f32[1024], u16[1024]) {
  %p0 = f32[1024]{0} parameter(0)
  %p1 = u16[128]{0} parameter(1)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag = u16[1024]{0} all-gather(%p1), replica_groups=[1,8]<=[8], dimensions={0}
  ROOT %t = (f32[1024]{0}, u16[1024]{0}) tuple(%ar, %ag)
}
"""


def test_resolve_expressions():
    syms = {"pp": 4, "vocab": 1024, "gb": 8}
    assert hlo_gates.resolve(7, syms) == 7.0
    assert hlo_gates.resolve("vocab/pp", syms) == 256.0
    assert hlo_gates.resolve("0.05*pp", syms) == pytest.approx(0.2)
    assert hlo_gates.resolve("vocab/pp/2", syms) == 128.0
    with pytest.raises(ValueError, match="unknown symbol"):
        hlo_gates.resolve("nope*2", syms)
    with pytest.raises(ValueError, match="unresolvable"):
        hlo_gates.resolve("a + b", syms)


def test_validate_gate_schema_errors():
    base = {"name": "g", "description": "d", "programs": ["p"],
            "checks": []}
    with pytest.raises(ValueError, match="missing 'name'"):
        hlo_gates.validate_gate({k: v for k, v in base.items()
                                 if k != "name"})
    with pytest.raises(ValueError, match="unknown kind"):
        hlo_gates.validate_gate(
            {**base, "checks": [{"kind": "bogus"}]})
    with pytest.raises(ValueError, match="not declared"):
        hlo_gates.validate_gate(
            {**base, "checks": [{"kind": "wire_dtype", "program": "q",
                                 "dtype": "f32", "op": "<=",
                                 "value": 1}]})
    with pytest.raises(ValueError, match="op"):
        hlo_gates.validate_gate(
            {**base, "checks": [{"kind": "wire_dtype", "program": "p",
                                 "dtype": "f32", "op": "~",
                                 "value": 1}]})


def _gate(tmp_path, raw):
    f = tmp_path / "g.json"
    f.write_text(json.dumps(raw))
    return hlo_gates.load_gate(f)


def test_gate_dot_flops_and_ratio(tmp_path):
    a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    full = jax.jit(lambda a, b: a @ b).lower(
        a, jax.ShapeDtypeStruct((16, 32), jnp.float32)
    ).compile().as_text()
    shard = jax.jit(lambda a, b: a @ b).lower(
        a, jax.ShapeDtypeStruct((16, 8), jnp.float32)
    ).compile().as_text()
    gate = _gate(tmp_path, {
        "name": "toy", "description": "d",
        "symbols": {"w": 32, "shards": 4},
        "programs": ["full", "shard"],
        "checks": [
            {"kind": "dot_flops", "id": "full_present", "program": "full",
             "width": "w", "op": ">", "value": 0},
            {"kind": "dot_flops", "id": "no_full_in_shard",
             "program": "shard", "width": "w", "op": "==", "value": 0},
            {"kind": "dot_flops_ratio", "id": "reduction",
             "num_program": "full", "num_width": "w",
             "den_program": "shard", "den_width": "w/shards",
             "target": "shards", "rtol": 0.05},
        ]})
    rep, measured = hlo_gates.evaluate(
        gate, {"full": full, "shard": shard})
    assert rep.ok, rep.render()
    assert measured["full_present"] == pytest.approx(2 * 8 * 16 * 32)
    assert measured["reduction"] == pytest.approx(4.0)
    # symbol override flips the gate red and quotes the histogram
    rep2, _ = hlo_gates.evaluate(gate, {"full": full, "shard": shard},
                                 symbols={"w": 8})
    bad = [f for f in rep2.errors if f.check == "hlo.dot_flops"]
    assert bad and "width histogram" in bad[0].message


def test_gate_wire_dtype_family_and_subset(tmp_path):
    gate = _gate(tmp_path, {
        "name": "wires", "description": "d", "symbols": {},
        "programs": ["step"],
        "checks": [
            {"kind": "wire_dtype", "id": "u16", "program": "step",
             "dtype": "u16", "op": ">", "value": 0},
            {"kind": "wire_dtype", "id": "no_s8", "program": "step",
             "dtype": "s8", "op": "==", "value": 0},
            {"kind": "family_dtype_wire", "id": "f32_ar",
             "program": "step", "family": "all-reduce", "dtype": "f32",
             "op": "<=", "value": 6144},
            {"kind": "collectives_subset", "id": "fams",
             "program": "step", "allowed": ["all-reduce"]},
        ]})
    rep, measured = hlo_gates.evaluate(gate, {"step": _SYNTH_HLO})
    assert measured["u16"] == pytest.approx(7 / 8 * 1024 * 2)
    assert measured["f32_ar"] == pytest.approx(2 * 3 / 4 * 1024 * 4)
    sub = [f for f in rep.errors if f.check == "hlo.collectives_subset"]
    assert sub and "all-gather" in sub[0].message
    assert "silent replication" in sub[0].message


def test_gate_wire_total_ratio_and_missing_program(tmp_path):
    gate = _gate(tmp_path, {
        "name": "r", "description": "d", "symbols": {},
        "programs": ["a", "b"],
        "checks": [
            {"kind": "wire_total_ratio", "id": "ratio",
             "num_program": "a", "den_program": "b",
             "op": "<=", "value": 1.0},
        ]})
    rep, measured = hlo_gates.evaluate(
        gate, {"a": _SYNTH_HLO, "b": _SYNTH_HLO})
    assert rep.ok and measured["ratio"] == pytest.approx(1.0)
    rep2, _ = hlo_gates.evaluate(gate, {"a": _SYNTH_HLO})
    assert [f.check for f in rep2.errors] == ["hlo.missing-program"]


def test_committed_gate_files_all_load():
    paths = hlo_gates.list_gates()
    names = {p.stem for p in paths}
    assert {"vp_ce", "tp_in_stage", "compress", "regime_pp2",
            "regime_cp2", "regime_pp2tp2",
            "regime_compressed"} <= names
    for p in paths:
        gate = hlo_gates.load_gate(p)      # schema-validates
        assert gate.checks, p
