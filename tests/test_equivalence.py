"""Training-equivalence guarantees (paper: "Maestro produces identical
model updates as the original unmodified training process").

* wavefront reordering = permuting samples within the global batch →
  the summed gradient is permutation-invariant;
* per-section microbatching (grad accumulation) = the full-batch gradient;
* MoE head-pad physical layout is numerics-neutral;
* distillation with teacher-output-layer colocation equals the naive
  formulation that materializes teacher logits.
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as cfgs
from repro.models.model import build_model
from conftest import toy_batch


def _grads(m, params, batch):
    return jax.grad(lambda p: m.loss(p, batch)[0])(params)


def test_gradient_permutation_invariance():
    cfg = cfgs.get_reduced("granite-3-8b").replace(dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = toy_batch(cfg, B=8, S=16)
    perm = np.random.default_rng(0).permutation(8)
    batch_p = {k: v[perm] for k, v in batch.items()}
    g1 = _grads(m, params, batch)
    g2 = _grads(m, params, batch_p)
    err = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)))
    assert err < 1e-4, err          # fp32 reduction-order noise only


def test_microbatch_accumulation_equals_full_batch():
    cfg = cfgs.get_reduced("qwen1.5-0.5b").replace(dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = toy_batch(cfg, B=8, S=16)
    g_full = _grads(m, params, batch)
    g_acc = jax.tree_util.tree_map(jnp.zeros_like, params)
    for i in range(4):
        mb = {k: v[2 * i:2 * i + 2] for k, v in batch.items()}
        g = _grads(m, params, mb)
        g_acc = jax.tree_util.tree_map(lambda a, b: a + b / 4, g_acc, g)
    # fp32 reduction-order noise only (scales with the 24-layer depth)
    for a, b in zip(jax.tree_util.tree_leaves(g_full),
                    jax.tree_util.tree_leaves(g_acc)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)


def test_head_pad_is_numerics_neutral():
    cfg0 = cfgs.get_reduced("qwen2.5-32b").replace(dtype="float32")
    cfg1 = cfg0.replace(head_pad=2)
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = m0.init(jax.random.PRNGKey(0))
    batch = toy_batch(cfg0)
    l0, _ = m0.loss(params, batch)
    l1, _ = m1.loss(params, batch)
    assert float(l0) == float(l1)
    g0 = jax.grad(lambda p: m0.loss(p, batch)[0])(params)
    g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
    err = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g0, g1)))
    assert err == 0.0


def test_distill_colocation_equals_naive():
    """Hidden-state handoff + chunked KL == CE+KL computed from full
    teacher logits."""
    from repro.distill.workload import distill_loss, teacher_hidden
    from repro.models import common as cm

    t_cfg = cfgs.get_reduced("qwen2.5-32b").replace(dtype="float32",
                                                    vocab_size=512)
    s_cfg = cfgs.get_reduced("granite-3-8b").replace(dtype="float32",
                                                     vocab_size=512)
    mt = build_model(t_cfg)
    ms = build_model(s_cfg)
    params_t = mt.init(jax.random.PRNGKey(1))
    params_s = ms.init(jax.random.PRNGKey(2))
    batch = toy_batch(s_cfg, B=2, S=16)
    T, alpha = 2.0, 0.5

    h_t = teacher_hidden(params_t, t_cfg, batch["tokens"], impl="ref")
    loss, met = distill_loss(params_s, s_cfg, batch, h_t,
                             params_t["unembed"], alpha=alpha,
                             temperature=T, impl="ref", kl_impl="ref")

    # naive formulation with materialized logits
    logits_t = mt.forward(params_t, {"tokens": batch["tokens"]})
    logits_s = ms.forward(params_s, {"tokens": batch["tokens"]})
    lt = jax.nn.log_softmax(logits_t.astype(jnp.float32) / T)
    ls = jax.nn.log_softmax(logits_s.astype(jnp.float32) / T)
    kl_tok = jnp.sum(jnp.exp(lt) * (lt - ls), axis=-1)
    mask = batch["loss_mask"]
    kl = jnp.sum(kl_tok * mask) / jnp.sum(mask)
    ce = cm.cross_entropy(logits_s, batch["labels"], mask)
    naive = (1 - alpha) * ce + alpha * T * T * kl
    np.testing.assert_allclose(float(loss), float(naive), rtol=1e-5,
                               atol=1e-5)
