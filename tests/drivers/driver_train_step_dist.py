"""Multi-device driver: build_train_step pp/cp dispatch end-to-end.

Verifies on an 8-device host-platform mesh that

* a ``pp=2``, a ``cp=2`` and a ``pp=2 × tp=2`` ``build_train_step`` yield
  the same loss and post-update parameters as the monolithic
  ``pp=cp=1`` reference step (fp32 tolerance), and
* ``build_pp_loss`` with microbatching is *exact* against the monolithic
  MoE loss — the aux term is rebuilt from accumulated router stats, not
  per-microbatch-averaged.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.types import ParallelConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.dist.pipeline import build_pp_loss
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.models.model import build_model
from repro.optim import adamw, schedules
from repro.train import step as step_mod

GB, S = 8, 16
# larger eps keeps the Adam direction Lipschitz in the grads, so the
# fp32-reduction-order differences between regimes stay first-order in
# the post-update params instead of flipping sign-like updates
OPT = adamw.AdamWConfig(eps=1e-3)
LR = functools.partial(schedules.constant, peak_lr=1e-3)


def make_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (GB, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (GB, S)),
                              jnp.int32),
        "loss_mask": jnp.ones((GB, S), jnp.float32)}


def run_step(cfg, parallel, batch, params, opt):
    model = build_model(cfg, impl="ref")
    shape = ShapeConfig("t", "train", S, GB)
    mesh = shd.section_mesh(jax.devices()[:parallel.devices], parallel)
    step, shardings = step_mod.build_train_step(
        model, mesh, parallel, shape, lr_schedule=LR, opt_cfg=OPT)
    with mesh:
        p = jax.device_put(params, shardings["params"])
        o = jax.device_put(opt, shardings["opt"])
        new_p, _, metrics = step(p, o, batch, jnp.int32(0))
        new_p = jax.device_get(new_p)
    return new_p, float(metrics["loss"]), float(metrics["grad_norm"])


def tree_max_diff(a, b):
    return max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda x, y: float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                           - y.astype(jnp.float32)))),
        a, b)))


# ---- pp=2 / cp=2 / pp×tp train steps vs monolithic reference -------------
cfg = get_reduced("granite-3-8b").replace(dtype="float32", num_layers=4)
model = build_model(cfg, impl="ref")
# keep host copies: the jitted steps donate their inputs, and device_put
# aliases (doesn't copy) arrays whose sharding already matches
params = jax.device_get(model.init(jax.random.PRNGKey(0)))
opt = jax.device_get(adamw.init(params))
batch = make_batch(cfg)

ref_p, ref_loss, ref_gn = run_step(
    cfg, ParallelConfig(mbs=GB), batch, params, opt)

for tag, par in [
        ("pp2",   ParallelConfig(dp=2, pp=2, mbs=2)),
        ("cp2",   ParallelConfig(dp=2, cp=2, mbs=2)),
        ("pp2tp2", ParallelConfig(dp=2, pp=2, tp=2, mbs=2))]:
    got_p, got_loss, got_gn = run_step(cfg, par, batch, params, opt)
    dl = abs(got_loss - ref_loss)
    dg = abs(got_gn - ref_gn)
    dp_ = tree_max_diff(got_p, ref_p)
    print(f"{tag}: dloss={dl:.2e} dgnorm={dg:.2e} dparams={dp_:.2e}")
    assert dl < 1e-5, (tag, got_loss, ref_loss)
    assert dg < 1e-3, (tag, got_gn, ref_gn)
    assert dp_ < 1e-4, (tag, dp_)

# ---- colocated distill step under CP vs plain ----------------------------
from repro.distill.workload import build_colocated_step


def run_distill(parallel, mesh_dims, axes):
    mesh = jax.make_mesh(mesh_dims, axes)
    shape = ShapeConfig("d", "train", S, GB)
    step, sh = build_colocated_step(
        cfg, cfg, mesh, shape, parallel, impl="ref", lr_schedule=LR,
        opt_cfg=OPT)
    with mesh:
        ps = jax.device_put(params, sh["student"])
        o = jax.device_put(opt, sh["opt"])
        pt = jax.device_put(params, sh["teacher"])
        new_p, _, metrics = step(ps, o, pt, batch, jnp.int32(0))
        new_p = jax.device_get(new_p)
    return new_p, float(metrics["loss"])


d_ref_p, d_ref_loss = run_distill(
    ParallelConfig(mbs=GB), (1, 1), ("data", "model"))
d_cp_p, d_cp_loss = run_distill(
    ParallelConfig(dp=2, cp=2, mbs=4), (2, 1, 2, 1),
    ("data", "pipe", "seq", "model"))
dl = abs(d_cp_loss - d_ref_loss)
dp_ = tree_max_diff(d_cp_p, d_ref_p)
print(f"distill cp2: dloss={dl:.2e} dparams={dp_:.2e}")
assert dl < 1e-5, (d_cp_loss, d_ref_loss)
assert dp_ < 1e-4, dp_

# ---- build_pp_loss MoE aux exactness vs monolithic reference -------------
mcfg = get_reduced("mixtral-8x22b").replace(dtype="float32", num_layers=2)
mparams = init_params(tf.lm_specs(mcfg), jax.random.PRNGKey(1))
mbatch = make_batch(mcfg, seed=1)
l_ref, _ = tf.lm_loss(mparams, mcfg, mbatch, impl="ref")
mesh = jax.make_mesh((2, 2), ("data", "pipe"))
loss_fn, info = build_pp_loss(mcfg, mesh, n_micro=2, impl="ref")
assert info["moe_layers_per_stage"] == 1, info
with mesh:
    l_pp = jax.jit(loss_fn)(mparams, mbatch)
    g_pp = jax.jit(jax.grad(lambda p: loss_fn(p, mbatch)))(mparams)
err = abs(float(l_pp) - float(l_ref))
print(f"moe pp loss: ref={float(l_ref):.6f} pp={float(l_pp):.6f} "
      f"err={err:.2e}")
assert err < 1e-5, (float(l_pp), float(l_ref))
g_ref = jax.grad(
    lambda p: tf.lm_loss(p, mcfg, mbatch, impl="ref")[0])(mparams)
gerr = tree_max_diff(g_pp, g_ref)
print(f"moe pp grad err={gerr:.2e}")
assert gerr < 5e-4, gerr

# ---- HLO proof: vocab-parallel CE FLOPs, TP-in-stage sharding ------------
# post-SPMD shapes are per-device, so matching the local vocab-shard /
# FFN-shard width isolates exactly the dots the optimizations target.
# The expectations themselves are data: the declarative gate files under
# repro/analysis/gates/, evaluated here against this reduced config
# (vocab 512 overrides the gate's bench-config default).
from repro.analysis import hlo_gates

# dims chosen so V (512), V/pp (128) and d_ff (no collision) identify dots
hcfg = get_reduced("qwen1.5-0.5b").replace(
    dtype="float32", num_layers=4, vocab_size=512, d_model=64,
    num_heads=4, num_kv_heads=4, head_dim=16, d_ff=160,
    tie_embeddings=False)


def pp_grad_hlo(mesh, vocab_parallel):
    loss_fn, _ = build_pp_loss(hcfg, mesh, n_micro=2, impl="ref",
                               vocab_parallel=vocab_parallel)
    hp = init_params(tf.lm_specs(hcfg), jax.random.PRNGKey(0))
    hb = make_batch(hcfg, seed=2)
    with mesh:
        return jax.jit(jax.value_and_grad(
            lambda p: loss_fn(p, hb))).lower(hp).compile().as_text()


hmesh = jax.make_mesh((2, 4), ("data", "pipe"))
rep, m = hlo_gates.evaluate_file(
    hlo_gates.GATES_DIR / "vp_ce.json",
    {"masked": pp_grad_hlo(hmesh, vocab_parallel=False),
     "vp": pp_grad_hlo(hmesh, vocab_parallel=True)},
    symbols={"vocab": float(hcfg.padded_vocab)})
print(f"vp-CE unembed dot FLOPs: masked {m['baseline_full_vocab']:.3g} "
      f"vp {m['shard_present']:.3g} ratio {m['reduction']:.2f} (pp=4)")
rep.raise_on_error(AssertionError)

tmesh1 = jax.make_mesh((2, 2, 1), ("data", "pipe", "model"))
tmesh2 = jax.make_mesh((1, 2, 2), ("data", "pipe", "model"))
rep, m = hlo_gates.evaluate_file(
    hlo_gates.GATES_DIR / "tp_in_stage.json",
    {"tp1": pp_grad_hlo(tmesh1, vocab_parallel=True),
     "tp2": pp_grad_hlo(tmesh2, vocab_parallel=True)})
print(f"TP-in-stage FFN dot FLOPs: tp1 {m['tp1_ffn_present']:.3g} "
      f"tp2 {m['tp2_shard_present']:.3g} per-sample ratio "
      f"{m['reduction']:.2f} (tp=2)")
rep.raise_on_error(AssertionError)

# ---- multi-pod PP: the pod axis must carry data parallelism --------------
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "pipe"))
loss3, info3 = build_pp_loss(cfg, mesh3, n_micro=2, impl="ref")
assert info3["data_axis"] == ("pod", "data"), info3
l_base, _ = tf.lm_loss(params, cfg, batch, impl="ref")
with mesh3:
    l3 = jax.jit(loss3)(params, batch)
err3 = abs(float(l3) - float(l_base))
print(f"multipod pp loss err={err3:.2e}")
assert err3 < 1e-5, (float(l3), float(l_base))

print("DRIVER_OK train_step_dist")
