"""Multi-device driver: (a) elastic checkpoint restore onto a different
mesh shape; (b) tiny dry-run cells (reduced configs, 8-device meshes) for a
train, a decode, and a MoE cell — exercising the exact dryrun code path."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_DRYRUN_TINY"] = "1"
os.environ["REPRO_DRYRUN_DEVICES"] = "8"

import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer

# ---- elastic restore ------------------------------------------------------
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
mesh_b = jax.make_mesh((2, 4), ("data", "model"))
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
with tempfile.TemporaryDirectory() as d:
    ck = Checkpointer(d, async_save=False)
    ck.save(3, {"w": xa})
    got = ck.restore(3, {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
                     {"w": NamedSharding(mesh_b, P("model", "data"))})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(x))
    assert got["w"].sharding.mesh.shape["data"] == 2

# ---- tiny dry-run cells ---------------------------------------------------
os.environ["REPRO_DRYRUN_MESH"] = "4,2"
from repro.launch import dryrun

with tempfile.TemporaryDirectory() as d:
    for arch, shape in [("granite-3-8b", "train_4k"),
                        ("mixtral-8x22b", "train_4k"),
                        ("mamba2-130m", "decode_32k"),
                        ("whisper-small", "prefill_32k")]:
        rec = dryrun.run_cell(arch, shape, "single", Path(d))
        assert "roofline" in rec, (arch, shape, rec.get("error"))
        assert rec["hlo"]["flops_per_device"] > 0
        assert rec["memory"]["temp_size_in_bytes"] >= 0

os.environ["REPRO_DRYRUN_MESH"] = "2,2,2"
with tempfile.TemporaryDirectory() as d:
    rec = dryrun.run_cell("qwen1.5-0.5b", "train_4k", "multi", Path(d))
    assert "roofline" in rec
    rec = dryrun.run_cell("granite-20b", "long_500k", "single", Path(d))
    assert "skipped" in rec          # full-attention arch skips long_500k

print("DRIVER_OK elastic_dryrun")
