"""Multi-device driver: multi-teacher distillation declared on the
generic WorkloadSpec/CompoundRuntime API (no bespoke runtime class) —
generalist teacher (devices 0-1), domain-routed specialist teacher
(devices 2-3) and student (devices 4-7) on disjoint meshes, verified
against the colocated single-jit reference on the same microbatch
composition."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import workload as wl
from repro.core.types import ParallelConfig
from repro.data.synthetic import routed_lm_batches
from repro.dist.sharding import section_mesh
from repro.distill.multi_teacher import (build_colocated_step,
                                         colocated_batch,
                                         multi_teacher_spec,
                                         teacher_unembed)
from repro.optim import adamw

B, S, MBS = 16, 32, 4
ta_cfg = get_reduced("qwen2.5-32b").replace(dtype="float32",
                                            vocab_size=256)
tb_cfg = get_reduced("granite-3-8b").replace(dtype="float32",
                                             vocab_size=256, d_model=64,
                                             head_dim=16, d_ff=128)
s_cfg = get_reduced("qwen1.5-0.5b").replace(dtype="float32",
                                            vocab_size=256)
assert ta_cfg.d_model != tb_cfg.d_model, \
    "teachers should exercise genuinely different port widths"
opt_cfg = adamw.AdamWConfig(clip_norm=0.0)   # tight compare: no clip

spec = multi_teacher_spec(
    ta_cfg, tb_cfg, s_cfg,
    ta_parallel=ParallelConfig(dp=2), tb_parallel=ParallelConfig(dp=2),
    s_parallel=ParallelConfig(dp=4),
    global_batch=B, seq_len=S, mbs=MBS, impl="ref")
rt = wl.CompoundRuntime(spec, impl="ref", opt_cfg=opt_cfg)
meshes = [rt.rt.mesh(n) for n in ("teacher_a", "teacher_b", "student")]
assert sum(m.devices.size for m in meshes) == 8
flat = [d for m in meshes for d in m.devices.flat]
assert len(set(flat)) == 8, "section meshes must be disjoint"

params, opts = rt.init(jax.random.PRNGKey(0))
params_host = jax.tree_util.tree_map(np.asarray, params)
smesh = rt.rt.mesh("student")
w_a = teacher_unembed(params["teacher_a"], ta_cfg, smesh)
w_b = teacher_unembed(params["teacher_b"], tb_cfg, smesh)
consts = {"student": {"w_a": w_a, "w_b": w_b}}

data = routed_lm_batches(batch=B, seq_len=S, vocab=256,
                         specialist_ratio=0.4, seed=0)
batch = next(data)
dom = np.asarray(batch["domain"]).astype(bool)
assert 0 < dom.sum() < B, dom.sum()

# wavefront groups specialist samples into fewer microbatches than FIFO
host = {k: np.asarray(v) for k, v in batch.items()}
plan = rt.plan_iteration(host, reorder=True)
fifo = rt.plan_iteration(host, reorder=False)
act, fact = plan.activation["teacher_b"], fifo.activation["teacher_b"]
assert tuple(fifo.order) == tuple(range(B))
assert len(act.active_mbs) <= len(fact.active_mbs)

params2, opts2, m = rt.train_iteration(params, opts, batch, 0, plan=plan,
                                       consts=consts, return_grads=True)

# executed-schedule invariants: the specialist ran only on its
# microbatches, the generalist on all of them
ex = m["execution"]
assert ex.task_counts["teacher_a"] == plan.n_mb
assert ex.task_counts.get("teacher_b", 0) == len(act.active_mbs)
assert ex.task_counts["student"] == plan.n_mb + 1   # mbs + worker-side upd
assert m["n_tasks"] == ex.task_counts
ends = {(e.section, e.tag): e.end for e in ex.timeline}
for i in act.active_mbs:
    assert ends[("teacher_b", f"fwd{i}")] <= ends[("student", f"mb{i}")]
# frozen teachers: hidden pushes only, no cotangent traffic
assert rt.rt.queue.stats()["pushes"] == plan.n_mb + len(act.active_mbs)

# ---- colocated single-jit reference on the same composition ----------- #
omesh = section_mesh(jax.devices()[:4], ParallelConfig(dp=4), "oracle")
ostep, oshard = build_colocated_step(ta_cfg, tb_cfg, s_cfg, omesh,
                                     mbs=MBS, seq_len=S, impl="ref",
                                     opt_cfg=opt_cfg, return_grads=True)
ps = jax.device_put(params_host["student"], oshard["student"])
pa = jax.device_put(params_host["teacher_a"], oshard["teacher_a"])
pb = jax.device_put(params_host["teacher_b"], oshard["teacher_b"])
oopt = jax.device_put(adamw.init(ps), oshard["opt"])
ow_a = jax.device_put(np.asarray(w_a))
ow_b = jax.device_put(np.asarray(w_b))
onew, oopt2, om = ostep(ps, oopt, pa, pb, ow_a, ow_b,
                        colocated_batch(batch, plan), jnp.int32(0))

np.testing.assert_allclose(np.asarray(m["loss"]), np.asarray(om["loss"]),
                           rtol=1e-6, err_msg="loss")
for a, b in zip(jax.tree_util.tree_leaves(m["grads"]["student"]),
                jax.tree_util.tree_leaves(om["grads"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=1e-7, err_msg="student grads")
# Adam's mu/sqrt(nu) normalizer amplifies sub-tolerance grad noise on
# near-zero entries to sign scale, so updated params compare at a
# fraction of one optimizer step (lr=1e-3), not at grad tolerance.
for a, b in zip(jax.tree_util.tree_leaves(params2["student"]),
                jax.tree_util.tree_leaves(onew)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-4, err_msg="updated student params")
print("mixed-domain batch: disaggregated == colocated reference")

# ---- all-generalist batch: the specialist section never fires --------- #
gbatch = dict(next(data))
gbatch["domain"] = jnp.zeros((B,), jnp.int32)
ghost = {k: np.asarray(v) for k, v in gbatch.items()}
gplan = rt.plan_iteration(ghost, reorder=True)
assert gplan.activation["teacher_b"].active_mbs == ()
pushes = rt.rt.queue.stats()["pushes"]
params3, opts3, gm = rt.train_iteration(params2, opts2, gbatch, 1,
                                        plan=gplan, consts=consts,
                                        return_grads=True)
assert rt.rt.queue.stats()["pushes"] == pushes + gplan.n_mb, \
    "all-generalist batch must produce zero specialist traffic"
assert not any(e.section == "teacher_b"
               for e in gm["execution"].timeline)
onew2, _, ogm = ostep(onew, oopt2, pa, pb, ow_a, ow_b,
                      colocated_batch(gbatch, gplan), jnp.int32(1))
np.testing.assert_allclose(np.asarray(gm["loss"]),
                           np.asarray(ogm["loss"]), rtol=1e-6,
                           err_msg="all-generalist loss")
for a, b in zip(jax.tree_util.tree_leaves(params3["student"]),
                jax.tree_util.tree_leaves(onew2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-4,
                               err_msg="all-generalist updated params")
print("all-generalist batch: specialist idle, KL_b exactly zero, "
      "still matches the reference")

# losses must fall over a few iterations (the student actually learns)
losses = [float(m["loss"]), float(gm["loss"])]
p, o = params3, opts3
for i in range(2, 6):
    p, o, mi = rt.train_iteration(p, o, next(data), i, consts=consts)
    losses.append(float(mi["loss"]))
assert all(np.isfinite(losses)), losses
rt.shutdown()
print("DRIVER_OK multi_teacher")
