"""Multi-device driver: M-to-N message queue resharding across meshes."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.messages import MessageQueue, reshard

devs = jax.devices()
mesh_a = Mesh(np.array(devs[:4]).reshape(2, 2), ("data", "model"))
mesh_b = Mesh(np.array(devs[4:]).reshape(4, 1), ("data", "model"))

q = MessageQueue()
x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))

# 1-to-1 with resharding TP2 -> TP1, DP2 -> DP4
q.push("vit", "llm", "h0", xa)
got = q.pull("vit", "llm", "h0",
             sharding=NamedSharding(mesh_b, P("data", None)))
np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
assert got.sharding.mesh.shape["data"] == 4

# M-to-N: two senders push fragments of one tensor
q.push("teacher", "student", "h1", x[:4], frag_index=(slice(0, 4),),
       frag_rank=0, frag_count=2, global_shape=(8, 4))
q.push("teacher", "student", "h1", x[4:], frag_index=(slice(4, 8),),
       frag_rank=1, frag_count=2, global_shape=(8, 4))
got2 = q.pull("teacher", "student", "h1",
              sharding=NamedSharding(mesh_b, P("data", None)))
np.testing.assert_array_equal(np.asarray(got2), np.asarray(x))

# FIFO across keys, stats
q.push("a", "b", "k1", jnp.ones(3))
q.push("a", "b", "k2", jnp.zeros(3))
np.testing.assert_array_equal(np.asarray(q.pull("a", "b", "k2")),
                              np.zeros(3))
np.testing.assert_array_equal(np.asarray(q.pull("a", "b", "k1")),
                              np.ones(3))
assert q.stats()["pushes"] == 5

# direct reshard helper: TP4 <- TP2 style move
y = reshard(xa, mesh_b, P(None, "data"))
np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

# timeout on missing fragment
try:
    q.pull("a", "b", "missing", timeout=0.2)
    raise SystemExit("expected TimeoutError")
except TimeoutError:
    pass

print("DRIVER_OK messages")
