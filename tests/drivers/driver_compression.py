"""8-way compression driver: compressed psum vs exact psum with
analytic error bounds, wire-dtype HLO checks, error feedback across
steps, and the full compressed training paths (build_train_step and
CompoundRuntime) tracking the uncompressed loss trajectory."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

import repro.core.workload as wl
from repro.configs import get_reduced
from repro.core.types import ParallelConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.models import common as cm
from repro.models.model import build_model
from repro.optim import adamw, compression as gcomp
from repro.roofline import analysis as ra
from repro.train import step as step_mod

DP, N = 8, 5000
mesh8 = jax.make_mesh((DP,), ("data",))
rng = np.random.default_rng(0)


# ---- compressed psum vs exact psum: bounded elementwise error ------------- #
def reduce_with(method):
    def body(xs):
        x = xs[0]                              # local shard's tensor [N]
        if method == "bf16":
            return gcomp.compressed_psum_bf16(x, "data")
        return gcomp.compressed_psum_int8(x, "data")
    return jax.jit(shd.shard_map(body, mesh8, (P("data"),), P()))


xs = rng.normal(size=(DP, N)).astype(np.float32)
exact = xs.sum(axis=0)

bf = np.asarray(reduce_with("bf16")(jnp.asarray(xs)))
# phase 1: each term rounds to bf16 (2^-8 relative); phase 2: one more
# bf16 rounding of the reduced value
bound_bf = (2.0 ** -8) * np.abs(xs).sum(axis=0) + (2.0 ** -8) * np.abs(exact)
err_bf = np.abs(bf - exact)
assert (err_bf <= bound_bf * 1.05 + 1e-6).all(), \
    (err_bf.max(), bound_bf[err_bf.argmax()])
assert err_bf.max() > 0, "bf16 path suspiciously exact — not compressing?"

q8 = np.asarray(reduce_with("int8")(jnp.asarray(xs)))
# phase 1: half-step of each source's per-tensor scale; phase 2: half-step
# of the reduced chunk's scale (bounded by the global max of the phase-1
# sums, overestimated slightly by max|exact| + phase-1 slack)
scales = np.abs(xs).max(axis=1) / 127.0
bound_q8 = 0.5 * scales.sum() + 0.5 * (np.abs(exact).max() / 127.0
                                       + scales.sum() / 127.0)
err_q8 = np.abs(q8 - exact)
assert err_q8.max() <= bound_q8 * 1.05 + 1e-6, (err_q8.max(), bound_q8)
print(f"psum err: bf16 {err_bf.max():.3e}  int8 {err_q8.max():.3e}")

# ---- wire dtypes and ring-wire ratio straight from compiled HLO ----------- #
hlos = {}
for method in ("none", "bf16", "int8"):
    def body(xs, m=method):
        x = xs[0]
        if m == "none":
            return jax.lax.psum(x, "data")
        if m == "bf16":
            return gcomp.compressed_psum_bf16(x, "data")
        return gcomp.compressed_psum_int8(x, "data")
    f = jax.jit(shd.shard_map(body, mesh8, (P("data"),), P()))
    hlos[method] = f.lower(
        jax.ShapeDtypeStruct((DP, N), jnp.float32)).compile().as_text()

wire = {m: ra.wire_bytes_by_dtype(t) for m, t in hlos.items()}
assert wire["none"].get("f32", 0) > 0, wire["none"]
assert wire["bf16"].get("u16", 0) > 0, wire["bf16"]
assert wire["int8"].get("s8", 0) > 0, wire["int8"]
tot = {m: sum(w.values()) for m, w in wire.items()}
assert tot["bf16"] <= 0.55 * tot["none"], (tot["bf16"], tot["none"])
assert tot["int8"] <= 0.35 * tot["none"], (tot["int8"], tot["none"])
print(f"wire bytes: {tot}")

# ---- error feedback carries across steps (sum of emitted ≈ sum fed) ------- #
g_const = {"w": jnp.asarray(rng.normal(size=(DP, 64)).astype(np.float32))}


def ef_step(g_stacked, ef_stacked):
    def body(g, ef):
        red, new_ef = gcomp.ef_compress_tree(
            {"w": g["w"][0]}, gcomp.ErrorFeedback({"w": ef["w"][0]}),
            "data", "int8")
        return red, {"w": new_ef.residual["w"][None]}
    return jax.jit(shd.shard_map(
        body, mesh8, (P("data"), P("data")), (P(), P("data"))))(
            g_stacked, ef_stacked)


ef = {"w": jnp.zeros((DP, 64), jnp.float32)}
emitted = np.zeros(64, np.float64)
for _ in range(20):
    red, ef = ef_step(g_const, ef)
    emitted += np.asarray(red["w"], np.float64)
target = np.asarray(g_const["w"], np.float64).mean(axis=0) * 20
drift = np.abs(emitted - target).max()
res = np.abs(np.asarray(ef["w"])).max()
# EF keeps the long-run mean unbiased: total drift stays bounded by the
# (per-step-scale) residual, instead of growing ~linearly with steps
assert drift <= 2.0 * np.abs(np.asarray(g_const["w"])).max() / 127.0 * DP, \
    drift
assert res > 0, "int8 EF residual should be nonzero"
print(f"EF drift over 20 steps {drift:.3e}, residual max {res:.3e}")

# ---- build_train_step: compressed trajectories track the exact one -------- #
cfg = get_reduced("qwen1.5-0.5b").replace(dtype="float32", num_layers=2,
                                          vocab_size=64, d_ff=96)
GB, S = 8, 16
shape = ShapeConfig("t", "train", S, GB)
model = build_model(cfg, impl="ref")


def make_batch(seed):
    r = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(r.integers(0, cfg.vocab_size, (GB, S)),
                              jnp.int32),
        "labels": jnp.asarray(r.integers(0, cfg.vocab_size, (GB, S)),
                              jnp.int32),
        "loss_mask": jnp.ones((GB, S), jnp.float32)}


def run_steps(method, n_steps=4):
    par = ParallelConfig(dp=DP, mbs=1, zero_opt=False,
                         grad_compress=method)
    mesh = shd.section_mesh(jax.devices(), par)
    step, sh = step_mod.build_train_step(
        model, mesh, par, shape, opt_cfg=adamw.AdamWConfig(eps=1e-3))
    params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                            sh["params"])
    opt = jax.device_put(adamw.init(params), sh["opt"])
    ef = sh["ef_init"](params) if method != "none" else None
    losses = []
    for i in range(n_steps):
        args = (params, opt, make_batch(i), jnp.int32(i))
        if method == "none":
            params, opt, m = step(*args)
        else:
            params, opt, m, ef = step(*args, ef)
        losses.append(float(m["loss"]))
    if method == "int8":
        mx = max(float(jnp.max(jnp.abs(l)))
                 for l in jax.tree_util.tree_leaves(ef))
        assert mx > 0, "step-path int8 EF residual should be nonzero"
    return losses


base = run_steps("none")
for method, tol in (("bf16", 1e-3), ("int8", 5e-2)):
    ls = run_steps(method)
    dev = max(abs(a - b) / max(abs(b), 1e-8) for a, b in zip(ls, base))
    print(f"step losses {method}: max rel dev {dev:.2e}")
    assert dev < tol, (method, dev, ls, base)

# ---- CompoundRuntime: per-section knob, partial-grad (sum) semantics ------ #
B, S2, MBS, D = 8, 8, 4, 16
h_port = wl.Port("h", (S2, D), "float32")


def enc_fn(p, x):
    return {"h": jnp.tanh(x["x"] @ p["w"])}


def head_fn(p, x):
    pred = x["enc.h"] @ p["v"]
    return jnp.mean(jnp.square(pred - x["y"]))


def make_spec(method):
    par = ParallelConfig(dp=4, grad_compress=method)
    enc = wl.SectionSpec(
        "enc", cfg, par, enc_fn,
        {"w": cm.ParamSpec((D, D), (None, None), "normal", jnp.float32)},
        inputs={"x": wl.Field((S2, D), "float32")},
        emits=(h_port,))
    head = wl.SectionSpec(
        "head", cfg, par, head_fn,
        {"v": cm.ParamSpec((D, D), (None, None), "normal", jnp.float32)},
        inputs={"y": wl.Field((S2, D), "float32")},
        consumes=(wl.Consume("enc", h_port),),
        loss=True, critical=True)
    return wl.WorkloadSpec("t", (enc, head), seq_len=S2,
                           global_batch=B, mbs=MBS)


batches = [{"x": rng.normal(size=(B, S2, D)).astype(np.float32),
            "y": rng.normal(size=(B, S2, D)).astype(np.float32)}
           for _ in range(4)]

results = {}
for method in ("none", "bf16", "int8"):
    rt = wl.CompoundRuntime(make_spec(method),
                            opt_cfg=adamw.AdamWConfig(clip_norm=1.0))
    params, opts = rt.init(jax.random.PRNGKey(0))
    losses = []
    for i, b in enumerate(batches):
        params, opts, m = rt.train_iteration(params, opts, b, i)
        losses.append(float(m["loss"]))
    results[method] = losses
    if method == "int8":
        mx = max(float(jnp.max(jnp.abs(l)))
                 for l in jax.tree_util.tree_leaves(rt._ef))
        assert mx > 0, "runtime int8 EF residual should be nonzero"
    rt.shutdown()

base = results["none"]
for method, tol in (("bf16", 1e-3), ("int8", 5e-2)):
    ls = results[method]
    dev = max(abs(a - b) / max(abs(b), 1e-8) for a, b in zip(ls, base))
    print(f"runtime losses {method}: max rel dev {dev:.2e}")
    assert dev < tol, (method, dev, ls, base)

# ---- donated-state guard on the runtime install path ---------------------- #
rt = wl.CompoundRuntime(make_spec("none"),
                        opt_cfg=adamw.AdamWConfig(clip_norm=1.0))
params, opts = rt.init(jax.random.PRNGKey(0))
params2, opts2, _ = rt.train_iteration(params, opts, batches[0], 0)
for leaf in jax.tree_util.tree_leaves(opts):
    if hasattr(leaf, "delete") and not leaf.is_deleted():
        leaf.delete()
try:
    rt.install(params2, opts)
except adamw.DonatedStateError as e:
    assert "re-`place`" in str(e) or "place" in str(e).lower(), e
else:
    raise AssertionError("install() accepted a donated optimizer state")
rt.shutdown()

print("DRIVER_OK compression")
