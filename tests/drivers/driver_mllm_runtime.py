"""Multi-device driver: disaggregated MLLM runtime on the compound
executor — ViT section (devices 0-3) and LLM section (devices 4-7) on
disjoint dp=4 meshes, wavefront-scheduled microbatch dispatch, data-
dependent activation — proved bit-for-bit equal to the colocated
single-jit oracle on mixed image/text batches AND on an all-text batch
where the vision section never fires."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.types import ParallelConfig
from repro.data.synthetic import vlm_batches
from repro.dist.sharding import section_mesh
from repro.mllm.workload import (MLLMRuntime, build_colocated_step,
                                 colocated_batch, init_compound_params)
from repro.models.vlm import vit_config
from repro.optim import adamw

B, S, K, MBS = 16, 32, 4, 4
lm_cfg = get_reduced("pixtral-12b").replace(
    dtype="float32", vocab_size=256, vision_dim=32, max_image_tokens=K)
vit_cfg = vit_config(num_layers=2, d_model=32, num_heads=2, d_ff=64,
                     patch_dim=16, downsample=4, out_dim=32,
                     name="vit-tiny").replace(dtype="float32")
opt_cfg = adamw.AdamWConfig(clip_norm=0.0)   # bitwise: no clip threshold

rt = MLLMRuntime(vit_cfg, lm_cfg,
                 vit_parallel=ParallelConfig(dp=4),
                 lm_parallel=ParallelConfig(dp=4),
                 global_batch=B, seq_len=S, mbs=MBS,
                 impl="ref", opt_cfg=opt_cfg)
assert rt.rt.mesh("vit").devices.size == 4
assert rt.rt.mesh("llm").devices.size == 4
assert not (set(rt.rt.mesh("vit").devices.flat)
            & set(rt.rt.mesh("llm").devices.flat)), "meshes must be disjoint"

params_host = init_compound_params(vit_cfg, lm_cfg, jax.random.PRNGKey(0))
params, opts = rt.place(params_host)

# colocated single-jit oracle on a 4-device dp=4 mesh (same section layout)
omesh = section_mesh(jax.devices()[:4], ParallelConfig(dp=4), "oracle")
ostep, oshard = build_colocated_step(vit_cfg, lm_cfg, omesh, mbs=MBS,
                                     seq_len=S, impl="ref",
                                     opt_cfg=opt_cfg, return_grads=True)
oparams = jax.device_put(params_host, oshard["params"])
oopt = jax.device_put(adamw.init(oparams), oshard["opt"])

data = vlm_batches(batch=B, seq_len=S, vocab=256, vision_ratio=0.5,
                   image_tokens=K, patch_dim=16, seed=0)


def tree_equal(a, b, what):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), (what, len(la), len(lb))
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


# ---- mixed image/text batch: wavefront reordering actually happens ----- #
batch = next(data)
has = np.asarray(batch["has_image"]).astype(bool)
assert 0 < has.sum() < B, has.sum()
plan = rt.plan_iteration(has, reorder=True)
assert tuple(plan.order) != tuple(range(B)), \
    "wavefront must reorder a heterogeneous batch"
fifo_plan = rt.plan_iteration(has, reorder=False)
assert tuple(fifo_plan.order) == tuple(range(B))
# reordering groups text samples: more all-text microbatches than FIFO
assert len(plan.image_mbs) <= len(fifo_plan.image_mbs)

params2, opts2, m = rt.train_iteration(params, opts, batch, 0, plan=plan,
                                       return_grads=True)
onew_p, onew_opt, om = ostep(oparams, oopt, colocated_batch(batch, plan),
                             jnp.int32(0))

np.testing.assert_array_equal(np.asarray(m["loss"]),
                              np.asarray(om["loss"]), err_msg="loss")
tree_equal(m["grads"]["lm"], om["grads"]["lm"], "lm grads")
tree_equal(m["grads"]["vit"], om["grads"]["vit"], "vit grads")
tree_equal(params2["lm"], onew_p["lm"], "updated lm params")
tree_equal(params2["vit"], onew_p["vit"], "updated vit params")
print("mixed batch: disaggregated == colocated oracle (bit-for-bit)")

# realized executed-schedule invariants: every vit fwd completes before
# its consumer LM microbatch completes; bwd only after the LM returned
# the cotangent; the vision section ran only for image-bearing mbs
ex = m["execution"]
ends = {(e.section, e.tag): e.end for e in ex.timeline}
assert set(ex.dispatch_order["vit"]) == \
    {f"fwd{i}" for i in plan.image_mbs} | \
    {f"bwd{i}" for i in plan.image_mbs} | {"upd"}
for i in plan.image_mbs:
    assert ends[("vit", f"fwd{i}")] <= ends[("llm", f"mb{i}")]
    assert ends[("vit", f"fwd{i}")] <= ends[("vit", f"bwd{i}")]
assert m["n_vit_tasks"] == 2 * len(plan.image_mbs)
# embeddings + cotangents, plus the 2-push joint grad-norm rendezvous
assert rt.rt.queue.stats()["pushes"] == 2 * len(plan.image_mbs) + 2

# ---- all-text batch: the vision section never fires ------------------- #
data_text = vlm_batches(batch=B, seq_len=S, vocab=256, vision_ratio=0.0,
                        image_tokens=K, patch_dim=16, seed=1)
tbatch = next(data_text)
assert not np.asarray(tbatch["has_image"]).any()
tplan = rt.plan_iteration(np.asarray(tbatch["has_image"]), reorder=True)
assert tplan.image_mbs == ()
pushes_before = rt.rt.queue.stats()["pushes"]
params3, opts3, tm = rt.train_iteration(params2, opts2, tbatch, 1,
                                        plan=tplan, return_grads=True)
assert rt.rt.queue.stats()["pushes"] == pushes_before + 2, \
    "all-text batch: gnorm rendezvous only, zero activation traffic"
assert tm["n_vit_tasks"] == 0
assert [e.tag for e in tm["execution"].timeline
        if e.section == "vit"] == ["upd"], \
    "idle vision section runs only its (exact-zero-grad) update"

onew_p2, onew_opt2, otm = ostep(onew_p, onew_opt,
                                colocated_batch(tbatch, tplan),
                                jnp.int32(1))
np.testing.assert_array_equal(np.asarray(tm["loss"]),
                              np.asarray(otm["loss"]),
                              err_msg="all-text loss")
tree_equal(tm["grads"]["lm"], otm["grads"]["lm"], "all-text lm grads")
tree_equal(tm["grads"]["vit"], otm["grads"]["vit"], "all-text vit grads")
tree_equal(params3["lm"], onew_p2["lm"], "all-text updated lm params")
tree_equal(params3["vit"], onew_p2["vit"], "all-text updated vit params")
print("all-text batch: vision section idle, still bit-for-bit")

rt.shutdown()

# ---- clip-ACTIVE path: the joint cross-section grad norm must drive the
# same clip scale the colocated oracle computes (this is what
# adamw.update(gnorm=) + MLLMRuntime._joint_gnorm exist for) ------------- #
clip_cfg = adamw.AdamWConfig(clip_norm=0.05)
rt2 = MLLMRuntime(vit_cfg, lm_cfg,
                  vit_parallel=ParallelConfig(dp=4),
                  lm_parallel=ParallelConfig(dp=4),
                  global_batch=B, seq_len=S, mbs=MBS,
                  impl="ref", opt_cfg=clip_cfg)
params_c, opts_c = rt2.place(params_host)
ostep2, oshard2 = build_colocated_step(vit_cfg, lm_cfg, omesh, mbs=MBS,
                                       seq_len=S, impl="ref",
                                       opt_cfg=clip_cfg, return_grads=True)
oparams_c = jax.device_put(params_host, oshard2["params"])
oopt_c = jax.device_put(adamw.init(oparams_c), oshard2["opt"])
cbatch = next(data)
cplan = rt2.plan_iteration(np.asarray(cbatch["has_image"]), reorder=True)
params_c2, _, cm_ = rt2.train_iteration(params_c, opts_c, cbatch, 0,
                                        plan=cplan, return_grads=True)
onew_pc, _, ocm = ostep2(oparams_c, oopt_c, colocated_batch(cbatch, cplan),
                         jnp.int32(0))
assert float(cm_["grad_norm"]) > clip_cfg.clip_norm, \
    "clipping must actually fire for this check to mean anything"
np.testing.assert_array_equal(np.asarray(cm_["loss"]),
                              np.asarray(ocm["loss"]),
                              err_msg="clip-path loss")
tree_equal(cm_["grads"]["lm"], ocm["grads"]["lm"], "clip-path lm grads")
tree_equal(cm_["grads"]["vit"], ocm["grads"]["vit"], "clip-path vit grads")
# The joint gnorm matches the oracle's to a few ulps but not always
# bitwise: the per-leaf sums of squares ARE bitwise equal (probed), but
# inside the oracle jit XLA fuses the stack-of-scalars sum into a scalar
# expression tree whose association differs from the runtime's
# materialized-vector reduce — an inherent cross-jit-boundary fusion
# limit, data-dependent, a couple of ulps of the norm.
gr, go = float(cm_["grad_norm"]), float(ocm["grad_norm"])
assert abs(gr - go) <= 4 * np.spacing(np.float32(go)), (gr, go)
# the 1-ulp clip scale propagates multiplicatively into the update
for sec in ("lm", "vit"):
    for a, b in zip(jax.tree_util.tree_leaves(params_c2[sec]),
                    jax.tree_util.tree_leaves(onew_pc[sec])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7,
                                   err_msg=f"clipped {sec} params")
print("clip-active path: grads bitwise, joint gnorm within ulps, "
      "clipped updates within scale-ulp of oracle")
rt2.shutdown()

# ---- ViT-CP section: context parallelism on the vision section's long
# patch sequences (the paper's own use of CP) now runs through the
# executor via the consolidated parallel_regime dispatch instead of the
# old blanket _reject_pp_cp.  vit mesh (data=2, seq=2) + llm dp=4. ------- #
rt3 = MLLMRuntime(vit_cfg, lm_cfg,
                  vit_parallel=ParallelConfig(dp=2, cp=2),
                  lm_parallel=ParallelConfig(dp=4),
                  global_batch=B, seq_len=S, mbs=MBS,
                  impl="ref", opt_cfg=opt_cfg)
vm3 = rt3.rt.mesh("vit")
assert dict(vm3.shape)["seq"] == 2 and dict(vm3.shape)["data"] == 2
params_cp, opts_cp = rt3.place(params_host)
oparams3 = jax.device_put(params_host, oshard["params"])
oopt3 = jax.device_put(adamw.init(oparams3), oshard["opt"])
cpbatch = next(data)
cp_plan = rt3.plan_iteration(np.asarray(cpbatch["has_image"]),
                             reorder=True)
assert len(cp_plan.image_mbs) > 0
params_cp2, _, mcp = rt3.train_iteration(params_cp, opts_cp, cpbatch, 0,
                                         plan=cp_plan, return_grads=True)
onew_p3, _, ocp = ostep(oparams3, oopt3, colocated_batch(cpbatch, cp_plan),
                        jnp.int32(0))
np.testing.assert_allclose(np.asarray(mcp["loss"]),
                           np.asarray(ocp["loss"]), rtol=1e-6,
                           err_msg="vit-cp loss")
for sec in ("lm", "vit"):
    for a, b in zip(jax.tree_util.tree_leaves(mcp["grads"][sec]),
                    jax.tree_util.tree_leaves(ocp["grads"][sec])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-7,
                                   err_msg=f"vit-cp {sec} grads")
    for a, b in zip(jax.tree_util.tree_leaves(params_cp2[sec]),
                    jax.tree_util.tree_leaves(onew_p3[sec])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-7,
                                   err_msg=f"vit-cp {sec} params")
ex3 = mcp["execution"]
assert set(ex3.dispatch_order["vit"]) == \
    {f"fwd{i}" for i in cp_plan.image_mbs} | \
    {f"bwd{i}" for i in cp_plan.image_mbs} | {"upd"}
print("ViT-CP section (dp=2, cp=2): runs through the executor, "
      "loss/grads/params match the oracle")
rt3.shutdown()

# ---- streaming with cross-iteration lookahead ENABLED: three pipelined
# iterations through submit/retire must stay bit-for-bit with the oracle
# stepped three times — removing the global barrier must not change a
# single bit of the training trajectory ------------------------------------ #
rt4 = MLLMRuntime(vit_cfg, lm_cfg,
                  vit_parallel=ParallelConfig(dp=4),
                  lm_parallel=ParallelConfig(dp=4),
                  global_batch=B, seq_len=S, mbs=MBS,
                  impl="ref", opt_cfg=opt_cfg, lookahead=1)
params_s, opts_s = rt4.place(params_host)
rt4.install(params_s, opts_s)
op4 = jax.device_put(params_host, oshard["params"])
oo4 = jax.device_put(adamw.init(op4), oshard["opt"])
sbatches = [next(data) for _ in range(3)]
splans = []
max_inflight = 0
for i, b in enumerate(sbatches):
    pl = rt4.plan_iteration(np.asarray(b["has_image"]), reorder=True)
    splans.append(pl)
    rt4.submit_iteration(b, i, plan=pl)
    max_inflight = max(max_inflight, rt4.in_flight)
assert max_inflight == 2, \
    f"lookahead=1 must pipeline two iterations in flight: {max_inflight}"
ms = rt4.drain()
assert rt4.in_flight == 0 and len(ms) == 3
params_s2, opts_s2 = rt4.state()
oms = []
for i, b in enumerate(sbatches):
    op4, oo4, om_i = ostep(op4, oo4, colocated_batch(b, splans[i]),
                           jnp.int32(i))
    oms.append(om_i)
for i in range(3):
    np.testing.assert_array_equal(
        np.asarray(ms[i]["loss"]), np.asarray(oms[i]["loss"]),
        err_msg=f"streaming loss, iteration {i}")
tree_equal(params_s2["lm"], op4["lm"], "streamed lm params after 3 iters")
tree_equal(params_s2["vit"], op4["vit"],
           "streamed vit params after 3 iters")
print("streaming lookahead=1: three pipelined iterations bit-for-bit "
      "with the oracle trajectory")
rt4.shutdown()
print("DRIVER_OK mllm_runtime")
