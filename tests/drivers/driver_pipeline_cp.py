"""Multi-device driver: cross-pod GPipe PP (loss+grads == reference) and
context-parallel attention (ulysses + allgather)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.dist.context import cp_attention
from repro.dist.pipeline import build_pp_loss
from repro.kernels import ref
from repro.models import transformer as tf
from repro.models.common import init_params

# ---- PP over pod × manual DP ---------------------------------------------
cfg = get_reduced("granite-3-8b").replace(dtype="float32", num_layers=4)
mesh = jax.make_mesh((2, 4), ("pod", "data"))
loss_fn, _ = build_pp_loss(cfg, mesh, n_micro=2)
params = init_params(tf.lm_specs(cfg), jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
B, S = 16, 16
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "loss_mask": jnp.ones((B, S), jnp.float32)}
l_ref, _ = tf.lm_loss(params, cfg, batch, impl="ref")
with mesh:
    l_pp = jax.jit(loss_fn)(params, batch)
    g_pp = jax.jit(jax.grad(lambda p: loss_fn(p, batch)))(params)
assert abs(float(l_pp) - float(l_ref)) < 1e-5, (float(l_pp), float(l_ref))
g_ref = jax.grad(lambda p: tf.lm_loss(p, cfg, batch, impl="ref")[0])(params)
err = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), g_pp, g_ref)))
assert err < 5e-4, err

# ---- CP attention ---------------------------------------------------------
mesh_cp = jax.make_mesh((4,), ("model",))
Bq, Sq, H, KV, D = 2, 64, 8, 4, 16
ks = jax.random.split(jax.random.PRNGKey(1), 3)
q = jax.random.normal(ks[0], (Bq, Sq, H, D))
k = jax.random.normal(ks[1], (Bq, Sq, KV, D))
v = jax.random.normal(ks[2], (Bq, Sq, KV, D))
o_ref = ref.mha_reference(q, k, v, causal=True)
with mesh_cp:
    for mode in ("ulysses", "allgather"):
        o = cp_attention(q, k, v, mesh_cp, mode=mode, causal=True,
                         block_q=16, block_kv=16)
        e = float(jnp.max(jnp.abs(o - o_ref)))
        assert e < 1e-5, (mode, e)
    g = jax.grad(lambda q: jnp.sum(cp_attention(
        q, k, v, mesh_cp, mode="ulysses", causal=True, block_q=16,
        block_kv=16) ** 2))(q)
assert bool(jnp.all(jnp.isfinite(g)))

print("DRIVER_OK pipeline_cp")
