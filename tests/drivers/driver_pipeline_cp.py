"""Multi-device driver: cross-pod GPipe PP (loss+grads == reference) and
context-parallel attention — every CP mode (ulysses, overlap-pipelined
ulysses, head-replicated ulysses_mqa, allgather) exact forward AND
backward against the naive reference, plus the kernel-substrate dispatch
(``--cp-only`` + ``REPRO_KERNEL_IMPL=pallas_interpret`` in CI runs the
whole CP matrix through the interpreted Pallas kernel)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.dist.context import cp_attention
from repro.dist.pipeline import build_pp_loss
from repro.kernels import ref
from repro.models import transformer as tf
from repro.models.common import init_params

CP_ONLY = "--cp-only" in sys.argv[1:]

# ---- PP over pod × manual DP ---------------------------------------------
if not CP_ONLY:
    cfg = get_reduced("granite-3-8b").replace(dtype="float32",
                                              num_layers=4)
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    loss_fn, _ = build_pp_loss(cfg, mesh, n_micro=2)
    params = init_params(tf.lm_specs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = 16, 16
    batch = {"tokens": jnp.asarray(
                 rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
             "labels": jnp.asarray(
                 rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    l_ref, _ = tf.lm_loss(params, cfg, batch, impl="ref")
    with mesh:
        l_pp = jax.jit(loss_fn)(params, batch)
        g_pp = jax.jit(jax.grad(lambda p: loss_fn(p, batch)))(params)
    assert abs(float(l_pp) - float(l_ref)) < 1e-5, (float(l_pp),
                                                    float(l_ref))
    g_ref = jax.grad(
        lambda p: tf.lm_loss(p, cfg, batch, impl="ref")[0])(params)
    err = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_pp, g_ref)))
    assert err < 5e-4, err

# ---- CP attention ---------------------------------------------------------
# impl tier for the in-shard flash calls: CI also runs this driver with
# REPRO_KERNEL_IMPL=pallas_interpret, which overrides the kops dispatch
# and sends every case below through the interpreted Pallas kernel.
Bq, Sq, H, KV, D = 2, 64, 8, 4, 16
ks = jax.random.split(jax.random.PRNGKey(1), 3)
q = jax.random.normal(ks[0], (Bq, Sq, H, D))
k = jax.random.normal(ks[1], (Bq, Sq, KV, D))
v = jax.random.normal(ks[2], (Bq, Sq, KV, D))


def check(mesh, mode, chunks=1, window=0, tol=2e-5):
    """Forward and full (dq, dk, dv) backward vs the naive reference."""
    def f(q, k, v):
        return cp_attention(q, k, v, mesh, mode=mode, causal=True,
                            window=window, overlap_chunks=chunks,
                            block_q=16, block_kv=16)

    def r(q, k, v):
        return ref.mha_reference(q, k, v, causal=True, window=window)

    with mesh:
        o = f(q, k, v)
        e = float(jnp.max(jnp.abs(o - r(q, k, v))))
        assert e < tol, (mode, chunks, window, "fwd", e)
        loss = lambda fn: lambda *a: jnp.sum(fn(*a) ** 2)
        g = jax.grad(loss(f), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss(r), argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("dq dk dv".split(), g, gr):
        eb = float(jnp.max(jnp.abs(a - b)))
        assert eb < tol, (mode, chunks, window, name, eb)


# cp=4 divides both H=8 and KV=4: ulysses territory, monolithic and
# overlap-chunked (the a2a interleaving makes chunk positions strided —
# kv_positions keeps causal/window masking exact).
mesh4 = jax.make_mesh((4,), ("model",))
check(mesh4, "ulysses")
check(mesh4, "allgather")
for chunks in (2, 4):
    check(mesh4, "ulysses", chunks=chunks)
check(mesh4, "ulysses", chunks=4, window=24)

# cp=8 does not divide KV=4: head-replicated ulysses vs the allgather
# fallback (the comm claim lives in the ulysses_mqa gate; exactness here).
mesh8 = jax.make_mesh((8,), ("model",))
check(mesh8, "ulysses_mqa")
check(mesh8, "allgather")
check(mesh8, "auto")        # resolves to ulysses_mqa at this shape

# explicit kernel-tier dispatch (independent of the env override):
# the interpreted Pallas kernel must agree inside the shard too.
with mesh4:
    o_pi = cp_attention(q, k, v, mesh4, mode="ulysses",
                        impl="pallas_interpret", overlap_chunks=2,
                        block_q=16, block_kv=16)
e = float(jnp.max(jnp.abs(o_pi - ref.mha_reference(q, k, v, causal=True))))
assert e < 2e-5, ("pallas_interpret", e)

print("DRIVER_OK pipeline_cp")
