"""Multi-device driver: the declarative HLO gate suite across regimes.

Compiles the real ``build_train_step`` post-SPMD HLO for every
distributed regime the repo claims (pp2 / cp2 / pp2tp2 / compressed-dp8)
and evaluates each regime's gate file (``repro/analysis/gates/``)
against it — the regime's declared collective profile (an undeclared
family = silent replication), the compressed payload dtypes, and the
f32 all-reduce residue budget are all machine-checked from data, not
inline asserts.  The per-claim gates (``vp_ce`` / ``tp_in_stage`` /
``compress``) run in ``driver_train_step_dist.py`` and the bench; this
driver owns the per-regime profiles.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo_gates
from repro.configs import get_reduced
from repro.core.types import ParallelConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.models.model import build_model
from repro.optim import adamw, schedules
from repro.train import step as step_mod

GB, S = 8, 16
OPT = adamw.AdamWConfig(eps=1e-3)
LR = functools.partial(schedules.constant, peak_lr=1e-3)
cfg = get_reduced("granite-3-8b").replace(dtype="float32", num_layers=4)
model = build_model(cfg, impl="ref")


def make_batch(c, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(rng.integers(0, c.vocab_size, (GB, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, c.vocab_size, (GB, S)),
                              jnp.int32),
        "loss_mask": jnp.ones((GB, S), jnp.float32)}


def step_hlo(par):
    shape = ShapeConfig("t", "train", S, GB)
    mesh = shd.section_mesh(jax.devices()[:par.devices], par)
    step, sh = step_mod.build_train_step(model, mesh, par, shape,
                                         lr_schedule=LR, opt_cfg=OPT)
    params = jax.device_put(model.init(jax.random.PRNGKey(0)),
                            sh["params"])
    opt = jax.device_put(adamw.init(params), sh["opt"])
    args = [params, opt, make_batch(cfg), jnp.int32(0)]
    if par.grad_compress != "none":
        args.append(sh["ef_init"](params))
    with mesh:
        return step.lower(*args).compile().as_text()


REGIMES = {
    "pp2": ParallelConfig(dp=2, pp=2, mbs=2),
    "cp2": ParallelConfig(dp=2, cp=2, mbs=2),
    "pp2tp2": ParallelConfig(dp=2, pp=2, tp=2, mbs=2),
    "compressed": ParallelConfig(dp=8, mbs=1, zero_opt=False,
                                 grad_compress="int8"),
}

failed = False
for tag, par in REGIMES.items():
    rep, _ = hlo_gates.evaluate_file(
        hlo_gates.GATES_DIR / f"regime_{tag}.json",
        {"step": step_hlo(par)})
    print(rep.render())
    failed = failed or not rep.ok

assert not failed, "one or more regime gates reported errors"
print("DRIVER_OK hlo_gates")
