"""Multi-device driver: disaggregated distillation runtime with fanout —
teacher and student sections on disjoint meshes; and numerical equivalence
against a monolithic (single-jit) formulation of the same loss."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.types import ParallelConfig
from repro.distill.workload import (DistillRuntime, distill_loss,
                                    teacher_hidden)

t_cfg = get_reduced("qwen2.5-32b").replace(dtype="float32", vocab_size=512)
s_cfg = get_reduced("qwen1.5-0.5b").replace(dtype="float32",
                                            vocab_size=512)
rt = DistillRuntime(t_cfg, s_cfg,
                    teacher_parallel=ParallelConfig(dp=2, tp=2),
                    student_parallel=ParallelConfig(dp=4, tp=1),
                    impl="ref", alpha=0.5, temperature=2.0, lr=1e-3)
assert rt.fanout == 2, rt.fanout

params_t, params_s, opt = rt.init(jax.random.PRNGKey(0))
params_s0 = jax.tree_util.tree_map(lambda x: np.asarray(x), params_s)
w_t = rt.teacher_unembed(params_t)
rng = np.random.default_rng(0)
B, S = 8, 32
losses = []
batches = []
for i in range(4):
    batch = {"tokens": jnp.asarray(rng.integers(0, 512, (B, S)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 512, (B, S)), jnp.int32),
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    batches.append(batch)
    params_s, opt, m = rt.train_iteration(params_t, params_s, opt, batch,
                                          i, w_t=w_t)
    losses.append(float(m["loss"]))
assert all(np.isfinite(losses)), losses
assert rt.rt.queue.stats()["pushes"] == 4

# equivalence of the FIRST iteration's loss vs monolithic computation on
# host (same params, same batch)
params_s_host = jax.tree_util.tree_map(jnp.asarray, params_s0)
params_t_host = jax.tree_util.tree_map(lambda x: jnp.asarray(np.asarray(x)),
                                       params_t)
h_t = teacher_hidden(params_t_host, t_cfg, batches[0]["tokens"], impl="ref")
mono, _ = distill_loss(params_s_host, s_cfg, batches[0], h_t,
                       params_t_host["unembed"], alpha=0.5,
                       temperature=2.0, impl="ref", kl_impl="ref")
assert abs(float(mono) - losses[0]) < 1e-4, (float(mono), losses[0])

rt.shutdown()
print("DRIVER_OK distill_runtime")
