"""End-to-end system behaviour on CPU: the full train loop (step builder +
optimizer + checkpointing + data pipeline) actually *learns* on the
synthetic markov stream, and the serving path generates consistently."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as cfgs
from repro.core.types import ParallelConfig, ShapeConfig
from repro.data.synthetic import lm_batches
from repro.models.model import build_model
from repro.optim import adamw, schedules
from repro.train import step as step_mod
from repro.train.loop import train


def test_training_learns_on_single_device_mesh(tmp_path):
    cfg = cfgs.get_reduced("qwen1.5-0.5b").replace(
        dtype="float32", num_layers=2, vocab_size=64, d_ff=128)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("tiny", "train", 32, 8)
    model = build_model(cfg)
    step, shardings = step_mod.build_train_step(
        model, mesh, ParallelConfig(mbs=4), shape,
        lr_schedule=functools.partial(schedules.constant, peak_lr=3e-3))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    with mesh:
        params = jax.device_put(params, shardings["params"])
        opt = jax.device_put(opt, shardings["opt"])
        res = train(step, params=params, opt_state=opt,
                    batches=lm_batches(batch=8, seq_len=32, vocab=64,
                                       seed=0),
                    num_steps=30, log_every=1000, log_fn=lambda s: None)
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first - 0.3, (first, last)   # actually learning


def test_serve_prefill_decode_loop():
    cfg = cfgs.get_reduced("granite-3-8b").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)),
                         jnp.int32)
    logits, cache = model.prefill(params, {"tokens": prompt},
                                  extra_cache=4)
    toks = [jnp.argmax(logits, -1)[:, None].astype(jnp.int32)]
    for i in range(4):
        logits, cache = model.decode(params, cache, toks[-1],
                                     jnp.int32(12 + i))
        toks.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
    gen = jnp.concatenate(toks, axis=1)
    assert gen.shape == (2, 5)
    # greedy decode must match teacher-forced full forward on own output
    full = model.forward(params, {"tokens": jnp.concatenate(
        [prompt, gen[:, :-1]], axis=1)})
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(full[:, -1], -1)),
        np.asarray(gen[:, -1]))


def test_train_step_sharded_single_device_matches_plain():
    """The jitted/sharded step computes the same loss as a plain grad."""
    from conftest import toy_batch
    cfg = cfgs.get_reduced("granite-3-8b").replace(dtype="float32")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeConfig("tiny", "train", 16, 4)
    model = build_model(cfg)
    step, shardings = step_mod.build_train_step(
        model, mesh, ParallelConfig(mbs=4), shape)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    batch = toy_batch(cfg, B=4, S=16)
    plain_loss, _ = model.loss(params, batch)
    with mesh:
        p = jax.device_put(params, shardings["params"])
        o = jax.device_put(opt, shardings["opt"])
        _, _, metrics = step(p, o, batch, jnp.int32(0))
    np.testing.assert_allclose(float(metrics["loss"]), float(plain_loss),
                               rtol=1e-5, atol=1e-5)
