"""Spec-compile-time guarantees of the declarative workload API.

Runtime equivalence (disaggregated ≡ colocated, bit-for-bit for MLLM)
lives in the multi-device subprocess drivers; this file covers what must
raise (or hold) BEFORE any mesh is carved or jit traced: port typing,
graph shape, activation layouts, and the consolidated per-section
parallelism validation."""
import numpy as np
import pytest

import repro.core.workload as wl
from repro.configs import get_reduced
from repro.core.types import ParallelConfig
from repro.dist import sharding as shd


def _cfg():
    return get_reduced("qwen1.5-0.5b").replace(
        dtype="float32", num_layers=2, vocab_size=64, d_ff=128)


def _producer(name="prod", port=None, **kw):
    port = port or wl.Port("h", (wl.SEQ, 16), "float32")
    return wl.SectionSpec(
        name, _cfg(), ParallelConfig(),
        fn=lambda p, x: {"h": x["tokens"]}, params={},
        inputs={"tokens": wl.Field((wl.SEQ,), "int32")},
        emits=(port,), mode="fwd_only", **kw)


def _loss(consumes=(), name="crit", **kw):
    return wl.SectionSpec(
        name, _cfg(), ParallelConfig(),
        fn=lambda p, x: 0.0, params={},
        inputs={"tokens": wl.Field((wl.SEQ,), "int32")},
        consumes=tuple(consumes), loss=True, critical=True, **kw)


def _spec(*sections):
    return wl.WorkloadSpec("t", tuple(sections), seq_len=8,
                           global_batch=4, mbs=2)


# --------------------------------------------------------------------- #
# spec-compile validation
# --------------------------------------------------------------------- #
def test_valid_spec_passes():
    port = wl.Port("h", (wl.SEQ, 16), "float32")
    _spec(_producer(port=port),
          _loss(consumes=[wl.Consume("prod", port)])).validate()


def test_port_shape_mismatch_raises():
    emit = wl.Port("h", (wl.SEQ, 16), "float32")
    expect = wl.Port("h", (wl.SEQ, 32), "float32")
    with pytest.raises(ValueError, match="port type mismatch"):
        _spec(_producer(port=emit),
              _loss(consumes=[wl.Consume("prod", expect)])).validate()


def test_port_dtype_mismatch_raises():
    emit = wl.Port("h", (wl.SEQ, 16), "float32")
    expect = wl.Port("h", (wl.SEQ, 16), "bfloat16")
    with pytest.raises(ValueError, match="port type mismatch"):
        _spec(_producer(port=emit),
              _loss(consumes=[wl.Consume("prod", expect)])).validate()


def test_unknown_port_raises():
    with pytest.raises(ValueError, match="does not emit"):
        _spec(_producer(),
              _loss(consumes=[wl.Consume(
                  "prod", wl.Port("nope", (4,), "float32"))])).validate()


def test_unknown_section_raises():
    with pytest.raises(ValueError, match="unknown section"):
        _spec(_producer(),
              _loss(consumes=[wl.Consume(
                  "ghost", wl.Port("h", (wl.SEQ, 16),
                                   "float32"))])).validate()


def test_exactly_one_critical():
    with pytest.raises(ValueError, match="critical"):
        _spec(_producer()).validate()


def test_critical_with_activation_raises():
    with pytest.raises(ValueError, match="activation"):
        _spec(_producer(),
              _loss(activation=lambda b: b["flag"])).validate()


def test_fwd_only_loss_raises():
    bad = wl.SectionSpec(
        "crit", _cfg(), ParallelConfig(), fn=lambda p, x: 0.0, params={},
        loss=True, critical=True, mode="fwd_only")
    with pytest.raises(ValueError, match="fwd_bwd loss section"):
        _spec(_producer(), bad).validate()


def test_trainable_port_fanout_raises():
    """A trainable producer's port needs exactly one consumer so the bwd
    task knows where its cotangent comes from."""
    port = wl.Port("h", (wl.SEQ, 16), "float32")
    prod = wl.SectionSpec(
        "prod", _cfg(), ParallelConfig(),
        fn=lambda p, x: {"h": x["tokens"]}, params={},
        inputs={"tokens": wl.Field((wl.SEQ,), "int32")},
        emits=(port,), mode="fwd_bwd")
    with pytest.raises(ValueError, match="exactly one consumer"):
        _spec(prod, _loss()).validate()


def test_trainable_port_into_fwd_only_consumer_raises():
    """A fwd_only consumer can never return a cotangent — the producer's
    bwd task would deadlock waiting on it; must raise at spec-compile."""
    pa = wl.Port("a", (wl.SEQ, 16), "float32")
    pb = wl.Port("b", (wl.SEQ, 16), "float32")
    prod = wl.SectionSpec(
        "prod", _cfg(), ParallelConfig(),
        fn=lambda p, x: {"a": x["tokens"]}, params={},
        inputs={"tokens": wl.Field((wl.SEQ,), "int32")},
        emits=(pa,), mode="fwd_bwd")
    mid = wl.SectionSpec(
        "mid", _cfg(), ParallelConfig(),
        fn=lambda p, x: {"b": x["prod.a"]}, params={},
        emits=(pb,), consumes=(wl.Consume("prod", pa),),
        mode="fwd_only")
    with pytest.raises(ValueError, match="never return a cotangent"):
        _spec(prod, mid,
              _loss(consumes=[wl.Consume("mid", pb)])).validate()


def test_cycle_raises():
    pa = wl.Port("a", (4,), "float32")
    pb = wl.Port("b", (4,), "float32")
    s1 = wl.SectionSpec("s1", _cfg(), ParallelConfig(),
                        fn=lambda p, x: {"a": 0}, params={},
                        emits=(pa,), mode="fwd_only",
                        consumes=(wl.Consume("s2", pb),))
    s2 = wl.SectionSpec("s2", _cfg(), ParallelConfig(),
                        fn=lambda p, x: {"b": 0}, params={},
                        emits=(pb,), mode="fwd_only",
                        consumes=(wl.Consume("s1", pa),))
    with pytest.raises(ValueError, match="cycle"):
        _spec(s1, s2, _loss()).validate()


def test_to_graph_edges_and_seq_scale():
    port = wl.Port("h", (wl.SEQ, 16), "float32")
    prod = _producer(port=port, seq_len=32)
    spec = _spec(prod, _loss(consumes=[wl.Consume("prod", port)]))
    g = spec.to_graph()
    assert set(g.sections) == {"prod", "crit"}
    assert g.sections["prod"].seq_scale == 32 / 8
    (e,) = g.edges
    assert (e.src, e.dst) == ("prod", "crit")
    assert e.bytes_per_token == 16 * 4          # f32 hidden width


# --------------------------------------------------------------------- #
# consolidated per-section parallelism validation
# --------------------------------------------------------------------- #
def test_section_pp_rejected_with_section_and_axis():
    mesh = shd.abstract_mesh((1, 2, 1, 1),
                             ("data", "pipe", "seq", "model"))
    with pytest.raises(NotImplementedError,
                       match=r"section 'vit'.*pipe"):
        wl.validate_section_parallel("vit", _cfg(), ParallelConfig(pp=2),
                                     mesh)


def test_section_mesh_mismatch_names_section():
    mesh = shd.abstract_mesh((2, 1, 1, 1),
                             ("data", "pipe", "seq", "model"))
    with pytest.raises(ValueError, match=r"section 'vit'.*cp=2"):
        wl.validate_section_parallel("vit", _cfg(), ParallelConfig(cp=2),
                                     mesh)


def test_section_cp_on_attention_free_arch_names_section():
    ssm = get_reduced("mamba2-130m").replace(dtype="float32")
    mesh = shd.abstract_mesh((1, 1, 2, 1),
                             ("data", "pipe", "seq", "model"))
    with pytest.raises(NotImplementedError, match=r"section 'ssm'"):
        wl.validate_section_parallel("ssm", ssm, ParallelConfig(cp=2),
                                     mesh)


def test_section_cp_accepted():
    mesh = shd.abstract_mesh((1, 1, 2, 1),
                             ("data", "pipe", "seq", "model"))
    assert wl.validate_section_parallel(
        "vit", _cfg(), ParallelConfig(cp=2), mesh) == "cp"


# --------------------------------------------------------------------- #
# activation layouts (the host-side half of data-dependent activation)
# --------------------------------------------------------------------- #
def test_build_activation_identity_order():
    flags = np.array([1, 0, 0, 1, 1, 0, 0, 0], bool)
    act = wl.build_activation(list(range(8)), flags, 4)
    assert act.active_mbs == (0, 1)
    np.testing.assert_array_equal(act.idx[0][:2], [0, 3])
    np.testing.assert_array_equal(act.valid[0], [1, 1, 0, 0])
    np.testing.assert_array_equal(act.idx[1][:1], [0])
    np.testing.assert_array_equal(act.valid[1], [1, 0, 0, 0])


def test_build_activation_reorder_groups():
    """A grouping permutation turns 2 activated microbatches into 1."""
    flags = np.array([1, 0, 0, 0, 1, 0, 0, 0], bool)
    fifo = wl.build_activation(list(range(8)), flags, 4)
    assert fifo.active_mbs == (0, 1)
    grouped = wl.build_activation([0, 4, 1, 2, 3, 5, 6, 7], flags, 4)
    assert grouped.active_mbs == (0,)
    np.testing.assert_array_equal(grouped.idx[0][:2], [0, 1])
    np.testing.assert_array_equal(grouped.valid[0], [1, 1, 0, 0])


def test_build_activation_none_active():
    act = wl.build_activation(list(range(4)), np.zeros(4, bool), 2)
    assert act.active_mbs == ()
    assert not act.valid.any()


# --------------------------------------------------------------------- #
# streaming execution (single section, single device)
# --------------------------------------------------------------------- #
def test_streaming_lookahead_matches_serialized_trajectory():
    """Three iterations through install/submit_iteration/retire with
    lookahead=1 must be bitwise the trajectory train_iteration (the
    serialized wrapper) produces — the worker-side update and the
    removed barrier change scheduling only, never arithmetic."""
    import jax
    from repro.models.model import build_model

    cfg = _cfg()
    model = build_model(cfg, impl="ref")

    def lm_fn(p, x):
        return model.loss(p, {"tokens": x["tokens"],
                              "labels": x["labels"]})[0]

    sec = wl.SectionSpec(
        "lm", cfg, ParallelConfig(), fn=lm_fn, params=model.specs(),
        inputs={"tokens": wl.Field((wl.SEQ,), "int32"),
                "labels": wl.Field((wl.SEQ,), "int32")},
        loss=True, critical=True)
    spec = wl.WorkloadSpec("lm-only", (sec,), seq_len=8,
                           global_batch=4, mbs=2)
    rng = np.random.default_rng(0)
    batches = [{"tokens": rng.integers(0, 64, (4, 8)).astype(np.int32),
                "labels": rng.integers(0, 64, (4, 8)).astype(np.int32)}
               for _ in range(3)]
    with wl.CompoundRuntime(spec, lookahead=1) as rt:
        # serialized reference trajectory (fresh opt state)
        p, o = rt.init(jax.random.PRNGKey(0))
        ref_losses = []
        for i, b in enumerate(batches):
            p, o, m = rt.train_iteration(p, o, b, i)
            ref_losses.append(np.asarray(m["loss"]))

        # streamed trajectory from the same init, two iterations in flight
        p2, o2 = rt.init(jax.random.PRNGKey(0))
        rt.install(p2, o2)
        max_inflight = 0
        for i, b in enumerate(batches):
            rt.submit_iteration(b, i)
            max_inflight = max(max_inflight, rt.in_flight)
        ms = rt.drain()
        assert max_inflight == 2 and rt.in_flight == 0
        p3, _ = rt.state()
        for i in range(3):
            np.testing.assert_array_equal(np.asarray(ms[i]["loss"]),
                                          ref_losses[i], err_msg=f"it {i}")
        for a, b in zip(jax.tree_util.tree_leaves(p["lm"]),
                        jax.tree_util.tree_leaves(p3["lm"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_iteration_rejects_inflight_stream():
    """The serialized wrapper must refuse to interleave with an open
    stream (its retire would steal the streamed iteration's metrics)."""
    import jax
    from repro.models.model import build_model

    cfg = _cfg()
    model = build_model(cfg, impl="ref")

    def lm_fn(p, x):
        return model.loss(p, {"tokens": x["tokens"],
                              "labels": x["labels"]})[0]

    sec = wl.SectionSpec(
        "lm", cfg, ParallelConfig(), fn=lm_fn, params=model.specs(),
        inputs={"tokens": wl.Field((wl.SEQ,), "int32"),
                "labels": wl.Field((wl.SEQ,), "int32")},
        loss=True, critical=True)
    spec = wl.WorkloadSpec("lm-only", (sec,), seq_len=8,
                           global_batch=4, mbs=2)
    rng = np.random.default_rng(1)
    b = {"tokens": rng.integers(0, 64, (4, 8)).astype(np.int32),
         "labels": rng.integers(0, 64, (4, 8)).astype(np.int32)}
    with wl.CompoundRuntime(spec, lookahead=2) as rt:
        p, o = rt.init(jax.random.PRNGKey(0))
        rt.install(p, o)
        rt.submit_iteration(b, 0)
        with pytest.raises(RuntimeError, match="serialized wrapper"):
            rt.train_iteration(p, o, b, 1)
        with pytest.raises(RuntimeError, match="quiescent"):
            rt.install(p, o)
        (m,) = rt.drain()
        assert np.isfinite(np.asarray(m["loss"]))
