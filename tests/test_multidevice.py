"""Multi-device behaviour via subprocess drivers (each sets
xla_force_host_platform_device_count before importing jax — the main test
process must keep seeing 1 device)."""
import os
import subprocess
import sys
from pathlib import Path


DRIVERS = Path(__file__).parent / "drivers"


def _run(name, timeout=560):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(DRIVERS / name)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-3000:])
    assert "DRIVER_OK" in proc.stdout, proc.stdout[-2000:]


def test_message_queue_m_to_n():
    _run("driver_messages.py")


def test_disaggregated_distill_runtime():
    _run("driver_distill_runtime.py")


def test_disaggregated_mllm_runtime():
    _run("driver_mllm_runtime.py")


def test_pipeline_and_context_parallelism():
    _run("driver_pipeline_cp.py")


def test_elastic_restore_and_tiny_dryrun():
    _run("driver_elastic_dryrun.py")


def test_gradient_compression_8way():
    _run("driver_compression.py")
