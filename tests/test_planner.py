"""Two-stage planner (§3.2): constraint satisfaction, fanout equation,
memory bounds, and the Fig.9 teacher-mbs calibration."""

from repro.configs import get_config
from repro.core import cost_model as cmdl
from repro.core.graph import build_distill_graph, build_vlm_graph
from repro.core.planner import (candidate_parallelisms, plan, plan_critical)
from repro.core.types import ParallelConfig, V5E
from repro.models.vlm import vit_config


def test_candidates_respect_divisibility():
    cfg = get_config("granite-3-8b")       # 32 heads, 40 layers
    for c in candidate_parallelisms(cfg, 64):
        assert cfg.num_heads % c.tp == 0
        assert cfg.num_layers % c.pp == 0
        assert c.dp * c.tp * c.pp * c.cp == 64


def test_fig9_teacher_mbs_calibration():
    """Paper Fig. 9: teacher mbs 1→4 gives ≈2.6× throughput at ~flat
    memory."""
    cfg = get_config("granite-3-8b")
    p1 = ParallelConfig(dp=1, tp=8, mbs=1)
    p4 = ParallelConfig(dp=1, tp=8, mbs=4)
    t1 = cmdl.microbatch_time(cfg, p1, 4096, forward_only=True)
    t4 = cmdl.microbatch_time(cfg, p4, 4096, forward_only=True)
    thr_ratio = (4 / t4) / (1 / t1)
    assert 2.3 < thr_ratio < 2.9, thr_ratio
    m1 = cmdl.memory_per_gpu(cfg, p1, 4096, trainable=False)
    m4 = cmdl.memory_per_gpu(cfg, p4, 4096, trainable=False)
    assert m4 / m1 < 1.3          # "peak memory remains nearly flat"


def test_memory_model_orders():
    cfg = get_config("granite-3-8b")
    train = cmdl.memory_per_gpu(cfg, ParallelConfig(tp=8), 4096,
                                trainable=True)
    frozen = cmdl.memory_per_gpu(cfg, ParallelConfig(tp=8), 4096,
                                 trainable=False)
    assert frozen < train / 2      # teacher ≪ student memory (§2.2)


def test_stage1_fits_memory():
    sec_plan = plan_critical(
        __import__("repro.core.types", fromlist=["SectionConfig"])
        .SectionConfig("s", get_config("granite-3-8b"), ParallelConfig(),
                       critical=True),
        256, 4096, 256)
    assert sec_plan.mem_per_gpu <= V5E.hbm_bytes * 0.9
    assert sec_plan.parallel.devices == 256 // sec_plan.parallel.dp * \
        sec_plan.parallel.dp // 1 or True
    assert 256 % sec_plan.parallel.dp == 0


def test_self_distill_plan_overlaps():
    """Self-distillation: frozen same-arch teacher overlaps with fewer
    GPUs (paper §2.2)."""
    cfg = get_config("granite-3-8b")
    g = build_distill_graph(cfg, cfg)
    p = plan(g, critical_gpus=256, seq_len=4096, global_batch=256)
    t = p.sections["teacher"]
    s = p.sections["student"]
    assert not t.stalls_critical
    assert t.n_gpus < s.n_gpus            # fewer resources, still overlaps
    assert t.parallel.dp * t.fanout == s.parallel.dp   # eq. (1)
    assert t.t_iter <= s.t_iter + 1e-9


def test_vlm_plan_small_vit_overlaps():
    vit = vit_config(out_dim=5120)
    g = build_vlm_graph(vit, get_config("qwen2.5-32b"))
    p = plan(g, critical_gpus=256, seq_len=4096, global_batch=256,
             activation_rates={"vit": 0.3})
    v = p.sections["vit"]
    assert not v.stalls_critical
    assert v.n_gpus <= 32                  # ≈ the paper's ~12.5% envelope
    assert v.parallel.dp * v.fanout == p.sections["llm"].parallel.dp


def test_infeasible_overlap_flags_stall():
    """When the GPU cap genuinely cannot hide the teacher, the planner
    must say so rather than pretend (best-effort plan + stall flag)."""
    from repro.core.planner import plan_auxiliary
    from repro.core.types import SectionConfig
    g = build_distill_graph(get_config("qwen2.5-32b"),
                            get_config("granite-3-8b"))
    crit = plan_critical(g.sections["student"], 128, 4096, 256)
    aux = plan_auxiliary(g.sections["teacher"], crit, 4096, 256,
                         is_producer=True, gpu_cap=16)
    assert aux.stalls_critical
    assert aux.n_gpus <= 16
    # and with a generous cap the same teacher overlaps cleanly
    aux2 = plan_auxiliary(g.sections["teacher"], crit, 4096, 256,
                          is_producer=True, gpu_cap=512)
    assert not aux2.stalls_critical


def test_flops_per_token_tracks_6nd():
    cfg = get_config("granite-3-8b")
    f = cmdl.flops_per_token_fwd(cfg, 4096)
    assert f > 2 * cfg.active_params()            # fwd ≥ 2N
    assert f < 2 * cfg.active_params() * 1.5      # attention overhead < 50%
