"""Gradient-compression primitives (single-device semantics) and the
donated-state guard.  Multi-device behavior — compressed psum vs exact
psum under shard_map, EF across steps, trajectory tolerance — lives in
tests/drivers/driver_compression.py (subprocess, 8 virtual devices)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import sharding as shd
from repro.optim import adamw, compression as gcomp


def _one_device_mesh():
    return jax.make_mesh((1,), ("data",))


def _run_axis(fn, *args):
    """Run fn(*args) inside a shard_map over a size-1 'data' axis."""
    from jax.sharding import PartitionSpec as P
    mesh = _one_device_mesh()
    specs = tuple(P() for _ in args)
    return shd.shard_map(fn, mesh, specs, P())(*args)


def test_compressed_psum_n1_is_local_roundtrip():
    x = jnp.linspace(-3.0, 3.0, 101, dtype=jnp.float32)
    bf = _run_axis(lambda v: gcomp.compressed_psum_bf16(v, "data"), x)
    assert np.allclose(np.asarray(bf),
                       np.asarray(x.astype(jnp.bfloat16), np.float32))
    q = _run_axis(lambda v: gcomp.compressed_psum_int8(v, "data"), x)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert np.max(np.abs(np.asarray(q) - np.asarray(x))) <= 0.5 * scale + 1e-7


def test_int8_quant_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(257,)), jnp.float32)
    q, scale = gcomp._quant_int8(x)
    err = np.abs(np.asarray(gcomp._dequant_int8(q, scale)) - np.asarray(x))
    assert err.max() <= 0.5 * float(scale) + 1e-7


def test_ef_residual_is_exact_quant_error_and_reenters():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(33,)), jnp.float32)}

    def step(grads, ef):
        return gcomp.ef_compress_tree(grads, ef, "data", "int8")

    red, ef1 = _run_axis(step, g, gcomp.ef_init(g))
    # n=1: reduced + residual reconstructs the input exactly (f32 math)
    recon = np.asarray(red["w"]) + np.asarray(ef1.residual["w"])
    assert np.allclose(recon, np.asarray(g["w"]), atol=1e-6)
    assert float(jnp.max(jnp.abs(ef1.residual["w"]))) > 0

    # the residual enters the NEXT step's gradient before compression:
    # feeding zero grads + ef1 must emit (approximately) the residual
    z = {"w": jnp.zeros_like(g["w"])}
    red2, _ = _run_axis(step, z, ef1)
    scale2 = float(jnp.max(jnp.abs(ef1.residual["w"]))) / 127.0
    assert np.max(np.abs(np.asarray(red2["w"])
                         - np.asarray(ef1.residual["w"]))) <= 0.5 * scale2 + 1e-7


def test_ef_none_method_is_exact_with_zero_residual():
    g = {"w": jnp.arange(8, dtype=jnp.float32)}
    red, ef = _run_axis(
        lambda gr, e: gcomp.ef_compress_tree(gr, e, "data", "none"),
        g, gcomp.ef_init(g))
    assert np.allclose(np.asarray(red["w"]), np.asarray(g["w"]))
    assert float(jnp.max(jnp.abs(ef.residual["w"]))) == 0.0


def test_unknown_method_rejected():
    g = {"w": jnp.zeros((4,), jnp.float32)}
    with pytest.raises(ValueError, match="fp4"):
        _run_axis(
            lambda gr, e: gcomp.ef_compress_tree(gr, e, "data", "fp4"),
            g, gcomp.ef_init(g))


def test_wire_bytes_payload_ratios():
    tree = {"a": jnp.zeros((16, 8), jnp.float32),
            "b": jnp.zeros((100,), jnp.float32)}
    n = 16 * 8 + 100
    assert gcomp.wire_bytes(tree, "none") == 4 * n
    assert gcomp.wire_bytes(tree, "bf16") == 2 * n
    assert gcomp.wire_bytes(tree, "int8") == n


def _tiny_state():
    params = {"w": jnp.ones((4,), jnp.float32)}
    return params, adamw.init(params)


def test_adamw_update_rejects_donated_state():
    params, state = _tiny_state()
    for leaf in jax.tree_util.tree_leaves(state):
        leaf.delete()
    with pytest.raises(adamw.DonatedStateError, match="donated"):
        adamw.update({"w": jnp.zeros((4,), jnp.float32)}, state,
                     jnp.float32(1e-3))


def test_check_live_passes_on_live_and_abstract_trees():
    params, state = _tiny_state()
    adamw.check_live(params)
    adamw.check_live(state)
    # ShapeDtypeStructs / tracers have no is_deleted — must be ignored
    adamw.check_live({"w": jax.ShapeDtypeStruct((4,), jnp.float32)})
