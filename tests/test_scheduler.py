"""Wavefront scheduler (Algorithm 1) + timeline simulator properties,
including the paper's Figure-7 worked example and hypothesis-based
invariants."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:     # tier-1 must collect without hypothesis installed
    HAVE_HYPOTHESIS = False

from repro.core.scheduler import (merge_fanout_schedules,
                                  partition_global_batch,
                                  schedule_global_batch,
                                  wavefront_schedule)
from repro.core.simulator import Sample, simulate, simulate_fanout


def vis(i, f, b, fc=1.0, bc=2.0):
    return Sample(i, f, fc, 0.0, 0.0, bc, b)


def txt(i, fc=1.0, bc=2.0):
    return Sample(i, 0.0, fc, 0.0, 0.0, bc, 0.0)


# --------------------------------------------------------------------------- #
# Simulator semantics
# --------------------------------------------------------------------------- #
def test_single_sample_is_serial_chain():
    s = Sample(0, 1.0, 2.0, 0.5, 0.25, 3.0, 0.75)
    res = simulate([s])
    assert res.makespan == pytest.approx(sum(s.tuple6))


def test_zero_phases_cost_nothing():
    res = simulate([txt(0), txt(1)])
    assert res.makespan == pytest.approx(2 * 3.0)   # 2 × (f_c + b_c)
    assert res.critical_utilization == pytest.approx(1.0)


def test_critical_lower_bound():
    samples = [vis(0, 0.5, 1.0), txt(1), vis(2, 2.0, 0.1), txt(3)]
    res = simulate(samples)
    lower = sum(s.t_f_c + s.t_b_c for s in samples)
    assert res.makespan >= lower - 1e-9


def test_dependencies_respected():
    # one huge-BC sample alone: critical must wait for it
    s = vis(0, 5.0, 1.0, fc=1.0, bc=1.0)
    res = simulate([s], collect_timeline=True)
    f_bc_end = [e for e in res.timeline if e[2] == "f_bc"][0][4]
    f_c_start = [e for e in res.timeline if e[2] == "f_c"][0][3]
    assert f_c_start >= f_bc_end - 1e-9


# --------------------------------------------------------------------------- #
# Figure 7: LLM section fully saturated, ViT hidden, 100% rel. efficiency
# --------------------------------------------------------------------------- #
def test_paper_figure7_example():
    samples = [vis(0, 0.1, 0.2), txt(1), txt(2), vis(3, 0.2, 0.4),
               txt(4), txt(5), vis(6, 0.15, 0.3), txt(7), txt(8),
               vis(9, 0.25, 0.5), txt(10), txt(11)]
    per_rank, merged = schedule_global_batch(samples, 4)
    res = simulate_fanout(per_rank)
    text_only_bound = 3 * 3.0          # 3 samples × (1 fwd + 2 bwd)
    assert res.makespan == pytest.approx(text_only_bound)
    assert res.critical_utilization == pytest.approx(1.0)
    # merged producer schedule is a round-robin over ranks
    assert len(merged) == 12


def test_wavefront_beats_fifo_when_vision_heavy():
    # all-vision-first FIFO stalls the critical section
    samples = [vis(0, 3.0, 3.0), vis(1, 3.0, 3.0), txt(2), txt(3), txt(4),
               txt(5)]
    sch = wavefront_schedule(samples)
    assert sch.makespan <= sch.fifo_makespan + 1e-9
    assert sch.sim.critical_idle <= simulate(samples).critical_idle + 1e-9


# --------------------------------------------------------------------------- #
# Algorithm-1 invariants (hypothesis)
# --------------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:
    sample_strategy = st.builds(
        lambda i, f, fc, bc, b: Sample(i, f, fc, 0.0, 0.0, bc, b),
        st.integers(0, 10_000),
        st.floats(0.0, 5.0, allow_nan=False),
        st.floats(0.1, 5.0, allow_nan=False),
        st.floats(0.1, 5.0, allow_nan=False),
        st.floats(0.0, 5.0, allow_nan=False))

    @settings(max_examples=20, deadline=None)
    @given(st.lists(sample_strategy, min_size=1, max_size=7))
    def test_schedule_is_permutation_and_no_worse_than_fifo(samples):
        sch = wavefront_schedule(samples)
        assert sorted(s.idx for s in sch.order) == sorted(s.idx for s in
                                                          samples)
        assert sch.makespan <= sch.fifo_makespan + 1e-9
        lower = sum(s.t_f_c + s.t_b_c for s in samples)
        assert sch.makespan >= lower - 1e-9

    @settings(max_examples=20, deadline=None)
    @given(st.lists(sample_strategy, min_size=8, max_size=16).map(
        lambda l: l[:len(l) // 4 * 4]), st.just(4))
    def test_partition_balances_with_equal_counts(samples, dp):
        ranks = partition_global_batch(samples, dp)
        assert all(len(r) == len(samples) // dp for r in ranks)
        assert sorted(s.idx for r in ranks for s in r) == sorted(
            s.idx for s in samples)
        loads = [sum(s.t_f_bc + s.t_b_ac for s in r) for r in ranks]
        # greedy LPT: max/min spread bounded by the largest single item
        biggest = max((s.t_f_bc + s.t_b_ac) for s in samples)
        assert max(loads) - min(loads) <= biggest + 1e-9
else:
    def test_schedule_is_permutation_and_no_worse_than_fifo():
        pytest.importorskip("hypothesis")

    def test_partition_balances_with_equal_counts():
        pytest.importorskip("hypothesis")


# --------------------------------------------------------------------------- #
# partition / fanout-merge (deterministic coverage)
# --------------------------------------------------------------------------- #
def test_partition_asserts_when_dp_does_not_divide():
    with pytest.raises(AssertionError):
        partition_global_batch([txt(0), txt(1), txt(2)], 2)


def test_partition_empty_input():
    ranks = partition_global_batch([], 4)
    assert ranks == [[], [], [], []]
    assert merge_fanout_schedules(ranks) == []


def test_partition_equal_counts_and_exact_cover():
    samples = [vis(i, 0.1 * i, 0.2 * i) if i % 3 == 0 else txt(i)
               for i in range(12)]
    ranks = partition_global_batch(samples, 3)
    assert [len(r) for r in ranks] == [4, 4, 4]
    assert sorted(s.idx for r in ranks for s in r) == list(range(12))


def test_merge_uneven_rank_lengths():
    a = [txt(0), txt(1), txt(2)]
    b = [txt(10)]
    merged = merge_fanout_schedules([a, b])
    assert [(r, s.idx) for r, s in merged] == \
        [(0, 0), (1, 10), (0, 1), (0, 2)]


def test_merge_round_robin_order():
    a = [txt(0), txt(1)]
    b = [txt(10), txt(11)]
    merged = merge_fanout_schedules([a, b])
    assert [(r, s.idx) for r, s in merged] == [(0, 0), (1, 10), (0, 1),
                                               (1, 11)]


def test_scheduling_overhead_is_small():
    """§3.4: scheduling must be overlappable with GPU execution."""
    samples = [vis(i, 0.1 * (i % 3), 0.1) if i % 3 == 0 else txt(i)
               for i in range(32)]
    sch = wavefront_schedule(samples)
    assert sch.elapsed_s < 5.0
