"""Checkpointing: roundtrip, atomic commit, retention, resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {"params": {"w": jax.random.normal(k[0], (8, 4)),
                       "b": jax.random.normal(k[1], (4,))},
            "opt": {"mu": jax.random.normal(k[2], (8, 4))}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    tree = _tree()
    ck.save(10, tree)
    got = ck.restore(10, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep_last_n=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    assert ck.all_steps() == [3, 4]
    assert ck.latest_step() == 4


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(tmp_path, async_save=True)
    ck.save(5, _tree())
    ck.wait()
    assert ck.latest_step() == 5


def test_atomic_no_partial_visible(tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(1, _tree())
    # a leftover tmp dir must be invisible to discovery
    (tmp_path / "step_00000002.tmp").mkdir()
    assert ck.all_steps() == [1]


def test_restore_casts_dtype(tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    tree = {"w": jnp.ones((4,), jnp.float32)}
    ck.save(1, tree)
    target = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    got = ck.restore(1, target)
    assert got["w"].dtype == jnp.bfloat16


def test_resume_at_num_steps_writes_no_spurious_checkpoint(tmp_path):
    """Regression: the final-save in train()'s ``finally`` used to write
    ``step + 1`` even when zero steps ran, so resuming a finished run
    (latest == num_steps) left a spurious ``num_steps + 1`` artifact."""
    from repro.train.loop import train

    params = {"w": jnp.ones((2,), jnp.float32)}
    opt = {"mu": jnp.zeros((2,), jnp.float32)}

    def step_fn(p, o, batch, i):
        return p, o, {"loss": jnp.float32(1.0)}

    def batches():
        while True:
            yield {}

    ck = Checkpointer(tmp_path, async_save=False)
    res = train(step_fn, params=params, opt_state=opt, batches=batches(),
                num_steps=3, checkpointer=ck, checkpoint_every=100,
                log_fn=lambda s: None)
    assert res.steps_run == 3 and res.final_step == 3
    assert ck.latest_step() == 3

    res2 = train(step_fn, params=params, opt_state=opt, batches=batches(),
                 num_steps=3, checkpointer=ck, checkpoint_every=100,
                 log_fn=lambda s: None)
    assert res2.resumed_from == 3
    assert res2.steps_run == 0
    assert res2.final_step == 3           # not num_steps + 1
    assert ck.all_steps() == [3], "no spurious num_steps+1 checkpoint"


def test_missing_leaf_raises(tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(KeyError):
        ck.restore(1, {"a": jnp.zeros(2), "b": jnp.zeros(2)})


def test_train_loop_resumes(tmp_path):
    """Integration: loop saves, a fresh loop resumes at the right step."""
    from repro.optim import adamw
    from repro.train.loop import train

    def make_step():
        def step(params, opt, batch, idx):
            grads = {"w": params["w"] - batch}
            p, o, gn = adamw.update(grads, opt, jnp.float32(0.1),
                                    adamw.AdamWConfig(weight_decay=0.0))
            return p, o, {"loss": jnp.sum(grads["w"] ** 2),
                          "grad_norm": gn}
        return step

    def batches():
        while True:
            yield jnp.asarray([1.0, 2.0])

    params = {"w": jnp.zeros(2)}
    opt = adamw.init(params)
    ck = Checkpointer(tmp_path, async_save=False)
    r1 = train(make_step(), params=params, opt_state=opt,
               batches=batches(), num_steps=5, checkpointer=ck,
               checkpoint_every=2, log_every=100, log_fn=lambda s: None)
    assert r1.final_step == 5
    # fresh state, same checkpointer -> resumes from step 5
    params2 = {"w": jnp.zeros(2)}
    opt2 = adamw.init(params2)
    r2 = train(make_step(), params=params2, opt_state=opt2,
               batches=batches(), num_steps=8, checkpointer=ck,
               checkpoint_every=100, log_every=100, log_fn=lambda s: None)
    assert r2.resumed_from == 5
    assert r2.steps_run == 3


def test_straggler_monitor():
    from repro.train.loop import StragglerMonitor
    mon = StragglerMonitor(factor=2.0)
    for _ in range(10):
        assert not mon.observe(0.1)
    assert mon.observe(0.5)
    assert mon.flagged == 1
