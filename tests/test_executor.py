"""Executed-schedule invariants of the compound executor (single device,
stub section programs — the multi-device MLLM/distill instantiations live
in tests/drivers/).

Covers the satellite checklist: realized completion order respects
cross-section dependencies; dispatch equals FIFO when wavefront
reordering is disabled; partition_global_batch / merge_fanout_schedules
compose with the executor under dp>1 fanout; SectionWorker failures stay
scoped to the failing task."""
import time

import pytest

import jax.numpy as jnp
import numpy as np

from repro.core.executor import (CompoundExecutor, Dispatch,
                                 chunk_microbatches, mark_start,
                                 order_global_batch, order_samples)
from repro.core.runtime import SectionWorker
from repro.core.simulator import Sample


def hetero_samples(n=8):
    """Alternating image/text mix (the MLLM regime): even samples carry
    bc (vision) work, odd samples skip it."""
    return [Sample(i, 0.4 if i % 2 == 0 else 0.0, 1.0, 0.0, 0.0, 2.0,
                   0.8 if i % 2 == 0 else 0.0) for i in range(n)]


# --------------------------------------------------------------------------- #
# Dispatch-order policies
# --------------------------------------------------------------------------- #
def test_order_samples_fifo_is_identity():
    order, sched = order_samples(hetero_samples(), reorder=False)
    assert order == list(range(8))
    assert sched is None


def test_order_samples_wavefront_reorders_and_never_loses():
    s = hetero_samples()
    order, sched = order_samples(s, reorder=True)
    assert sorted(order) == list(range(8))
    assert sched is not None
    assert sched.makespan <= sched.fifo_makespan
    assert order != list(range(8)), \
        "a heterogeneous batch must actually be reordered"


def test_sample_tuples_transitive_upstream():
    """A depth-2 producer chain (adapter → vit → critical) must phase BOTH
    producers as before-critical (bc), not flip the transitive one into
    the after-critical phases."""
    from repro.core.cost_model import sample_tuples
    from repro.core.graph import SectionGraph
    from repro.core.types import ArchConfig, ParallelConfig, SectionConfig

    arch = ArchConfig("t", "dense", 2, 64, 4, 4, 128, 128)
    g = SectionGraph()
    g.add(SectionConfig("adapter", arch, ParallelConfig()))
    g.add(SectionConfig("vit", arch, ParallelConfig()))
    g.add(SectionConfig("llm", arch, ParallelConfig(), critical=True))
    g.connect("adapter", "vit")
    g.connect("vit", "llm")
    g.validate()
    s_on = sample_tuples(g, {"adapter": [True], "vit": [True]}, 64, n=1)[0]
    assert s_on.t_f_ac == 0.0 and s_on.t_b_bc == 0.0
    assert s_on.t_f_bc > 0.0 and s_on.t_b_ac > 0.0
    # adapter alone still lands in bc
    s_ad = sample_tuples(g, {"adapter": [True], "vit": [False]}, 64,
                         n=1)[0]
    assert s_ad.t_f_bc > 0.0 and s_ad.t_f_ac == 0.0
    assert s_ad.t_f_bc < s_on.t_f_bc


def test_chunk_microbatches_contiguous():
    assert chunk_microbatches([3, 1, 0, 2], 2) == [[3, 1], [0, 2]]
    with pytest.raises(AssertionError):
        chunk_microbatches([0, 1, 2], 2)


# --------------------------------------------------------------------------- #
# Realized execution invariants
# --------------------------------------------------------------------------- #
def _producer_consumer_dispatches(ex, order, it="t"):
    q = ex.queue
    disp = []
    for i in order:
        def produce(i=i):
            v = jnp.full((2,), i, jnp.float32)
            q.push("bc", "c", f"{it}/x{i}", v)
            return int(i)
        disp.append(Dispatch("bc", f"p{i}", produce))
    for i in order:
        def consume(i=i):
            v = q.pull("bc", "c", f"{it}/x{i}", timeout=30.0)
            return float(np.asarray(v)[0])
        disp.append(Dispatch("c", f"c{i}", consume))
    return disp


def test_completion_order_respects_cross_section_dependencies():
    with CompoundExecutor(sections=["bc", "c"]) as ex:
        order, _ = order_samples(hetero_samples(), reorder=True)
        res = ex.run(_producer_consumer_dispatches(ex, order))
        ends = {(e.section, e.tag): e.end for e in res.timeline}
        for i in order:
            # the consumer of sample i can only complete after its
            # producer completed (the queue pull is the dependency)
            assert ends[("bc", f"p{i}")] <= ends[("c", f"c{i}")]
        # FIFO workers: realized critical completion order == its
        # dispatch order == the wavefront schedule order
        c_order = [t for s, t in res.completion_order if s == "c"]
        assert c_order == [f"c{i}" for i in order]
        for i in order:
            assert res.results[("c", f"c{i}")] == float(i)
        assert res.makespan > 0.0
        assert 0.0 < res.utilization("c") <= 1.0


def test_fifo_mode_realizes_incoming_order():
    with CompoundExecutor(sections=["bc", "c"]) as ex:
        order, sched = order_samples(hetero_samples(), reorder=False)
        assert sched is None
        res = ex.run(_producer_consumer_dispatches(ex, order, it="f"))
        c_order = [t for s, t in res.completion_order if s == "c"]
        assert c_order == [f"c{i}" for i in range(8)]
        assert res.dispatch_order["c"] == [f"c{i}" for i in range(8)]


def test_fanout_composition_with_executor():
    """partition_global_batch → per-rank Algorithm 1 →
    merge_fanout_schedules, executed: one producer section feeds two
    consumer ranks; realized completion respects every dependency and
    each rank consumes exactly its partition in schedule order."""
    s = hetero_samples(8)
    ranks, merged = order_global_batch(s, dp=2, reorder=True)
    assert sorted(ranks[0] + ranks[1]) == list(range(8))
    assert len(ranks[0]) == len(ranks[1]) == 4       # SPMD-equal counts
    assert sorted(merged) == sorted(
        (r, i) for r in range(2) for i in ranks[r])

    with CompoundExecutor(sections=["vit", "c0", "c1"]) as ex:
        q = ex.queue
        disp = []
        for r, i in merged:
            def produce(r=r, i=i):
                q.push("vit", f"c{r}", f"s{i}",
                       jnp.full((2,), i, jnp.float32))
                return i
            disp.append(Dispatch("vit", f"p{r}.{i}", produce))
        for r in range(2):
            for i in ranks[r]:
                def consume(r=r, i=i):
                    v = q.pull("vit", f"c{r}", f"s{i}", timeout=30.0)
                    return float(np.asarray(v)[0])
                disp.append(Dispatch(f"c{r}", f"c{i}", consume))
        res = ex.run(disp)
        ends = {(e.section, e.tag): e.end for e in res.timeline}
        for r, i in merged:
            assert ends[("vit", f"p{r}.{i}")] <= ends[(f"c{r}", f"c{i}")]
        for r in range(2):
            got = [res.results[(f"c{r}", f"c{i}")] for i in ranks[r]]
            assert got == [float(i) for i in ranks[r]]


def test_utilization_excludes_marked_stalls():
    """A consumer stalling in a blocking pull must read as section IDLE
    (mark_start re-stamps the busy window), otherwise realized
    utilization is ~1.0 no matter how badly the schedule stalls."""
    with CompoundExecutor(sections=["bc", "c"]) as ex:
        q = ex.queue

        def slow_produce():
            time.sleep(0.15)
            q.push("bc", "c", "x", jnp.ones((2,)))
            return True

        def stalled_consume():
            v = q.pull("bc", "c", "x", timeout=10.0)
            mark_start()
            time.sleep(0.02)
            return float(np.asarray(v)[0])

        res = ex.run([Dispatch("bc", "p", slow_produce),
                      Dispatch("c", "c0", lambda: 1),
                      Dispatch("c", "c1", stalled_consume)])
        assert res.utilization("c") < 0.7
        ev = {e.tag: e for e in res.section_events("c")}
        assert ev["c1"].start >= 0.1    # start re-stamped after the pull


def test_fanout_composition_fifo_mode():
    s = hetero_samples(8)
    ranks, merged = order_global_batch(s, dp=2, reorder=False)
    assert ranks == [[0, 1, 2, 3], [4, 5, 6, 7]]
    # round-robin merged producer order over contiguous rank partitions
    assert merged == [(0, 0), (1, 4), (0, 1), (1, 5), (0, 2), (1, 6),
                      (0, 3), (1, 7)]


# --------------------------------------------------------------------------- #
# Worker failure scoping (satellite)
# --------------------------------------------------------------------------- #
def test_worker_error_scoped_to_failing_task():
    w = SectionWorker("s")
    try:
        w.submit("bad", lambda: 1 / 0)
        w.submit("good", lambda: 42)
        with pytest.raises(RuntimeError, match=r"task 'bad'"):
            w.drain(1)
        # a later drain is NOT poisoned by the earlier failure
        assert w.drain(1) == {"good": 42}
    finally:
        w.stop()


def test_executor_raises_on_failing_dispatch():
    with CompoundExecutor(sections=["a"]) as ex:
        with pytest.raises(RuntimeError, match=r"boom"):
            ex.run([Dispatch("a", "boom",
                             lambda: (_ for _ in ()).throw(
                                 ValueError("inner"))),
                    Dispatch("a", "later", lambda: 1)])
        # 'later' completed after the failed drain; its stale result must
        # not satisfy (or pollute) the next run's drain
        res = ex.run([Dispatch("a", "ok", lambda: 7)])
        assert res.results == {("a", "ok"): 7}


def test_stale_task_error_after_abort_is_logged(caplog):
    """Satellite fix: a poisoned task landing after its iteration already
    aborted used to vanish without a trace — it must be logged."""
    import logging

    with CompoundExecutor(sections=["a"]) as ex:
        s = ex.session()

        def late_failure():
            time.sleep(0.2)
            raise ValueError("late-inner")

        with caplog.at_level(logging.WARNING, logger="repro.executor"):
            s.submit(0, [Dispatch("a", "boom",
                                  lambda: (_ for _ in ()).throw(
                                      ValueError("inner"))),
                         Dispatch("a", "late", late_failure)])
            with pytest.raises(RuntimeError, match=r"'boom'"):
                s.retire(0)
            deadline = time.monotonic() + 5.0
            while time.monotonic() < deadline and not caplog.records:
                time.sleep(0.01)
        assert any("stale TaskError" in r.getMessage()
                   and "late-inner" in r.getMessage()
                   for r in caplog.records)


# --------------------------------------------------------------------------- #
# Cross-iteration streaming (tentpole)
# --------------------------------------------------------------------------- #
def test_stream_overlap_invariants():
    """With lookahead, section A's fwd(i+1) may start before section B's
    upd(i) ends — but never before A's OWN upd(i) (per-section FIFO)."""
    with CompoundExecutor(sections=["a", "b"]) as ex:
        s = ex.session()

        def work(dt):
            def fn():
                time.sleep(dt)
                return dt
            return fn

        def iteration(i):
            return [Dispatch("a", "fwd", work(0.01)),
                    Dispatch("a", "upd", work(0.01)),
                    Dispatch("b", "fwd", work(0.01)),
                    Dispatch("b", "upd", work(0.3))]   # slow straggler

        s.submit(0, iteration(0))
        s.submit(1, iteration(1))
        assert s.in_flight == 2
        r0 = s.retire(0)
        r1 = s.retire(1)
        assert s.in_flight == 0

        def abs_times(res, section, tag):
            (e,) = [e for e in res.timeline
                    if e.section == section and e.tag == tag]
            return res.t0 + e.start, res.t0 + e.end

        a_upd0_end = abs_times(r0, "a", "upd")[1]
        b_upd0_end = abs_times(r0, "b", "upd")[1]
        a_fwd1_start = abs_times(r1, "a", "fwd")[0]
        # A streams into iteration 1 behind its own update...
        assert a_fwd1_start >= a_upd0_end
        # ...without waiting for B's straggling update (the old barrier)
        assert a_fwd1_start < b_upd0_end


def test_stream_serialized_depth_matches_run_completion_order():
    """Submit-then-retire one iteration at a time (lookahead depth 0)
    must realize exactly the per-section completion order of the old
    barriered CompoundExecutor.run on the same dispatch list."""
    order, _ = order_samples(hetero_samples(), reorder=True)

    def per_section(res):
        return {s: [t for sec, t in res.completion_order if sec == s]
                for s in ("bc", "c")}

    with CompoundExecutor(sections=["bc", "c"]) as ex:
        barriered = per_section(
            ex.run(_producer_consumer_dispatches(ex, order, it="r")))
    with CompoundExecutor(sections=["bc", "c"]) as ex:
        s = ex.session()
        s.submit(0, _producer_consumer_dispatches(ex, order, it="s"))
        res = s.retire(0)
        assert per_section(res) == barriered
        for i in order:
            assert res.results[("c", f"c{i}")] == float(i)


def test_stream_iteration_indices_must_increase():
    with CompoundExecutor(sections=["a"]) as ex:
        s = ex.session()
        s.submit(3, [Dispatch("a", "t", lambda: 1)])
        with pytest.raises(AssertionError, match=r"strictly increasing"):
            s.submit(3, [Dispatch("a", "t", lambda: 1)])
        s.retire(3)
