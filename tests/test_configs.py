"""The 10 assigned architectures carry their exact published configs."""
import pytest

import repro.configs as cfgs

EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
    "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
    "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    "whisper-small": (12, 768, 12, 12, 3072, 51865),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
}


@pytest.mark.parametrize("name", list(EXPECTED))
def test_exact_numbers(name):
    cfg = cfgs.get_config(name)
    L, d, h, kv, ff, v = EXPECTED[name]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_family_features():
    assert cfgs.get_config("mixtral-8x22b").num_experts == 8
    assert cfgs.get_config("mixtral-8x22b").experts_per_token == 2
    assert cfgs.get_config("mixtral-8x22b").sliding_window > 0
    assert cfgs.get_config("moonshot-v1-16b-a3b").num_experts == 64
    assert cfgs.get_config("moonshot-v1-16b-a3b").experts_per_token == 6
    assert cfgs.get_config("mamba2-130m").ssm_state == 128
    assert cfgs.get_config("qwen1.5-0.5b").qkv_bias
    assert cfgs.get_config("qwen2.5-32b").qkv_bias
    assert cfgs.get_config("whisper-small").encoder_layers == 12
    j = cfgs.get_config("jamba-v0.1-52b")
    assert j.attn_period == 8 and j.num_experts == 16


def test_layer_interleave_jamba():
    cfg = cfgs.get_config("jamba-v0.1-52b")
    attn_layers = [i for i in range(cfg.num_layers) if cfg.is_attn_layer(i)]
    assert len(attn_layers) == cfg.num_layers // 8        # 1:7 ratio
    moe_layers = [i for i in range(cfg.num_layers) if cfg.is_moe_layer(i)]
    assert len(moe_layers) == cfg.num_layers // 2         # every 2nd


def test_param_counts_in_expected_range():
    """Analytic total_params should land near each model's nameplate —
    except where the ASSIGNED hyperparameters deviate from the published
    model (moonshot: the assigned 48L × 64e gives ~28B, not the 16B
    nameplate of 27L Moonlight; the assignment numbers are the spec)."""
    expect = {"granite-20b": (15e9, 25e9), "qwen2.5-32b": (28e9, 37e9),
              "granite-3-8b": (7e9, 10e9), "mixtral-8x22b": (120e9, 150e9),
              "mamba2-130m": (0.10e9, 0.20e9),
              "moonshot-v1-16b-a3b": (25e9, 32e9),
              "pixtral-12b": (10e9, 14e9),
              "jamba-v0.1-52b": (45e9, 60e9)}
    for name, (lo, hi) in expect.items():
        n = cfgs.get_config(name).total_params()
        assert lo < n < hi, (name, n / 1e9)
    # MoE active-parameter counts match the -aXb naming
    assert cfgs.get_config("moonshot-v1-16b-a3b").active_params() < 5e9
    assert cfgs.get_config("mixtral-8x22b").active_params() < 45e9


def test_sub_quadratic_flags():
    for name in ["mamba2-130m", "jamba-v0.1-52b", "mixtral-8x22b"]:
        assert cfgs.get_config(name).sub_quadratic, name
    for name in ["granite-20b", "qwen2.5-32b", "pixtral-12b",
                 "whisper-small"]:
        assert not cfgs.get_config(name).sub_quadratic, name


def test_reduced_preserves_family():
    for name in cfgs.ARCH_NAMES:
        full, red = cfgs.get_config(name), cfgs.get_reduced(name)
        assert full.family == red.family
        assert (full.num_experts > 0) == (red.num_experts > 0)
        assert (full.attn_period > 0) == (red.attn_period > 0)
        assert (full.encoder_layers > 0) == (red.encoder_layers > 0)
        assert red.total_params() < 5e6, name
