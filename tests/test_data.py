"""Data pipeline: determinism, modality mixture, mask semantics."""
import numpy as np

from repro.data.synthetic import (lm_batches, sample_modalities,
                                  vlm_batches)


def test_lm_batches_deterministic():
    a = next(lm_batches(batch=4, seq_len=16, vocab=128, seed=7))
    b = next(lm_batches(batch=4, seq_len=16, vocab=128, seed=7))
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = next(lm_batches(batch=4, seq_len=16, vocab=128, seed=8))
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))


def test_labels_are_shifted_tokens():
    b = next(lm_batches(batch=2, seq_len=16, vocab=64, seed=0))
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_tokens_learnable_structure():
    """The markov generator must beat random chance for a bigram
    predictor — otherwise training-loss-decreases tests are meaningless."""
    b = next(lm_batches(batch=16, seq_len=256, vocab=64, seed=0))
    toks = np.asarray(b["tokens"])
    labs = np.asarray(b["labels"])
    # per-sequence: same current-token should frequently map to the same
    # next-token (deterministic map + 10% noise)
    hits = total = 0
    for r in range(toks.shape[0]):
        seen = {}
        for t, l in zip(toks[r], labs[r]):
            if t in seen:
                total += 1
                hits += int(seen[t] == l)
            seen[t] = l
    assert total > 100
    assert hits / total > 0.6, hits / total


def test_modality_mixture_ratio():
    rng = np.random.default_rng(0)
    samples = sample_modalities(rng, 4000, vision_ratio=0.25,
                                image_tokens=64)
    frac = sum(s.has_image for s in samples) / len(samples)
    assert 0.2 < frac < 0.3
    for s in samples:
        if s.has_image:
            assert s.vit_patches == 4 * s.image_tokens   # 4:1 downsample
        else:
            assert s.vit_patches == 0


def test_vlm_batch_semantics():
    it = vlm_batches(batch=8, seq_len=64, vocab=128, vision_ratio=0.5,
                     image_tokens=8, patch_dim=16, seed=0)
    b = next(it)
    has = np.asarray(b["has_image"]).astype(bool)
    valid = np.asarray(b["image_valid"])
    mask = np.asarray(b["loss_mask"])
    patches = np.asarray(b["patches"], np.float32)
    for i in range(8):
        assert valid[i].all() == has[i]
        if has[i]:
            assert mask[i, :8].sum() == 0        # no loss on image slots
            assert np.abs(patches[i]).sum() > 0
        else:
            assert mask[i].all()
            assert np.abs(patches[i]).sum() == 0
