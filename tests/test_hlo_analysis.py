"""Roofline HLO parser: trip-count multipliers, dot FLOPs, in-place
traffic modeling, collective accounting — validated on real compiled HLO."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import analyze_hlo, parse_hlo, roofline_terms


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_while_trip_count_multiplies_flops():
    n, L = 64, 9

    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    text = _compile_text(f, jax.ShapeDtypeStruct((n, n), jnp.float32))
    st = analyze_hlo(text)
    expect = L * 2 * n ** 3
    assert abs(st.flops - expect) / expect < 0.05, (st.flops, expect)
    assert L in st.while_trip_counts.values()


def test_single_dot_flops_exact():
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    text = _compile_text(lambda a, b: a @ b, a, b)
    st = analyze_hlo(text)
    assert st.flops == pytest.approx(2 * 32 * 64 * 16, rel=0.01)


def test_dus_counted_at_slice_size():
    """Scan carrying a big buffer and updating one row per step must not
    charge the full buffer per step."""
    big = 512

    def f(x):
        buf = jnp.zeros((big, big), jnp.float32)

        def body(buf, i):
            return jax.lax.dynamic_update_slice(
                buf, x[None] * i.astype(jnp.float32), (i, 0)), None
        buf, _ = jax.lax.scan(body, buf, jnp.arange(big))
        return buf

    text = _compile_text(f, jax.ShapeDtypeStruct((big,), jnp.float32))
    st = analyze_hlo(text)
    full_charge = big * (big * big * 4)       # what naive counting gives
    assert st.hbm_bytes < full_charge * 0.05, (st.hbm_bytes, full_charge)


def test_transcendental_counted():
    text = _compile_text(lambda x: jnp.tanh(x),
                         jax.ShapeDtypeStruct((128,), jnp.float32))
    st = analyze_hlo(text)
    assert st.transcendental >= 128


def test_parse_computations():
    text = _compile_text(lambda x: jnp.sum(x * 2),
                         jax.ShapeDtypeStruct((64,), jnp.float32))
    comps = parse_hlo(text)
    assert len(comps) >= 1
    assert any(i.opcode in ("fusion", "multiply", "reduce")
               for c in comps.values() for i in c.instrs)


def test_roofline_terms_structure():
    from repro.roofline.analysis import HloStats
    st = HloStats(flops=197e12, hbm_bytes=819e9,
                  collective_bytes={"all-reduce": 50e9})
    t = roofline_terms(st)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    assert t["dominant"] in ("compute", "memory", "collective")
