"""Roofline HLO parser: trip-count multipliers, dot FLOPs, in-place
traffic modeling, collective accounting — validated on real compiled HLO."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (analyze_hlo, collective_ops,
                                     dot_flops_matching, parse_hlo,
                                     roofline_terms, total_wire_bytes,
                                     wire_bytes_by_dtype, _ring_wire)


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_while_trip_count_multiplies_flops():
    n, L = 64, 9

    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    text = _compile_text(f, jax.ShapeDtypeStruct((n, n), jnp.float32))
    st = analyze_hlo(text)
    expect = L * 2 * n ** 3
    assert abs(st.flops - expect) / expect < 0.05, (st.flops, expect)
    assert L in st.while_trip_counts.values()


def test_single_dot_flops_exact():
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    text = _compile_text(lambda a, b: a @ b, a, b)
    st = analyze_hlo(text)
    assert st.flops == pytest.approx(2 * 32 * 64 * 16, rel=0.01)


def test_dus_counted_at_slice_size():
    """Scan carrying a big buffer and updating one row per step must not
    charge the full buffer per step."""
    big = 512

    def f(x):
        buf = jnp.zeros((big, big), jnp.float32)

        def body(buf, i):
            return jax.lax.dynamic_update_slice(
                buf, x[None] * i.astype(jnp.float32), (i, 0)), None
        buf, _ = jax.lax.scan(body, buf, jnp.arange(big))
        return buf

    text = _compile_text(f, jax.ShapeDtypeStruct((big,), jnp.float32))
    st = analyze_hlo(text)
    full_charge = big * (big * big * 4)       # what naive counting gives
    assert st.hbm_bytes < full_charge * 0.05, (st.hbm_bytes, full_charge)


def test_transcendental_counted():
    text = _compile_text(lambda x: jnp.tanh(x),
                         jax.ShapeDtypeStruct((128,), jnp.float32))
    st = analyze_hlo(text)
    assert st.transcendental >= 128


def test_parse_computations():
    text = _compile_text(lambda x: jnp.sum(x * 2),
                         jax.ShapeDtypeStruct((64,), jnp.float32))
    comps = parse_hlo(text)
    assert len(comps) >= 1
    assert any(i.opcode in ("fusion", "multiply", "reduce")
               for c in comps.values() for i in c.instrs)


# hand-written but grammar-exact post-SPMD HLO: one set-form all-reduce
# (2 groups of 4) and one iota-form all-gather (1 group of 8)
_SYNTH_COLLECTIVE_HLO = """\
HloModule synth

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[1024], p1: u16[128]) -> (f32[1024], u16[1024]) {
  %p0 = f32[1024]{0} parameter(0)
  %p1 = u16[128]{0} parameter(1)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag = u16[1024]{0} all-gather(%p1), replica_groups=[1,8]<=[8], dimensions={0}
  ROOT %t = (f32[1024]{0}, u16[1024]{0}) tuple(%ar, %ag)
}
"""


def test_collective_group_size_both_formats():
    ops = {op.family: op for op in collective_ops(_SYNTH_COLLECTIVE_HLO)}
    assert ops["all-reduce"].group_size == 4      # {{0,1,2,3},{4,5,6,7}}
    assert ops["all-gather"].group_size == 8      # [1,8]<=[8]
    assert ops["all-reduce"].dtype == "f32"
    assert ops["all-gather"].dtype == "u16"


def test_ring_wire_model():
    # all-reduce: reduce-scatter + all-gather phases, 2(n-1)/n × payload
    assert _ring_wire("all-reduce", 4, 4096, 4096) == \
        pytest.approx(2 * 3 / 4 * 4096)
    # all-gather ships the full RESULT minus the local shard
    assert _ring_wire("all-gather", 8, 256, 2048) == \
        pytest.approx(7 / 8 * 2048)
    assert _ring_wire("all-to-all", 8, 2048, 2048) == \
        pytest.approx(7 / 8 * 2048)
    assert _ring_wire("collective-permute", 8, 2048, 2048) == 2048
    # degenerate single-participant groups move nothing
    assert _ring_wire("all-reduce", 1, 4096, 4096) == 0.0


def test_wire_bytes_by_dtype_synthetic():
    w = wire_bytes_by_dtype(_SYNTH_COLLECTIVE_HLO)
    assert w["f32"] == pytest.approx(2 * 3 / 4 * 1024 * 4)
    assert w["u16"] == pytest.approx(7 / 8 * 1024 * 2)
    assert total_wire_bytes(_SYNTH_COLLECTIVE_HLO) == \
        pytest.approx(w["f32"] + w["u16"])


def test_dot_flops_matching_selects_by_output_width():
    a = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    text = _compile_text(lambda a, b: a @ b, a, b)
    assert dot_flops_matching(text, 32) == pytest.approx(2 * 8 * 16 * 32)
    assert dot_flops_matching(text, 31) == 0.0


def test_dot_flops_matching_scales_with_while_trips():
    n, L = 8, 3

    def f(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    text = _compile_text(f, jax.ShapeDtypeStruct((n, n), jnp.float32))
    assert dot_flops_matching(text, n) == pytest.approx(L * 2 * n ** 3)


def test_roofline_terms_structure():
    from repro.roofline.analysis import HloStats
    st = HloStats(flops=197e12, hbm_bytes=819e9,
                  collective_bytes={"all-reduce": 50e9})
    t = roofline_terms(st)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    assert t["dominant"] in ("compute", "memory", "collective")
