"""MessageQueue unit tests (single device): per-key metadata indexing,
device-side assembly of axis-0-contiguous fragments, host fallback for
arbitrary fragment layouts, and M-to-N composition."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.messages import MessageQueue, _axis0_contiguous


def test_m_to_n_axis0_contiguous_device_path():
    q = MessageQueue()
    x = np.arange(48, dtype=np.float32).reshape(12, 4)
    # three senders, out-of-order ranks, axis-0 contiguous tiling
    q.push("t", "s", "h", jnp.asarray(x[8:12]), frag_index=(slice(8, 12),),
           frag_rank=2, frag_count=3, global_shape=(12, 4))
    q.push("t", "s", "h", jnp.asarray(x[0:4]), frag_index=(slice(0, 4),),
           frag_rank=0, frag_count=3, global_shape=(12, 4))
    q.push("t", "s", "h", jnp.asarray(x[4:8]), frag_index=(slice(4, 8),),
           frag_rank=1, frag_count=3, global_shape=(12, 4))
    got = q.pull("t", "s", "h")
    np.testing.assert_array_equal(np.asarray(got), x)


def test_non_contiguous_fragments_host_fallback():
    q = MessageQueue()
    x = np.arange(32, dtype=np.float32).reshape(4, 8)
    # axis-1 split: not axis-0 contiguous -> host assembly
    frags = [((slice(0, 4), slice(0, 4)), x[:, :4]),
             ((slice(0, 4), slice(4, 8)), x[:, 4:])]
    for r, (idx, frag) in enumerate(frags):
        q.push("t", "s", "k", jnp.asarray(np.ascontiguousarray(frag)),
               frag_index=idx, frag_rank=r, frag_count=2,
               global_shape=(4, 8))
    got = q.pull("t", "s", "k")
    np.testing.assert_array_equal(np.asarray(got), x)


def test_axis0_contiguity_detection():
    from repro.core.messages import Meta

    def meta(rank, sl0, gshape=(8, 4), sl1=None):
        idx = (sl0, sl1 if sl1 is not None else slice(0, gshape[1]))
        return Meta("k", "t", gshape, np.float32, idx, rank, 2)

    ok = {0: meta(0, slice(0, 4)), 1: meta(1, slice(4, 8))}
    assert _axis0_contiguous(ok) == [0, 1]
    # reversed rank order still detected (sorted by start offset)
    rev = {0: meta(0, slice(4, 8)), 1: meta(1, slice(0, 4))}
    assert _axis0_contiguous(rev) == [1, 0]
    gap = {0: meta(0, slice(0, 3)), 1: meta(1, slice(4, 8))}
    assert _axis0_contiguous(gap) is None
    partial_cols = {0: meta(0, slice(0, 4), sl1=slice(0, 2)),
                    1: meta(1, slice(4, 8))}
    assert _axis0_contiguous(partial_cols) is None


def test_per_key_indexing_with_deep_backlog():
    """A pull must find its key regardless of how many other keys are
    buffered on the channel (the old implementation rescanned every
    buffered meta per wakeup)."""
    q = MessageQueue()
    for i in range(50):
        q.push("a", "b", f"k{i}", jnp.full((2,), i, jnp.float32))
    # pull in arbitrary order; untouched keys stay buffered
    for i in (37, 0, 49, 12):
        got = q.pull("a", "b", f"k{i}")
        np.testing.assert_array_equal(np.asarray(got),
                                      np.full((2,), i, np.float32))
    assert q.stats()["pushes"] == 50


def test_pull_blocks_until_all_fragments_arrive():
    q = MessageQueue()
    x = np.arange(8, dtype=np.float32).reshape(4, 2)
    out = {}

    def puller():
        out["v"] = q.pull("t", "s", "h", timeout=10.0)

    th = threading.Thread(target=puller)
    q.push("t", "s", "h", jnp.asarray(x[:2]), frag_index=(slice(0, 2),),
           frag_rank=0, frag_count=2, global_shape=(4, 2))
    th.start()
    q.push("t", "s", "h", jnp.asarray(x[2:]), frag_index=(slice(2, 4),),
           frag_rank=1, frag_count=2, global_shape=(4, 2))
    th.join(timeout=10)
    assert not th.is_alive()
    np.testing.assert_array_equal(np.asarray(out["v"]), x)


def test_pull_timeout():
    q = MessageQueue()
    with pytest.raises(TimeoutError):
        q.pull("a", "b", "missing", timeout=0.1)
