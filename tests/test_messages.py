"""MessageQueue unit tests (single device): per-key metadata indexing,
device-side assembly of axis-0-contiguous fragments, host fallback for
arbitrary fragment layouts, and M-to-N composition."""
import logging
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.messages import (MessageQueue, PullTimeout,
                                 StaleScopeError, _axis0_contiguous)


def test_m_to_n_axis0_contiguous_device_path():
    q = MessageQueue()
    x = np.arange(48, dtype=np.float32).reshape(12, 4)
    # three senders, out-of-order ranks, axis-0 contiguous tiling
    q.push("t", "s", "h", jnp.asarray(x[8:12]), frag_index=(slice(8, 12),),
           frag_rank=2, frag_count=3, global_shape=(12, 4))
    q.push("t", "s", "h", jnp.asarray(x[0:4]), frag_index=(slice(0, 4),),
           frag_rank=0, frag_count=3, global_shape=(12, 4))
    q.push("t", "s", "h", jnp.asarray(x[4:8]), frag_index=(slice(4, 8),),
           frag_rank=1, frag_count=3, global_shape=(12, 4))
    got = q.pull("t", "s", "h")
    np.testing.assert_array_equal(np.asarray(got), x)


def test_non_contiguous_fragments_host_fallback():
    q = MessageQueue()
    x = np.arange(32, dtype=np.float32).reshape(4, 8)
    # axis-1 split: not axis-0 contiguous -> host assembly
    frags = [((slice(0, 4), slice(0, 4)), x[:, :4]),
             ((slice(0, 4), slice(4, 8)), x[:, 4:])]
    for r, (idx, frag) in enumerate(frags):
        q.push("t", "s", "k", jnp.asarray(np.ascontiguousarray(frag)),
               frag_index=idx, frag_rank=r, frag_count=2,
               global_shape=(4, 8))
    got = q.pull("t", "s", "k")
    np.testing.assert_array_equal(np.asarray(got), x)


def test_axis0_contiguity_detection():
    from repro.core.messages import Meta

    def meta(rank, sl0, gshape=(8, 4), sl1=None):
        idx = (sl0, sl1 if sl1 is not None else slice(0, gshape[1]))
        return Meta("k", "t", gshape, np.float32, idx, rank, 2)

    ok = {0: meta(0, slice(0, 4)), 1: meta(1, slice(4, 8))}
    assert _axis0_contiguous(ok) == [0, 1]
    # reversed rank order still detected (sorted by start offset)
    rev = {0: meta(0, slice(4, 8)), 1: meta(1, slice(0, 4))}
    assert _axis0_contiguous(rev) == [1, 0]
    gap = {0: meta(0, slice(0, 3)), 1: meta(1, slice(4, 8))}
    assert _axis0_contiguous(gap) is None
    partial_cols = {0: meta(0, slice(0, 4), sl1=slice(0, 2)),
                    1: meta(1, slice(4, 8))}
    assert _axis0_contiguous(partial_cols) is None


def test_per_key_indexing_with_deep_backlog():
    """A pull must find its key regardless of how many other keys are
    buffered on the channel (the old implementation rescanned every
    buffered meta per wakeup)."""
    q = MessageQueue()
    for i in range(50):
        q.push("a", "b", f"k{i}", jnp.full((2,), i, jnp.float32))
    # pull in arbitrary order; untouched keys stay buffered
    for i in (37, 0, 49, 12):
        got = q.pull("a", "b", f"k{i}")
        np.testing.assert_array_equal(np.asarray(got),
                                      np.full((2,), i, np.float32))
    assert q.stats()["pushes"] == 50


def test_pull_blocks_until_all_fragments_arrive():
    q = MessageQueue()
    x = np.arange(8, dtype=np.float32).reshape(4, 2)
    out = {}

    def puller():
        out["v"] = q.pull("t", "s", "h", timeout=10.0)

    th = threading.Thread(target=puller)
    q.push("t", "s", "h", jnp.asarray(x[:2]), frag_index=(slice(0, 2),),
           frag_rank=0, frag_count=2, global_shape=(4, 2))
    th.start()
    q.push("t", "s", "h", jnp.asarray(x[2:]), frag_index=(slice(2, 4),),
           frag_rank=1, frag_count=2, global_shape=(4, 2))
    th.join(timeout=10)
    assert not th.is_alive()
    np.testing.assert_array_equal(np.asarray(out["v"]), x)


def test_pull_timeout():
    q = MessageQueue()
    with pytest.raises(TimeoutError):
        q.pull("a", "b", "missing", timeout=0.1)


# --------------------------------------------------------------------------- #
# Diagnosability (satellite): per-edge stats, pending keys in timeouts
# --------------------------------------------------------------------------- #
def test_stats_per_edge_depth_pending_bytes():
    q = MessageQueue()
    q.push("a", "b", "s0/x", jnp.zeros((4, 2), jnp.float32))
    q.push("a", "b", "s0/y", jnp.zeros((8,), jnp.float32))
    q.push("b", "c", "s0/z", jnp.zeros((2,), jnp.float32))
    st = q.stats()
    assert st["pushes"] == 3 and st["channels"] == 2
    ab = st["edges"]["a->b"]
    assert ab["depth"] == 2
    assert ab["pending"] == ["s0/x", "s0/y"]
    assert ab["bytes"] == (4 * 2 + 8) * 4
    assert st["edges"]["b->c"] == {"depth": 1, "pending": ["s0/z"],
                                   "bytes": 2 * 4}
    q.pull("a", "b", "s0/x")
    st2 = q.stats()
    assert st2["edges"]["a->b"]["pending"] == ["s0/y"]
    assert st2["edges"]["a->b"]["bytes"] == 8 * 4


def test_pull_timeout_reports_pending_keys():
    """The timeout error must name what IS buffered on the edge — a
    stale-scope or typo'd key is diagnosed from the message alone."""
    q = MessageQueue()
    q.push("a", "b", "s0/emb.0", jnp.zeros((2,)))
    with pytest.raises(TimeoutError, match=r"s1/emb\.0.*s0/emb\.0"):
        q.pull("a", "b", "s1/emb.0", timeout=0.1)


# --------------------------------------------------------------------------- #
# Iteration-scoped namespaces (streaming tentpole)
# --------------------------------------------------------------------------- #
def test_evict_scope_drops_leftovers_and_seals_namespace():
    q = MessageQueue()
    q.push("a", "b", "s0/left", jnp.zeros((2,)))
    q.push("a", "b", "s1/keep", jnp.ones((2,)))
    q.push("a", "b", "unscoped", jnp.ones((3,)))
    evicted = q.evict_scope("s0")
    assert evicted == {"a->b": ["s0/left"]}
    # the retired namespace is sealed in both directions
    with pytest.raises(RuntimeError, match=r"scope 's0'.*retired"):
        q.push("a", "b", "s0/late", jnp.zeros((1,)))
    with pytest.raises(RuntimeError, match=r"scope 's0'.*retired"):
        q.pull("a", "b", "s0/left", timeout=0.1)
    # other scopes and unscoped keys are untouched
    np.testing.assert_array_equal(np.asarray(q.pull("a", "b", "s1/keep")),
                                  np.ones((2,), np.float32))
    np.testing.assert_array_equal(np.asarray(q.pull("a", "b", "unscoped")),
                                  np.ones((3,), np.float32))
    assert q.stats()["edges"]["a->b"]["depth"] == 0


def test_evict_scope_clean_iteration_reports_nothing():
    q = MessageQueue()
    q.push("a", "b", "s7/x", jnp.zeros((2,)))
    q.pull("a", "b", "s7/x")
    assert q.evict_scope("s7") == {}


# --------------------------------------------------------------------------- #
# Retirement diagnosability (satellite): named errors, eviction logging,
# stats after eviction
# --------------------------------------------------------------------------- #
def test_sealed_scope_raises_named_error():
    """Stale traffic into a retired scope raises the NAMED
    StaleScopeError (a RuntimeError subclass, so old handlers keep
    working) — callers can catch exactly this condition."""
    q = MessageQueue()
    q.evict_scope("s0")
    with pytest.raises(StaleScopeError, match=r"scope 's0'.*retired"):
        q.push("a", "b", "s0/late", jnp.zeros((1,)))
    with pytest.raises(StaleScopeError, match=r"scope 's0'.*retired"):
        q.pull("a", "b", "s0/late", timeout=0.1)
    assert issubclass(StaleScopeError, RuntimeError)


def test_pull_timeout_is_named_and_blames_producer_and_scope():
    """The timeout error is the NAMED PullTimeout (TimeoutError
    subclass) and names the producing section and the iteration scope
    being waited on."""
    q = MessageQueue()
    with pytest.raises(PullTimeout,
                       match=r"producer section 'vit'.*scope 's3'"):
        q.pull("vit", "llm", "s3/emb.1", timeout=0.1)
    # unscoped keys still name the producer, without a scope clause
    with pytest.raises(PullTimeout, match=r"producer section 'a'"):
        q.pull("a", "b", "plainkey", timeout=0.1)


def test_evict_scope_logs_leftovers(caplog):
    """Leftover eviction must leave a log trail naming scope, edge and
    keys — a producer pushed something no consumer ever pulled."""
    q = MessageQueue()
    q.push("a", "b", "s0/orphan.0", jnp.zeros((2,)))
    q.push("a", "b", "s0/orphan.1", jnp.zeros((2,)))
    with caplog.at_level(logging.WARNING, logger="repro.messages"):
        q.evict_scope("s0")
    msgs = [r.getMessage() for r in caplog.records
            if r.name == "repro.messages"]
    assert len(msgs) == 1
    assert "'s0'" in msgs[0] and "a->b" in msgs[0]
    assert "s0/orphan.0" in msgs[0] and "s0/orphan.1" in msgs[0]
    # a clean eviction logs nothing
    caplog.clear()
    q.push("a", "b", "s1/x", jnp.zeros((2,)))
    q.pull("a", "b", "s1/x")
    with caplog.at_level(logging.WARNING, logger="repro.messages"):
        q.evict_scope("s1")
    assert not [r for r in caplog.records if r.name == "repro.messages"]


def test_stats_per_edge_after_eviction():
    """stats() must reflect eviction: depth and buffered bytes drop to
    zero for the evicted scope while other scopes' bytes survive."""
    q = MessageQueue()
    q.push("a", "b", "s0/x", jnp.zeros((4, 2), jnp.float32))
    q.push("a", "b", "s1/y", jnp.zeros((8,), jnp.float32))
    q.push("b", "c", "s0/z", jnp.zeros((2,), jnp.float32))
    q.evict_scope("s0")
    st = q.stats()
    assert st["edges"]["a->b"] == {"depth": 1, "pending": ["s1/y"],
                                   "bytes": 8 * 4}
    assert st["edges"]["b->c"] == {"depth": 0, "pending": [], "bytes": 0}
    # totals are cumulative push-side counters, untouched by eviction
    assert st["pushes"] == 3
