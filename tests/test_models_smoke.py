"""Per-arch smoke tests: every assigned architecture instantiates a REDUCED
config of its family and runs one forward/train step on CPU — output shapes
+ no NaNs; plus prefill/decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as cfgs
from repro.models.model import build_model
from conftest import toy_batch


@pytest.mark.parametrize("name", cfgs.ARCH_NAMES)
def test_forward_and_loss(name):
    cfg = cfgs.get_reduced(name)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = toy_batch(cfg)
    loss, metrics = jax.jit(m.loss)(params, batch)
    assert jnp.isfinite(loss), name
    # CE at init should be near ln(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(metrics["ce"]) \
        < 2.5 * np.log(cfg.vocab_size), (name, float(metrics["ce"]))
    logits = m.forward(params, {k: v for k, v in batch.items()
                                if k not in ("labels", "loss_mask")})
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", cfgs.ARCH_NAMES)
def test_one_train_step(name):
    from repro.optim import adamw
    cfg = cfgs.get_reduced(name)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    batch = toy_batch(cfg)

    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(m.loss, has_aux=True)(
            params, batch)
        new_p, new_opt, gnorm = adamw.update(grads, opt, jnp.float32(1e-3))
        return new_p, new_opt, loss, gnorm

    new_p, new_opt, loss, gnorm = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(loss) and jnp.isfinite(gnorm), name
    # params actually changed
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_p)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0, name


DECODE_ARCHS = ["granite-20b", "qwen1.5-0.5b", "qwen2.5-32b",
                "granite-3-8b", "mixtral-8x22b", "moonshot-v1-16b-a3b",
                "mamba2-130m", "pixtral-12b", "whisper-small",
                "jamba-v0.1-52b"]


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_prefill_decode_matches_forward(name):
    """Prefill S-2 tokens then decode 2 == full forward (fp32, no-drop
    MoE capacity)."""
    cfg = cfgs.get_reduced(name).replace(dtype="float32",
                                         capacity_factor=8.0)
    if cfg.vision_dim:
        cfg = cfg.replace(vision_dim=0)      # decode path is text-only
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = toy_batch(cfg, B=B, S=S, seed=1)
    fwd_in = {k: v for k, v in batch.items()
              if k not in ("labels", "loss_mask")}
    full = m.forward(params, fwd_in)
    pre = dict(fwd_in)
    pre["tokens"] = fwd_in["tokens"][:, :S - 2]
    logits_p, cache = m.prefill(params, pre, extra_cache=2)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full[:, S - 3]), atol=2e-4,
                               rtol=2e-4)
    lg, cache = m.decode(params, cache, fwd_in["tokens"][:, S - 2:S - 1],
                         jnp.int32(S - 2))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S - 2]),
                               atol=2e-4, rtol=2e-4)
    lg2, _ = m.decode(params, cache, fwd_in["tokens"][:, S - 1:S],
                      jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(full[:, S - 1]),
                               atol=2e-4, rtol=2e-4)


def test_sliding_window_rolling_cache():
    """SWA decode with a rolling window-sized cache matches full forward."""
    cfg = cfgs.get_reduced("mixtral-8x22b").replace(
        dtype="float32", capacity_factor=8.0, sliding_window=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    B, S = 1, 24
    batch = toy_batch(cfg, B=B, S=S, seed=2)
    full = m.forward(params, {"tokens": batch["tokens"]})
    pre = {"tokens": batch["tokens"][:, :S - 1]}
    _, cache = m.prefill(params, pre, extra_cache=1)
    assert cache["sub0"]["k"].shape[2] == 8     # window-sized cache
    lg, _ = m.decode(params, cache, batch["tokens"][:, S - 1:S],
                     jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S - 1]),
                               atol=2e-4, rtol=2e-4)
