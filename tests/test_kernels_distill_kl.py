"""Chunked-vocab distillation KL: chunked-jnp and Pallas (interpret) vs
the full-materialization oracle; analytic backward vs autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.distill_kl import distill_kl_chunked_jnp
from repro.kernels.distill_kl_pallas import distill_kl_pallas


def _inputs(N, Ds, Dt, V, seed=0):
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    return (jax.random.normal(k[0], (N, Ds)),
            jax.random.normal(k[1], (Ds, V)) * 0.2,
            jax.random.normal(k[2], (N, Dt)),
            jax.random.normal(k[3], (Dt, V)) * 0.2)


@pytest.mark.parametrize("N,Ds,Dt,V", [(32, 16, 24, 128), (64, 8, 8, 256),
                                       (16, 32, 16, 96)])
@pytest.mark.parametrize("T", [1.0, 2.0])
@pytest.mark.parametrize("masked", [False, True])
def test_chunked_matches_oracle(N, Ds, Dt, V, T, masked):
    hs, ws, ht, wt = _inputs(N, Ds, Dt, V)
    mask = (jnp.arange(N) % 3 != 0) if masked else None
    r_ref = ref.distill_kl_reference(hs, ws, ht, wt, mask=mask,
                                     temperature=T)
    r = distill_kl_chunked_jnp(hs, ws, ht, wt, mask=mask, temperature=T,
                               block_v=32)
    np.testing.assert_allclose(float(r), float(r_ref), atol=1e-5,
                               rtol=1e-5)


@pytest.mark.parametrize("block_t,block_v", [(16, 64), (32, 32)])
def test_pallas_matches_oracle(block_t, block_v):
    hs, ws, ht, wt = _inputs(64, 16, 24, 256)
    mask = jnp.arange(64) % 4 != 0
    r_ref = ref.distill_kl_reference(hs, ws, ht, wt, mask=mask,
                                     temperature=2.0)
    r = distill_kl_pallas(hs, ws, ht, wt, mask=mask, temperature=2.0,
                          interpret=True, block_t=block_t, block_v=block_v)
    np.testing.assert_allclose(float(r), float(r_ref), atol=1e-5,
                               rtol=1e-5)


def test_analytic_backward_matches_autodiff():
    hs, ws, ht, wt = _inputs(32, 16, 24, 128)
    mask = jnp.arange(32) % 3 != 0
    g1 = jax.grad(lambda *a: distill_kl_chunked_jnp(
        *a, mask=mask, temperature=2.0, block_v=32),
        argnums=(0, 1, 2, 3))(hs, ws, ht, wt)
    g2 = jax.grad(lambda *a: ref.distill_kl_reference(
        *a, mask=mask, temperature=2.0), argnums=(0, 1, 2, 3))(hs, ws, ht,
                                                               wt)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   rtol=1e-5)


def test_pallas_backward():
    hs, ws, ht, wt = _inputs(32, 16, 16, 128)
    g1 = jax.grad(lambda *a: distill_kl_pallas(
        *a, temperature=1.5, interpret=True, block_t=16, block_v=32),
        argnums=(0, 1, 2, 3))(hs, ws, ht, wt)
    g2 = jax.grad(lambda *a: ref.distill_kl_reference(
        *a, temperature=1.5), argnums=(0, 1, 2, 3))(hs, ws, ht, wt)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6,
                                   rtol=1e-5)


def test_kl_properties():
    """KL(p||p) == 0; KL ≥ 0 (identical teacher/student nets give 0)."""
    hs, ws, _, _ = _inputs(16, 8, 8, 64)
    z = distill_kl_chunked_jnp(hs, ws, hs, ws, temperature=1.0, block_v=16)
    np.testing.assert_allclose(float(z), 0.0, atol=1e-6)
    _, _, ht, wt = _inputs(16, 8, 8, 64, seed=7)
    pos = distill_kl_chunked_jnp(hs, ws, ht, wt, temperature=1.0,
                                 block_v=16)
    assert float(pos) >= 0.0
