"""Mamba-2 SSD: chunked-jnp and Pallas (interpret) vs sequential oracle;
decode-step consistency with the scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ssd_scan import (ssd_chunked_jnp, ssd_decode_step)
from repro.kernels.ssd_pallas import ssd_scan_pallas


def _inputs(b, s, h, p, n, seed=1):
    k = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(k[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(k[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(k[2], (h,)))
    B = jax.random.normal(k[3], (b, s, n))
    C = jax.random.normal(k[4], (b, s, n))
    D = jax.random.normal(k[5], (h,))
    return x, dt, A, B, C, D


@pytest.mark.parametrize("b,s,h,p,n", [(2, 64, 3, 8, 16), (1, 128, 2, 16, 8),
                                       (2, 48, 4, 8, 4)])
@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_chunked_matches_sequential(b, s, h, p, n, chunk):
    x, dt, A, B, C, D = _inputs(b, s, h, p, n)
    y_ref = ref.ssd_reference(x, dt, A, B, C, D)
    y = ssd_chunked_jnp(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("chunk", [16, 32])
def test_pallas_matches_sequential(chunk):
    x, dt, A, B, C, D = _inputs(2, 64, 3, 8, 16)
    y_ref = ref.ssd_reference(x, dt, A, B, C, D)
    y = ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-4)


def test_pallas_grads():
    x, dt, A, B, C, D = _inputs(1, 32, 2, 8, 8)
    gp = jax.grad(lambda x, B: jnp.sum(ssd_scan_pallas(
        x, dt, A, B, C, D, chunk=8, interpret=True) ** 2),
        argnums=(0, 1))(x, B)
    gr = jax.grad(lambda x, B: jnp.sum(ref.ssd_reference(
        x, dt, A, B, C, D) ** 2), argnums=(0, 1))(x, B)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3,
                                   rtol=2e-3)


def test_decode_step_matches_scan():
    """Running the recurrence token-by-token == the chunked scan."""
    b, s, h, p, n = 2, 32, 3, 8, 16
    x, dt, A, B, C, D = _inputs(b, s, h, p, n)
    y_full, state_full = ssd_chunked_jnp(x, dt, A, B, C, D, chunk=8,
                                         return_state=True)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        state, yt = ssd_decode_step(state, x[:, t], dt[:, t], A, B[:, t],
                                    C[:, t], D)
        ys.append(yt)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_full),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_full),
                               atol=1e-3, rtol=1e-3)


def test_initial_state_continuation():
    """Scanning two halves with state handoff == one full scan."""
    x, dt, A, B, C, D = _inputs(1, 64, 2, 8, 8)
    y_full = ssd_chunked_jnp(x, dt, A, B, C, D, chunk=16)
    y1, st = ssd_chunked_jnp(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32],
                             D, chunk=16, return_state=True)
    y2 = ssd_chunked_jnp(x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:], D,
                         chunk=16, initial_state=st)
    y_cat = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full),
                               atol=1e-4, rtol=1e-4)
