"""Flash attention as a Pallas TPU kernel.

TPU-native layout (not a CUDA port): the MXU wants [≥128 × 128] matmul
tiles, so blocks default to (block_q=512, block_kv=512) with head_dim as
the minor dimension; online-softmax statistics live in VMEM scratch across
the sequential innermost grid dimension (TPU grids execute in order, which
replaces the CUDA warp-level loop).

Grid: (batch, q_heads, nq, nkv) — the kv dimension is innermost; (m, l,
acc) scratch carries across it and the output/LSE tiles are flushed at the
final kv step.  GQA is expressed in the K/V index_map (kv_head = h // G) —
no KV duplication in HBM or VMEM.  Causal/sliding-window masking is
positional; fully-masked (above-diagonal) blocks skip their matmuls via
``pl.when``.

KV positions: both masks are difference-based (``q_pos >= kv_pos`` and
``q_pos - kv_pos < window``), so a shifted query window (decode q_offset,
CP allgather shards) and non-contiguous KV rows (chunked-ulysses a2a
output, which interleaves per-device sub-slices) are both expressed as an
explicit ``kv_positions`` int32 operand — one extra [1, T] input, loaded
per kv block; the block-skip condition then uses the block's position
min/max instead of the static grid arithmetic.

Backward: custom VJP over the blockwise-recompute backward in ``ref.py``
(identical math to the FlashAttention-2 backward; on TPU it lowers to the
same scan structure the forward uses).  Forward emits LSE for it.
``flash_attention_lse`` exposes the (o, lse) pair with a VJP that consumes
the lse cotangent, which makes :func:`merge_flash_partials` — the
online-softmax merge of partial results over disjoint KV chunks — exactly
differentiable end to end.

Validated in interpret mode on CPU against ``ref.mha_reference`` across a
shape/dtype sweep (tests/test_kernels_flash.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref as _ref

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, *rest, scale, causal, window,
                block_q, block_kv, nkv, has_pos):
    if has_pos:
        pos_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref = rest
    else:
        pos_ref = None
        o_ref, lse_ref, acc_ref, m_ref, l_ref = rest
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    if has_pos:
        kv_pos = pos_ref[...]                              # [1, bkv]
        kv_lo, kv_hi = jnp.min(kv_pos), jnp.max(kv_pos)
    else:
        kv_pos = ik * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        kv_lo, kv_hi = ik * block_kv, ik * block_kv + block_kv - 1
    run = True
    if causal:
        # skip blocks entirely above the diagonal
        run = kv_lo <= (iq * block_q + block_q - 1)
    if window > 0:
        run = jnp.logical_and(run, (iq * block_q) - kv_hi < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)                # [bkv, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, q_pos >= kv_pos)
        if window > 0:
            mask = jnp.logical_and(mask, q_pos - kv_pos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new

    @pl.when(ik == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[:, 0] + jnp.log(l)


def _flash_fwd_pallas(q, k, v, kv_pos, *, scale, causal, window, block_q,
                      block_kv, interpret):
    """q [B,H,S,D]; k,v [B,KV,T,D]; kv_pos [T] or None
    -> (o [B,H,S,D], lse [B,H,S])."""
    B, H, S, D = q.shape
    KV, T = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bkv = min(block_kv, T)
    assert S % bq == 0 and T % bkv == 0, (S, T, bq, bkv)
    nq, nkv = S // bq, T // bkv
    grid = (B, H, nq, nkv)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_kv=bkv, nkv=nkv, has_pos=kv_pos is not None)
    in_specs = [
        pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, bkv, D),
                     lambda b, h, iq, ik: (b, h // G, ik, 0)),
        pl.BlockSpec((1, 1, bkv, D),
                     lambda b, h, iq, ik: (b, h // G, ik, 0)),
    ]
    args = [q, k, v]
    if kv_pos is not None:
        in_specs.append(
            pl.BlockSpec((1, bkv), lambda b, h, iq, ik: (0, ik)))
        args.append(kv_pos.reshape(1, T).astype(jnp.int32))
    out_shape = [
        jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        jax.ShapeDtypeStruct((B, H, S), jnp.float32),
    ]
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return o, lse


def _zgrad(x):
    if x is None:
        return None
    return np.zeros(getattr(x, "shape", ()), jax.dtypes.float0)


def _fwd(q, k, v, kv_pos, causal, window, scale, blocks, interpret,
         out_dtype):
    qt = jnp.swapaxes(q, 1, 2)                  # [B,H,S,D]
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o, lse = _flash_fwd_pallas(qt, kt, vt, kv_pos, scale=scale,
                               causal=causal, window=window,
                               block_q=blocks[0], block_kv=blocks[1],
                               interpret=interpret)
    o = jnp.swapaxes(o, 1, 2)                   # [B,S,H,D]
    lse_bsh = jnp.transpose(lse, (0, 2, 1))     # [B,S,H]
    return (o, lse_bsh), (q, k, v, o, lse_bsh, kv_pos)


def _bwd_res(res):
    q, k, v, o, lse, kv_pos = res
    return (q, k, v, o, lse, None, None, jnp.int32(0), kv_pos)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _flash_pallas(q, k, v, kv_pos, causal, window, scale, blocks,
                  interpret, out_dtype):
    return _fwd(q, k, v, kv_pos, causal, window, scale, blocks, interpret,
                out_dtype)[0][0]


def _fwd_vjp(q, k, v, kv_pos, causal, window, scale, blocks, interpret,
             out_dtype):
    (o, _), res = _fwd(q, k, v, kv_pos, causal, window, scale, blocks,
                       interpret, out_dtype)
    return o, res


def _bwd_vjp(causal, window, scale, blocks, interpret, out_dtype, res, do):
    # blockwise-recompute backward (ref.py) — the lse layout there is
    # [B, S, H] with H = KV*G ordering identical to ours
    dq, dk, dv, _, _, _, _ = _ref._flash_bwd(
        causal, window, scale, blocks, _bwd_res(res), do)
    return dq, dk, dv, _zgrad(res[5])


_flash_pallas.defvjp(_fwd_vjp, _bwd_vjp)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _flash_pallas_lse(q, k, v, kv_pos, causal, window, scale, blocks,
                      interpret, out_dtype):
    return _fwd(q, k, v, kv_pos, causal, window, scale, blocks, interpret,
                out_dtype)[0]


def _fwd_lse_vjp(q, k, v, kv_pos, causal, window, scale, blocks, interpret,
                 out_dtype):
    return _fwd(q, k, v, kv_pos, causal, window, scale, blocks, interpret,
                out_dtype)


def _bwd_lse_vjp(causal, window, scale, blocks, interpret, out_dtype, res,
                 cts):
    do, dlse = cts
    dq, dk, dv, _, _, _, _ = _ref._flash_bwd_core(
        causal, window, scale, blocks, _bwd_res(res), do, dlse)
    return dq, dk, dv, _zgrad(res[5])


_flash_pallas_lse.defvjp(_fwd_lse_vjp, _bwd_lse_vjp)


def _positions(kv_positions, q_offset, T):
    """Fold q_offset into explicit KV positions (masks are
    difference-based, so shifting KV by −q_offset is exact) — this is how
    traced offsets (CP allgather's axis_index) reach the Pallas kernel."""
    static_zero = isinstance(q_offset, (int, np.integer)) and q_offset == 0
    if kv_positions is None:
        if static_zero:
            return None
        return jnp.arange(T, dtype=jnp.int32) - jnp.asarray(
            q_offset, jnp.int32)
    kv_positions = jnp.asarray(kv_positions, jnp.int32)
    if static_zero:
        return kv_positions
    return kv_positions - jnp.asarray(q_offset, jnp.int32)


def flash_attention(q, k, v, *, causal=True, window=0, segment_q=None,
                    segment_kv=None, scale: Optional[float] = None,
                    q_offset=0, kv_positions=None, interpret: bool = False,
                    block_q: int = 512, block_kv: int = 512):
    """Pallas flash attention; q [B,S,H,D], k/v [B,T,KV,D].

    Segment ids fall back to the jnp blockwise path (they appear only in
    packed-sequence contexts where the caller already composes its own
    kernel); q_offset / kv_positions run natively via the positions
    operand."""
    if segment_q is not None or segment_kv is not None:
        return _ref.flash_attention_jnp(
            q, k, v, causal=causal, window=window, segment_q=segment_q,
            segment_kv=segment_kv, scale=scale, q_offset=q_offset,
            kv_positions=kv_positions, block_q=block_q, block_kv=block_kv)
    B, S, H, D = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, S)
    bkv = min(block_kv, T)
    if S % bq or T % bkv:
        return _ref.flash_attention_jnp(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, kv_positions=kv_positions,
            block_q=block_q, block_kv=block_kv)
    kv_pos = _positions(kv_positions, q_offset, T)
    return _flash_pallas(q, k, v, kv_pos, bool(causal), int(window),
                         float(scale), (bq, bkv), bool(interpret), q.dtype)


def flash_attention_lse(q, k, v, *, causal=True, window=0,
                        scale: Optional[float] = None, q_offset=0,
                        kv_positions=None, interpret: bool = False,
                        block_q: int = 512, block_kv: int = 512):
    """Pallas flash attention returning ``(o [B,S,H,D], lse [B,S,H])``.

    The VJP consumes the lse cotangent, so partial results over disjoint
    KV chunks merged with :func:`merge_flash_partials` differentiate
    exactly (the overlap-pipelined CP path relies on this)."""
    B, S, H, D = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, S)
    bkv = min(block_kv, T)
    if S % bq or T % bkv:
        return _ref.flash_attention_jnp_lse(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, kv_positions=kv_positions,
            block_q=block_q, block_kv=block_kv)
    kv_pos = _positions(kv_positions, q_offset, T)
    return _flash_pallas_lse(q, k, v, kv_pos, bool(causal), int(window),
                             float(scale), (bq, bkv), bool(interpret),
                             q.dtype)


def merge_flash_partials(o_parts, lse_parts):
    """Online-softmax merge of flash partials over disjoint KV chunks.

    o_parts [N,B,S,H,D] (or a list of [B,S,H,D]), lse_parts [N,B,S,H] (or
    a list of [B,S,H]) -> (o, lse) over the union of the chunks.  Exact:
    each partial is its chunk's softmax-weighted value sum with its
    log-sum-exp, so reweighting by ``exp(lse_i − lse)`` reconstructs the
    global softmax.  A fully-masked chunk carries lse ≈ −1e30 and merges
    with weight 0, which also zeroes its (meaningless) o part.
    Differentiable: plain jnp, and the chunk kernels' VJPs consume the
    resulting (do_i, dlse_i) cotangents.
    """
    if isinstance(o_parts, (list, tuple)):
        o_parts = jnp.stack(o_parts)
    if isinstance(lse_parts, (list, tuple)):
        lse_parts = jnp.stack(lse_parts)
    m = jnp.max(lse_parts, axis=0)
    w = jnp.exp(lse_parts - m[None])
    l = jnp.maximum(jnp.sum(w, axis=0), 1e-30)
    lse = m + jnp.log(l)
    o = jnp.sum(o_parts.astype(jnp.float32) * (w / l[None])[..., None],
                axis=0)
    return o.astype(o_parts.dtype), lse
