"""Pure-jnp oracles for every Pallas kernel in this package.

Two tiers:

* ``*_reference``  — naive, full-materialization math. Ground truth for tests.
* ``flash_attention_jnp`` — blockwise online-softmax attention with a
  custom VJP (flash-style recompute backward).  Memory-optimal in jnp; this is
  what the model stack uses on CPU and what the Pallas kernel is checked
  against on larger shapes.

All attention shapes: q [B, S, H, D];  k, v [B, T, KV, D] with H % KV == 0.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _mask(sq, skv, q_pos, kv_pos, causal, window, seg_q, seg_kv):
    """Boolean mask [*, sq, skv] — True = attend."""
    m = jnp.ones((sq, skv), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= kv_pos[None, :]
    if window > 0:
        m &= q_pos[:, None] - kv_pos[None, :] < window
    if seg_q is not None:
        sm = seg_q[..., :, None] == seg_kv[..., None, :]
        m = m & sm
    return m


def mha_reference(q, k, v, *, causal=True, window=0,
                  segment_q=None, segment_kv=None,
                  q_offset=0, kv_positions=None,
                  scale: Optional[float] = None):
    """Naive GQA attention. q_offset: absolute position of q[0] (for decode).

    kv_positions [T] overrides the implicit ``arange(T)`` KV positions —
    used when the KV rows are a non-contiguous slice of a longer sequence
    (chunked context-parallel attention)."""
    B, S, H, D = q.shape
    _, T, KV, _ = k.shape
    G = H // KV
    scale = scale if scale is not None else D ** -0.5
    qr = q.reshape(B, S, KV, G, D)
    logits = jnp.einsum("bskgd,btkd->bkgst", qr.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S) + q_offset
    kv_pos = jnp.arange(T) if kv_positions is None else kv_positions
    m = _mask(S, T, q_pos, kv_pos, causal, window,
              None if segment_q is None else segment_q[:, None, None, :],
              None if segment_kv is None else segment_kv[:, None, None, :])
    logits = jnp.where(m, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


# --------------------------------------------------------------------------- #
# Blockwise flash attention in jnp with custom VJP
# --------------------------------------------------------------------------- #
def _block_mask(q_pos, kv_pos, causal, window, seg_q, seg_kv):
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= kv_pos[None, :]
    if window > 0:
        m &= q_pos[:, None] - kv_pos[None, :] < window
    out = m
    if seg_q is not None:
        # seg_q [B, sq], seg_kv [B, skv] -> [B, sq, skv]
        out = out[None] & (seg_q[:, :, None] == seg_kv[:, None, :])
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10))
def _flash(q, k, v, seg_q, seg_kv, q_offset, kv_pos, causal, window, scale,
           blocks):
    return _flash_fwd(q, k, v, seg_q, seg_kv, q_offset, kv_pos, causal,
                      window, scale, blocks)[0]


def _flash_fwd(q, k, v, seg_q, seg_kv, q_offset, kv_pos, causal, window,
               scale, blocks):
    block_q, block_kv = blocks
    o, lse = _flash_fwd_raw(q, k, v, seg_q, seg_kv, causal, window,
                            scale, q_offset, block_q, block_kv,
                            kv_pos=kv_pos)
    return o, (q, k, v, o, lse, seg_q, seg_kv, q_offset, kv_pos)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10))
def _flash_lse(q, k, v, seg_q, seg_kv, q_offset, kv_pos, causal, window,
               scale, blocks):
    """Like ``_flash`` but returns ``(o, lse)`` with a custom VJP over the
    joint output — the backward consumes the lse cotangent too, so chunked
    callers can differentiate through an online-softmax merge of partial
    results."""
    o, res = _flash_fwd(q, k, v, seg_q, seg_kv, q_offset, kv_pos, causal,
                        window, scale, blocks)
    return o, res[4]


def _flash_lse_fwd(q, k, v, seg_q, seg_kv, q_offset, kv_pos, causal,
                   window, scale, blocks):
    o, res = _flash_fwd(q, k, v, seg_q, seg_kv, q_offset, kv_pos, causal,
                        window, scale, blocks)
    return (o, res[4]), res


def _flash_lse_bwd(causal, window, scale, blocks, res, cts):
    do, dlse = cts
    return _flash_bwd_core(causal, window, scale, blocks, res, do, dlse)


def _flash_fwd_raw(q, k, v, seg_q, seg_kv, causal, window, scale, q_offset,
                   block_q, block_kv, kv_pos=None):
    B, S, H, D = q.shape
    _, T, KV, _ = k.shape
    G = H // KV
    nq, nkv = S // block_q, T // block_kv
    qr = (q.reshape(B, nq, block_q, KV, G, D).astype(jnp.float32) * scale)
    kr = k.reshape(B, nkv, block_kv, KV, D).astype(jnp.float32)
    vr = v.reshape(B, nkv, block_kv, KV, D).astype(jnp.float32)
    sq_r = (seg_q.reshape(B, nq, block_q).transpose(1, 0, 2)
            if seg_q is not None else jnp.zeros((nq, 1, 1), jnp.int32))
    skv_r = (seg_kv.reshape(B, nkv, block_kv).transpose(1, 0, 2)
             if seg_kv is not None else jnp.zeros((nkv, 1, 1), jnp.int32))
    kvp_r = (kv_pos.reshape(nkv, block_kv) if kv_pos is not None
             else jnp.zeros((nkv, 1), jnp.int32))

    def q_block(carry, inp):
        qi, q_blk, sq_blk = inp
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        def kv_step(acc, kin):
            o_acc, m_acc, l_acc = acc
            ki, k_blk, v_blk, skv_blk, kvp_blk = kin
            kv_pos_b = (kvp_blk if kv_pos is not None
                        else ki * block_kv + jnp.arange(block_kv))
            s = jnp.einsum("bqkgd,btkd->bkgqt", q_blk, k_blk)
            msk = _block_mask(q_pos, kv_pos_b, causal, window,
                              sq_blk if seg_q is not None else None,
                              skv_blk if seg_kv is not None else None)
            msk = msk[None, None, None] if msk.ndim == 2 else msk[:, None, None]
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m_acc, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_acc - m_new)
            l_new = l_acc * corr + jnp.sum(p, axis=-1)
            o_new = o_acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, v_blk)
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((B, KV, G, block_q, D), jnp.float32)
        m0 = jnp.full((B, KV, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, block_q), jnp.float32)
        (o, m, l), _ = jax.lax.scan(
            kv_step, (o0, m0, l0),
            (jnp.arange(nkv), kr.transpose(1, 0, 2, 3, 4),
             vr.transpose(1, 0, 2, 3, 4), skv_r, kvp_r))
        l_safe = jnp.maximum(l, 1e-30)
        o = o / l_safe[..., None]
        lse = m + jnp.log(l_safe)
        return carry, (o, lse)

    _, (o_all, lse_all) = jax.lax.scan(
        q_block, None,
        (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5), sq_r))
    o = o_all.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, D).astype(q.dtype)
    lse = lse_all.transpose(1, 0, 4, 2, 3).reshape(B, S, H)
    return o, lse


def _flash_bwd(causal, window, scale, blocks, res, do):
    return _flash_bwd_core(causal, window, scale, blocks, res, do, None)


def _flash_bwd_core(causal, window, scale, blocks, res, do, dlse):
    """Blockwise-recompute flash backward.

    ``dlse`` is the cotangent of the forward's log-sum-exp output (None when
    only ``o`` was consumed).  The FlashAttention-2 backward's per-row term
    ``delta_i = Σ_d do_id·o_id`` generalizes to ``delta_i − dlse_i`` when the
    lse is itself differentiated — d lse_i/d s_ij = p_ij, so the joint
    cotangent of s_ij is p_ij·(dp_ij − delta_i + dlse_i)."""
    q, k, v, o, lse, seg_q, seg_kv, q_offset, kv_pos = res
    block_q, block_kv = blocks
    B, S, H, D = q.shape
    _, T, KV, _ = k.shape
    G = H // KV
    nq, nkv = S // block_q, T // block_kv
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    of = o.astype(jnp.float32)
    # delta [B,S,H]
    delta = jnp.sum(dof * of, axis=-1)
    if dlse is not None:
        delta = delta - dlse.astype(jnp.float32)

    qr = qf.reshape(B, nq, block_q, KV, G, D)
    dor = dof.reshape(B, nq, block_q, KV, G, D)
    lser = lse.reshape(B, nq, block_q, KV, G)
    dltr = delta.reshape(B, nq, block_q, KV, G)
    kr = kf.reshape(B, nkv, block_kv, KV, D)
    vr = vf.reshape(B, nkv, block_kv, KV, D)
    sq_r = (seg_q.reshape(B, nq, block_q).transpose(1, 0, 2)
            if seg_q is not None else jnp.zeros((nq, 1, 1), jnp.int32))
    skv_r = (seg_kv.reshape(B, nkv, block_kv).transpose(1, 0, 2)
             if seg_kv is not None else jnp.zeros((nkv, 1, 1), jnp.int32))
    kvp_r = (kv_pos.reshape(nkv, block_kv) if kv_pos is not None
             else jnp.zeros((nkv, 1), jnp.int32))

    dk0 = jnp.zeros((nkv, B, block_kv, KV, D), jnp.float32)
    dv0 = jnp.zeros((nkv, B, block_kv, KV, D), jnp.float32)

    # Outer scan over q blocks carries full dk/dv accumulators; the inner scan
    # over kv blocks emits per-(q,kv)-block dk/dv contributions.
    def outer2(carry, qin):
        dk_acc, dv_acc = carry
        qi, q_blk, do_blk, lse_blk, dlt_blk, sq_blk = qin
        q_pos = q_offset + qi * block_q + jnp.arange(block_q)

        def inner(dq_acc, kin):
            ki, k_blk, v_blk, skv_blk, kvp_blk = kin
            kv_pos_b = (kvp_blk if kv_pos is not None
                        else ki * block_kv + jnp.arange(block_kv))
            s = jnp.einsum("bqkgd,btkd->bkgqt", q_blk * scale, k_blk)
            msk = _block_mask(q_pos, kv_pos_b, causal, window,
                              sq_blk if seg_q is not None else None,
                              skv_blk if seg_kv is not None else None)
            msk = (msk[None, None, None] if msk.ndim == 2
                   else msk[:, None, None])
            s = jnp.where(msk, s, NEG_INF)
            p = jnp.exp(s - lse_blk.transpose(0, 2, 3, 1)[..., None])
            dp = jnp.einsum("bqkgd,btkd->bkgqt", do_blk, v_blk)
            ds = p * (dp - dlt_blk.transpose(0, 2, 3, 1)[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bkgqt,btkd->bqkgd", ds, k_blk)
            dk_b = jnp.einsum("bkgqt,bqkgd->btkd", ds, q_blk)
            dv_b = jnp.einsum("bkgqt,bqkgd->btkd", p, do_blk)
            return dq_acc, (dk_b, dv_b)

        dq0 = jnp.zeros((B, block_q, KV, G, D), jnp.float32)
        dq, (dk_b, dv_b) = jax.lax.scan(
            inner, dq0,
            (jnp.arange(nkv), kr.transpose(1, 0, 2, 3, 4),
             vr.transpose(1, 0, 2, 3, 4), skv_r, kvp_r))
        return (dk_acc + dk_b, dv_acc + dv_b), dq

    (dk_all, dv_all), dq_all = jax.lax.scan(
        outer2, (dk0, dv0),
        (jnp.arange(nq), qr.transpose(1, 0, 2, 3, 4, 5),
         dor.transpose(1, 0, 2, 3, 4, 5),
         lser.transpose(1, 0, 2, 3, 4), dltr.transpose(1, 0, 2, 3, 4), sq_r))
    dq = dq_all.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, D).astype(q.dtype)
    dk = dk_all.transpose(1, 0, 2, 3, 4).reshape(B, T, KV, D).astype(k.dtype)
    dv = dv_all.transpose(1, 0, 2, 3, 4).reshape(B, T, KV, D).astype(v.dtype)

    def zgrad(x):
        if x is None:
            return None
        shape = getattr(x, "shape", ())
        return np.zeros(shape, jax.dtypes.float0)

    return (dq, dk, dv, zgrad(seg_q), zgrad(seg_kv), zgrad(q_offset),
            zgrad(kv_pos))


_flash.defvjp(_flash_fwd, _flash_bwd)
_flash_lse.defvjp(_flash_lse_fwd, _flash_lse_bwd)


def _flash_prep(q, k, v, segment_q, segment_kv, kv_positions,
                block_q, block_kv):
    """Pad inputs up to block multiples (padded KV excluded via segment
    ids; padded Q rows sliced off by the caller) instead of shrinking the
    block — tiny blocks on odd lengths (e.g. whisper's 1500 frames) would
    explode the scan trip count."""
    B, S, _, _ = q.shape
    T = k.shape[1]
    bq = min(block_q, S)
    bkv = min(block_kv, T)
    pad_q = (-S) % bq
    pad_kv = (-T) % bkv
    if pad_q or pad_kv:
        sq = (segment_q if segment_q is not None
              else jnp.zeros((B, S), jnp.int32))
        skv = (segment_kv if segment_kv is not None
               else jnp.zeros((B, T), jnp.int32))
        segment_q = jnp.pad(sq, ((0, 0), (0, pad_q)), constant_values=-1)
        segment_kv = jnp.pad(skv, ((0, 0), (0, pad_kv)), constant_values=-2)
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        if kv_positions is not None:
            kv_positions = jnp.pad(kv_positions, (0, pad_kv),
                                   constant_values=2 ** 30)
    if kv_positions is not None:
        kv_positions = jnp.asarray(kv_positions, jnp.int32)
    return q, k, v, segment_q, segment_kv, kv_positions, bq, bkv, pad_q


def flash_attention_jnp(q, k, v, *, causal=True, window=0,
                        segment_q=None, segment_kv=None,
                        scale: Optional[float] = None, q_offset=0,
                        kv_positions=None, block_q=512, block_kv=512):
    """Blockwise flash attention (jnp, custom-VJP recompute backward)."""
    S, D = q.shape[1], q.shape[3]
    scale = scale if scale is not None else D ** -0.5
    q, k, v, segment_q, segment_kv, kv_positions, bq, bkv, pad_q = \
        _flash_prep(q, k, v, segment_q, segment_kv, kv_positions,
                    block_q, block_kv)
    q_off = jnp.asarray(q_offset, jnp.int32)
    out = _flash(q, k, v, segment_q, segment_kv, q_off, kv_positions,
                 bool(causal), int(window), float(scale), (bq, bkv))
    if pad_q:
        out = out[:, :S]
    return out


def flash_attention_jnp_lse(q, k, v, *, causal=True, window=0,
                            scale: Optional[float] = None, q_offset=0,
                            kv_positions=None, block_q=512, block_kv=512):
    """Blockwise flash attention returning ``(o [B,S,H,D], lse [B,S,H])``.

    The custom VJP consumes the lse cotangent, so chunked callers (the
    overlap-pipelined CP path) can differentiate straight through
    :func:`repro.kernels.flash_attention.merge_flash_partials`."""
    S, D = q.shape[1], q.shape[3]
    scale = scale if scale is not None else D ** -0.5
    q, k, v, segment_q, segment_kv, kv_positions, bq, bkv, pad_q = \
        _flash_prep(q, k, v, None, None, kv_positions, block_q, block_kv)
    q_off = jnp.asarray(q_offset, jnp.int32)
    o, lse = _flash_lse(q, k, v, segment_q, segment_kv, q_off,
                        kv_positions, bool(causal), int(window),
                        float(scale), (bq, bkv))
    if pad_q:
        o, lse = o[:, :S], lse[:, :S]
    return o, lse


# --------------------------------------------------------------------------- #
# Chunked-vocab distillation KL oracle (see kernels/distill_kl.py)
# --------------------------------------------------------------------------- #
def distill_kl_reference(h_student, w_student, h_teacher, w_teacher,
                         *, mask=None, temperature: float = 1.0):
    """KL(p_teacher || p_student), token-mean, from hidden states.

    h_* : [N, D_*];  w_* : [D_*, V].  Full-materialization oracle.
    """
    zs = (h_student.astype(jnp.float32) @ w_student.astype(jnp.float32))
    zt = (h_teacher.astype(jnp.float32) @ w_teacher.astype(jnp.float32))
    zs, zt = zs / temperature, zt / temperature
    ls = jax.nn.log_softmax(zs, axis=-1)
    lt = jax.nn.log_softmax(zt, axis=-1)
    pt = jnp.exp(lt)
    kl = jnp.sum(pt * (lt - ls), axis=-1)
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(kl * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(kl)


# --------------------------------------------------------------------------- #
# Mamba2 / SSD oracle: sequential recurrence (ground truth)
# --------------------------------------------------------------------------- #
def ssd_reference(x, dt, A, B, C, D):
    """Sequential SSD scan.

    x  [b, s, h, p]   inputs per head
    dt [b, s, h]      softplus-ed timestep
    A  [h]            negative decay rate (A < 0 stored as value, decay=exp(A*dt))
    B  [b, s, n]      input projection (ngroups=1)
    C  [b, s, n]      output projection
    D  [h]            skip
    returns y [b, s, h, p]
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                       # [b,h,p],[b,h],[b,n],[b,n]
        decay = jnp.exp(A[None] * dtt)              # [b,h]
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dtt, Bt, xt)
        state = state * decay[..., None, None] + dBx
        yt = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, yt

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, s0,
                         (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
                          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2, 3) + xf * D[None, None, :, None]
    return y.astype(x.dtype)
