"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Grid: (batch, heads, chunks) with chunks innermost (sequential on TPU);
the inter-chunk SSM state [headdim, d_state] persists in VMEM scratch
across chunk steps — the recurrence never round-trips HBM, which is the
TPU-native replacement for the paper's warp-level chunk scan.

Per chunk (Q = chunk length), everything is MXU-shaped:
  cb     = C·Bᵀ                      [Q, Q]
  scores = cb ⊙ tril(exp(cum_i−cum_j))
  y      = scores·(dt⊙x) + exp(cum)·(C·stateᵀ)
  state  = exp(cum_Q)·state + (decay_end⊙dt⊙x)ᵀ·B

The D·x skip and dt softplus/bias run in the jit wrapper (fused by XLA).
Backward: custom VJP that recomputes through the chunked-jnp
implementation (same math, memory-bounded).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ssd_scan as _ssd


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, state_ref, *, nc):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # [Q]
    A = A_ref[0]                                    # scalar
    Bm = B_ref[0, 0].astype(jnp.float32)            # [Q, N]
    Cm = C_ref[0, 0].astype(jnp.float32)            # [Q, N]
    Q = x.shape[0]

    a = A * dt                                      # [Q] log-decays
    cum = jnp.cumsum(a)                             # [Q]
    iq = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jq = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(iq >= jq, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = cb * L                                 # [Q, Q]
    dx = dt[:, None] * x                            # [Q, P]
    y_intra = jax.lax.dot_general(scores, dx, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state = state_ref[...]                          # [P, N]
    y_inter = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[:, None]       # [Q, P]
    y_ref[0, 0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    decay_end = jnp.exp(cum[-1] - cum)              # [Q]
    wx = (decay_end * dt)[:, None] * x              # [Q, P]
    new_state = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        wx, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # [P, N]
    state_ref[...] = new_state


def _ssd_fwd_pallas(x, dt, A, B, C, *, chunk, interpret):
    """x [b,s,h,p], dt [b,s,h] (softplus'ed), A [h], B/C [b,s,n] -> y
    (without the D·x skip)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, s)
    while s % Q:
        Q //= 2
    nc = s // Q
    xr = x.transpose(0, 2, 1, 3).reshape(b, h, nc, Q, p)
    dtr = dt.transpose(0, 2, 1).reshape(b, h, nc, Q)
    Br = B.reshape(b, nc, Q, n)
    Cr = C.reshape(b, nc, Q, n)
    kernel = functools.partial(_kernel, nc=nc)
    y = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, p), lambda ib, ih, ic: (ib, ih, ic,
                                                              0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, 1, Q, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, Q, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Q, p),
                               lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nc, Q, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, A.astype(jnp.float32), Br, Cr)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _ssd_p(x, dt, A, B, C, D, chunk, interpret):
    y = _ssd_fwd_pallas(x, dt, A, B, C, chunk=chunk, interpret=interpret)
    return (y.astype(jnp.float32)
            + x.astype(jnp.float32) * D[None, None, :, None]).astype(x.dtype)


def _fwd(x, dt, A, B, C, D, chunk, interpret):
    return _ssd_p(x, dt, A, B, C, D, chunk, interpret), (x, dt, A, B, C, D)


def _bwd(chunk, interpret, res, g):
    x, dt, A, B, C, D = res
    _, vjp = jax.vjp(
        lambda *args: _ssd.ssd_chunked_jnp(*args, chunk=chunk), x, dt, A,
        B, C, D)
    return vjp(g)


_ssd_p.defvjp(_fwd, _bwd)


def ssd_scan_pallas(x, dt, A, B, C, D, *, chunk: int = 128,
                    interpret: bool = False):
    return _ssd_p(x, dt, A, B, C, D, int(chunk), bool(interpret))
