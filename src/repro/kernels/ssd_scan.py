"""Mamba-2 SSD (state-space duality) scan — chunked formulation.

The chunked algorithm (Mamba-2 paper, arXiv:2405.21060 §6) splits the sequence
into chunks of length Q:

* intra-chunk: quadratic "attention-like" term  (C_i·B_j)·exp(cum_i − cum_j)
* chunk state: S_c = Σ_j exp(cum_Q − cum_j)·dt_j·B_j⊗x_j
* inter-chunk: a length-S/Q recurrence over chunk states
* output:      y = y_intra + C_i·(exp(cum_i)·H_{c−1}) + D·x

``ssd_chunked_jnp`` is the jnp implementation used by the model stack (and
the oracle target of the Pallas kernel, which computes the intra-chunk +
state terms per chunk with VMEM-resident blocks).

Shapes: x [b,s,h,p], dt [b,s,h], A [h], B [b,s,n], C [b,s,n], D [h].
"""
from __future__ import annotations


import jax
import jax.numpy as jnp



def _chunk_terms(xc, dtc, A, Bc, Cc):
    """Per-chunk intra output, final-state contribution, and total decay.

    xc [b,Q,h,p], dtc [b,Q,h], Bc/Cc [b,Q,n] -> (y_intra, S_c, decay_chunk,
    cum) with S_c [b,h,p,n], decay_chunk [b,h], cum [b,Q,h].
    """
    a = A[None, None, :] * dtc                       # [b,Q,h] log-decays
    cum = jnp.cumsum(a, axis=1)                      # [b,Q,h]
    # L[i,j] = exp(cum_i - cum_j) for j <= i
    diff = cum[:, :, None, :] - cum[:, None, :, :]   # [b,Q,Q,h]
    Q = xc.shape[1]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bin,bjn->bij", Cc, Bc)          # [b,Q,Q]
    scores = cb[:, :, :, None] * L                   # [b,Q,Q,h]
    dx = dtc[..., None] * xc                         # [b,Q,h,p]
    y_intra = jnp.einsum("bijh,bjhp->bihp", scores, dx)
    # state contribution: S_c[h,p,n] = sum_j exp(cum_Q - cum_j) dt_j x_j B_j
    decay_to_end = jnp.exp(cum[:, -1:, :] - cum)     # [b,Q,h]
    S_c = jnp.einsum("bjh,bjhp,bjn->bhpn", decay_to_end * dtc, xc, Bc)
    decay_chunk = jnp.exp(cum[:, -1, :])             # [b,h]
    return y_intra, S_c, decay_chunk, cum


def ssd_chunked_jnp(x, dt, A, B, C, D, *, chunk: int = 128,
                    initial_state=None, return_state: bool = False):
    b, s, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, s)
    while s % Q:
        Q //= 2
    nc = s // Q
    xf = x.astype(jnp.float32).reshape(b, nc, Q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, Q, h)
    Bf = B.astype(jnp.float32).reshape(b, nc, Q, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, Q, n)
    Af = A.astype(jnp.float32)

    def step(Hprev, inp):
        xc, dtc, Bc, Cc = inp
        y_intra, S_c, decay_chunk, cum = _chunk_terms(xc, dtc, Af, Bc, Cc)
        # inter-chunk: y_inter[i] = C_i · (exp(cum_i) * Hprev)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", Cc, Hprev,
                             jnp.exp(cum))
        Hnew = Hprev * decay_chunk[:, :, None, None] + S_c
        return Hnew, y_intra + y_inter

    H0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    Hfin, ys = jax.lax.scan(
        step, H0,
        (xf.transpose(1, 0, 2, 3, 4), dtf.transpose(1, 0, 2, 3),
         Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, Hfin
    return y


def ssd_decode_step(state, xt, dtt, A, Bt, Ct, D):
    """Single-token SSD recurrence.

    state [b,h,p,n], xt [b,h,p], dtt [b,h], Bt/Ct [b,n] -> (state', y [b,h,p])
    """
    decay = jnp.exp(A[None] * dtt.astype(jnp.float32))
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dtt.astype(jnp.float32),
                     Bt.astype(jnp.float32), xt.astype(jnp.float32))
    state = state * decay[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", state, Ct.astype(jnp.float32))
    y = y + xt.astype(jnp.float32) * D[None, :, None]
    return state, y.astype(xt.dtype)


def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128, interpret: bool = False):
    """Pallas entry point (see bottom of file); falls back to chunked jnp
    until the kernel is wired for the requested shape."""
    from repro.kernels import ssd_pallas
    return ssd_pallas.ssd_scan_pallas(x, dt, A, B, C, D, chunk=chunk,
                                      interpret=interpret)
