"""Chunked-vocab distillation KL — KL(p_teacher || p_student) from hidden states.

Maestro §3.1: the logits tensor is vocab/hidden ≈ 62× larger than the hidden
state it is computed from, so the teacher's output layer is colocated with the
student and only hidden states cross the section boundary.  This kernel takes
that insight to its conclusion: the KL is computed by streaming over vocab
blocks with online-logsumexp accumulators, so the [N, V] logits of *neither*
model are ever materialized in HBM.

Per token (with z = h·W / T):

    KL = Σ_v p_t (log p_t − log p_s)
       = (Σ p_t z_t) − lse_t − (Σ p_t z_s) + lse_s

All four accumulators stream in one pass.  The custom VJP recomputes per-block
probabilities in a second pass (flash-style):

    dKL/dz_s = p_s − p_t
    dKL/dz_t = p_t ⊙ ((z_t − Σp_t z_t) − (z_s − Σp_t z_s))

``distill_kl`` is the Pallas entry point; ``distill_kl_chunked_jnp`` is the
chunked jnp implementation (used on CPU; oracle: ref.distill_kl_reference).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _blocks(V, block_v):
    bv = min(block_v, V)
    while V % bv:
        bv //= 2
    return max(bv, 1)


def _fwd_pass(h_s, w_s, h_t, w_t, T, block_v):
    """Returns per-token (lse_s, lse_t, e_t=Σp_t·z_t, e_s=Σp_t·z_s)."""
    N = h_s.shape[0]
    V = w_s.shape[1]
    bv = _blocks(V, block_v)
    nb = V // bv
    hs = h_s.astype(jnp.float32)
    ht = h_t.astype(jnp.float32)
    ws = w_s.astype(jnp.float32).reshape(w_s.shape[0], nb, bv)
    wt = w_t.astype(jnp.float32).reshape(w_t.shape[0], nb, bv)

    def step(carry, inp):
        ms, ls, mt, lt, ut, us = carry
        wsb, wtb = inp
        zs = (hs @ wsb) / T                          # [N, bv]
        zt = (ht @ wtb) / T
        ms_n = jnp.maximum(ms, jnp.max(zs, -1))
        ls = ls * jnp.exp(ms - ms_n) + jnp.sum(jnp.exp(zs - ms_n[:, None]), -1)
        mt_n = jnp.maximum(mt, jnp.max(zt, -1))
        corr = jnp.exp(mt - mt_n)
        pt_blk = jnp.exp(zt - mt_n[:, None])
        lt = lt * corr + jnp.sum(pt_blk, -1)
        ut = ut * corr + jnp.sum(pt_blk * zt, -1)
        us = us * corr + jnp.sum(pt_blk * zs, -1)
        return (ms_n, ls, mt_n, lt, ut, us), None

    neg = jnp.full((N,), -1e30, jnp.float32)
    zero = jnp.zeros((N,), jnp.float32)
    (ms, ls, mt, lt, ut, us), _ = jax.lax.scan(
        step, (neg, zero, neg, zero, zero, zero),
        (ws.transpose(1, 0, 2), wt.transpose(1, 0, 2)))
    lse_s = ms + jnp.log(ls)
    lse_t = mt + jnp.log(lt)
    e_t = ut / lt
    e_s = us / lt
    return lse_s, lse_t, e_t, e_s


def _kl_from_stats(lse_s, lse_t, e_t, e_s, mask):
    kl = e_t - lse_t - e_s + lse_s
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(kl * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(kl)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _distill_kl(h_s, w_s, h_t, w_t, mask, T, block_v):
    lse_s, lse_t, e_t, e_s = _fwd_pass(h_s, w_s, h_t, w_t, T, block_v)
    return _kl_from_stats(lse_s, lse_t, e_t, e_s, mask)


def _distill_kl_fwd(h_s, w_s, h_t, w_t, mask, T, block_v):
    lse_s, lse_t, e_t, e_s = _fwd_pass(h_s, w_s, h_t, w_t, T, block_v)
    out = _kl_from_stats(lse_s, lse_t, e_t, e_s, mask)
    return out, (h_s, w_s, h_t, w_t, mask, lse_s, lse_t, e_t, e_s)


def _distill_kl_bwd(T, block_v, res, g):
    h_s, w_s, h_t, w_t, mask, lse_s, lse_t, e_t, e_s = res
    N = h_s.shape[0]
    V = w_s.shape[1]
    bv = _blocks(V, block_v)
    nb = V // bv
    hs = h_s.astype(jnp.float32)
    ht = h_t.astype(jnp.float32)
    ws = w_s.astype(jnp.float32).reshape(w_s.shape[0], nb, bv)
    wt = w_t.astype(jnp.float32).reshape(w_t.shape[0], nb, bv)
    if mask is not None:
        tok_w = mask.astype(jnp.float32)
        tok_w = tok_w / jnp.maximum(jnp.sum(tok_w), 1.0)
    else:
        tok_w = jnp.full((N,), 1.0 / N, jnp.float32)
    tok_w = tok_w * g.astype(jnp.float32)

    def step(carry, inp):
        dhs, dht, i = carry
        wsb, wtb = inp
        zs = (hs @ wsb) / T
        zt = (ht @ wtb) / T
        ps = jnp.exp(zs - lse_s[:, None])
        pt = jnp.exp(zt - lse_t[:, None])
        dzs = (ps - pt) * tok_w[:, None] / T
        dzt = pt * ((zt - e_t[:, None]) - (zs - e_s[:, None])) \
            * tok_w[:, None] / T
        dhs = dhs + dzs @ wsb.T
        dht = dht + dzt @ wtb.T
        dws_b = hs.T @ dzs
        dwt_b = ht.T @ dzt
        return (dhs, dht, i + 1), (dws_b, dwt_b)

    dhs0 = jnp.zeros_like(hs)
    dht0 = jnp.zeros_like(ht)
    (dhs, dht, _), (dws_blocks, dwt_blocks) = jax.lax.scan(
        step, (dhs0, dht0, 0),
        (ws.transpose(1, 0, 2), wt.transpose(1, 0, 2)))
    dws = dws_blocks.transpose(1, 0, 2).reshape(w_s.shape)
    dwt = dwt_blocks.transpose(1, 0, 2).reshape(w_t.shape)
    dmask = (None if mask is None
             else np.zeros(mask.shape, jax.dtypes.float0))
    return (dhs.astype(h_s.dtype), dws.astype(w_s.dtype),
            dht.astype(h_t.dtype), dwt.astype(w_t.dtype), dmask)


_distill_kl.defvjp(_distill_kl_fwd, _distill_kl_bwd)


def distill_kl_chunked_jnp(h_student, w_student, h_teacher, w_teacher, *,
                           mask=None, temperature: float = 1.0,
                           block_v: int = 2048):
    return _distill_kl(h_student, w_student, h_teacher, w_teacher, mask,
                       float(temperature), int(block_v))


def distill_kl(h_student, w_student, h_teacher, w_teacher, *, mask=None,
               temperature: float = 1.0, interpret: bool = False,
               block_v: int = 2048):
    """Pallas entry point."""
    from repro.kernels import distill_kl_pallas as dkp
    return dkp.distill_kl_pallas(h_student, w_student, h_teacher, w_teacher,
                                 mask=mask, temperature=temperature,
                                 interpret=interpret, block_v=block_v)
