"""Dispatching wrappers around the Pallas kernels.

Each op picks an implementation:

* ``pallas``           — the TPU kernel (``pl.pallas_call``).
* ``pallas_interpret`` — same kernel body, interpret mode (CPU correctness).
* ``ref``              — the memory-efficient jnp path (``ref.py``).
* ``auto``             — pallas on TPU, ref elsewhere.

The model stack always calls through here, so swapping in the TPU kernel is a
config change, not a code change.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import ref


@functools.lru_cache(None)
def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:          # pragma: no cover
        return False


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


# --------------------------------------------------------------------------- #
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    segment_q=None, segment_kv=None,
                    scale: Optional[float] = None, q_offset: int = 0,
                    impl: str = "auto",
                    block_q: int = 512, block_kv: int = 512):
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(
            q, k, v, causal=causal, window=window,
            segment_q=segment_q, segment_kv=segment_kv, scale=scale,
            q_offset=q_offset, interpret=(impl == "pallas_interpret"),
            block_q=block_q, block_kv=block_kv)
    if impl == "ref":
        return ref.flash_attention_jnp(
            q, k, v, causal=causal, window=window,
            segment_q=segment_q, segment_kv=segment_kv, scale=scale,
            q_offset=q_offset, block_q=block_q, block_kv=block_kv)
    if impl == "ref_naive":
        return ref.mha_reference(
            q, k, v, causal=causal, window=window,
            segment_q=segment_q, segment_kv=segment_kv, scale=scale,
            q_offset=q_offset)
    raise ValueError(f"unknown attention impl {impl!r}")


# --------------------------------------------------------------------------- #
def distill_kl(h_student, w_student, h_teacher, w_teacher, *, mask=None,
               temperature: float = 1.0, impl: str = "auto",
               block_v: int = 2048):
    """Chunked-vocab KL(p_t || p_s) from hidden states (never materializes
    the [N, V] teacher logits — the kernel form of Maestro's §3.1 colocation
    insight)."""
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import distill_kl as dk
        return dk.distill_kl(h_student, w_student, h_teacher, w_teacher,
                             mask=mask, temperature=temperature,
                             interpret=(impl == "pallas_interpret"),
                             block_v=block_v)
    if impl == "ref":
        from repro.kernels import distill_kl as dk
        return dk.distill_kl_chunked_jnp(
            h_student, w_student, h_teacher, w_teacher, mask=mask,
            temperature=temperature, block_v=block_v)
    if impl == "ref_naive":
        return ref.distill_kl_reference(h_student, w_student, h_teacher,
                                        w_teacher, mask=mask,
                                        temperature=temperature)
    raise ValueError(f"unknown distill_kl impl {impl!r}")


# --------------------------------------------------------------------------- #
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128, impl: str = "auto"):
    """Mamba2 SSD over a full sequence. See ref.ssd_reference for shapes."""
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ssd_scan as ssd
        return ssd.ssd_scan(x, dt, A, B, C, D, chunk=chunk,
                            interpret=(impl == "pallas_interpret"))
    if impl == "ref":
        from repro.kernels import ssd_scan as ssd
        return ssd.ssd_chunked_jnp(x, dt, A, B, C, D, chunk=chunk)
    if impl == "ref_naive":
        return ref.ssd_reference(x, dt, A, B, C, D)
    raise ValueError(f"unknown ssd impl {impl!r}")
