"""Dispatching wrappers around the Pallas kernels.

Each op picks an implementation:

* ``pallas``           — the TPU kernel (``pl.pallas_call``).
* ``pallas_interpret`` — same kernel body, interpret mode (CPU correctness).
* ``ref``              — the memory-efficient jnp path (``ref.py``).
* ``auto``             — pallas on TPU, ref elsewhere.

The model stack always calls through here, so swapping in the TPU kernel is a
config change, not a code change.  A ``REPRO_KERNEL_IMPL`` environment
variable overrides every dispatch repo-wide (CI forces
``pallas_interpret`` through the full driver stack with it).
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels import ref

_IMPLS = ("auto", "pallas", "pallas_interpret", "ref", "ref_naive")


def _on_tpu() -> bool:
    # deliberately uncached: backend selection can change mid-process
    # (tests flip platforms; jax.default_backend is already memoized
    # per-config internally)
    try:
        return jax.default_backend() == "tpu"
    except Exception:          # pragma: no cover
        return False


def _resolve(impl: str) -> str:
    env = os.environ.get("REPRO_KERNEL_IMPL", "")
    if env:
        if env not in _IMPLS:
            raise ValueError(
                f"REPRO_KERNEL_IMPL={env!r}: expected one of {_IMPLS}")
        impl = env
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    if impl not in _IMPLS:
        raise ValueError(f"unknown kernel impl {impl!r}")
    return impl


# --------------------------------------------------------------------------- #
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    segment_q=None, segment_kv=None,
                    scale: Optional[float] = None, q_offset=0,
                    kv_positions=None, impl: str = "auto",
                    block_q: int = 512, block_kv: int = 512):
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(
            q, k, v, causal=causal, window=window,
            segment_q=segment_q, segment_kv=segment_kv, scale=scale,
            q_offset=q_offset, kv_positions=kv_positions,
            interpret=(impl == "pallas_interpret"),
            block_q=block_q, block_kv=block_kv)
    if impl == "ref":
        return ref.flash_attention_jnp(
            q, k, v, causal=causal, window=window,
            segment_q=segment_q, segment_kv=segment_kv, scale=scale,
            q_offset=q_offset, kv_positions=kv_positions,
            block_q=block_q, block_kv=block_kv)
    if impl == "ref_naive":
        return ref.mha_reference(
            q, k, v, causal=causal, window=window,
            segment_q=segment_q, segment_kv=segment_kv, scale=scale,
            q_offset=q_offset, kv_positions=kv_positions)
    raise ValueError(f"unknown attention impl {impl!r}")


# --------------------------------------------------------------------------- #
def flash_attention_lse(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: Optional[float] = None, q_offset=0,
                        kv_positions=None, impl: str = "auto",
                        block_q: int = 512, block_kv: int = 512):
    """Flash attention returning ``(o, lse)`` with a merge-aware VJP.

    The chunked CP path calls this per KV chunk and merges the partials
    with ``flash_attention.merge_flash_partials``; no ``ref_naive`` tier
    (the naive oracle has no lse output)."""
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import flash_attention as fa
        return fa.flash_attention_lse(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, kv_positions=kv_positions,
            interpret=(impl == "pallas_interpret"),
            block_q=block_q, block_kv=block_kv)
    if impl == "ref":
        return ref.flash_attention_jnp_lse(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, kv_positions=kv_positions,
            block_q=block_q, block_kv=block_kv)
    raise ValueError(f"attention impl {impl!r} has no lse-returning form")


# --------------------------------------------------------------------------- #
def distill_kl(h_student, w_student, h_teacher, w_teacher, *, mask=None,
               temperature: float = 1.0, impl: str = "auto",
               block_v: int = 2048):
    """Chunked-vocab KL(p_t || p_s) from hidden states (never materializes
    the [N, V] teacher logits — the kernel form of Maestro's §3.1 colocation
    insight)."""
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import distill_kl as dk
        return dk.distill_kl(h_student, w_student, h_teacher, w_teacher,
                             mask=mask, temperature=temperature,
                             interpret=(impl == "pallas_interpret"),
                             block_v=block_v)
    if impl == "ref":
        from repro.kernels import distill_kl as dk
        return dk.distill_kl_chunked_jnp(
            h_student, w_student, h_teacher, w_teacher, mask=mask,
            temperature=temperature, block_v=block_v)
    if impl == "ref_naive":
        return ref.distill_kl_reference(h_student, w_student, h_teacher,
                                        w_teacher, mask=mask,
                                        temperature=temperature)
    raise ValueError(f"unknown distill_kl impl {impl!r}")


# --------------------------------------------------------------------------- #
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 128, impl: str = "auto"):
    """Mamba2 SSD over a full sequence. See ref.ssd_reference for shapes."""
    impl = _resolve(impl)
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ssd_scan as ssd
        return ssd.ssd_scan(x, dt, A, B, C, D, chunk=chunk,
                            interpret=(impl == "pallas_interpret"))
    if impl == "ref":
        from repro.kernels import ssd_scan as ssd
        return ssd.ssd_chunked_jnp(x, dt, A, B, C, D, chunk=chunk)
    if impl == "ref_naive":
        return ref.ssd_reference(x, dt, A, B, C, D)
    raise ValueError(f"unknown ssd impl {impl!r}")
