"""Chunked-vocab distillation KL as a Pallas TPU kernel.

Grid: (token_blocks, vocab_blocks) with vocab innermost (sequential on
TPU); six online accumulators (max/sumexp for both models + the two
teacher-weighted sums) live in VMEM scratch and the per-token statistics
are flushed at the last vocab step.  Neither model's [N, V] logits ever
exist in HBM — the kernel-level form of Maestro §3.1's "ship hidden
states, not logits".

VMEM working set per step: two weight tiles [D, block_v] (the dominant
term) + two hidden tiles [block_t, D] + the [block_t, block_v] logit tiles.
With D=4096, block_v=512, block_t=256, bf16: ≈ 2·4MB + 2·2MB + 2·0.25MB
≈ 12.5 MB — fits v5e VMEM headroom at double buffering.

Backward: custom VJP reusing the analytic chunked backward from
``distill_kl.py`` (dz_s = p_s − p_t etc.), which never materializes logits
either.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import distill_kl as _dk

NEG_INF = -1e30


def _kernel(hs_ref, ws_ref, ht_ref, wt_ref,
            lse_s_ref, lse_t_ref, e_t_ref, e_s_ref,
            ms_ref, ls_ref, mt_ref, lt_ref, ut_ref, us_ref, *,
            inv_temp, nv):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        ms_ref[...] = jnp.full_like(ms_ref, NEG_INF)
        mt_ref[...] = jnp.full_like(mt_ref, NEG_INF)
        ls_ref[...] = jnp.zeros_like(ls_ref)
        lt_ref[...] = jnp.zeros_like(lt_ref)
        ut_ref[...] = jnp.zeros_like(ut_ref)
        us_ref[...] = jnp.zeros_like(us_ref)

    hs = hs_ref[...].astype(jnp.float32)
    ht = ht_ref[...].astype(jnp.float32)
    ws = ws_ref[...].astype(jnp.float32)
    wt = wt_ref[...].astype(jnp.float32)
    zs = jax.lax.dot_general(hs, ws, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) * inv_temp
    zt = jax.lax.dot_general(ht, wt, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) * inv_temp

    ms_new = jnp.maximum(ms_ref[:, 0], jnp.max(zs, axis=1))
    ls_ref[:, 0] = (ls_ref[:, 0] * jnp.exp(ms_ref[:, 0] - ms_new)
                    + jnp.sum(jnp.exp(zs - ms_new[:, None]), axis=1))
    ms_ref[:, 0] = ms_new

    mt_new = jnp.maximum(mt_ref[:, 0], jnp.max(zt, axis=1))
    corr = jnp.exp(mt_ref[:, 0] - mt_new)
    pt = jnp.exp(zt - mt_new[:, None])
    lt_ref[:, 0] = lt_ref[:, 0] * corr + jnp.sum(pt, axis=1)
    ut_ref[:, 0] = ut_ref[:, 0] * corr + jnp.sum(pt * zt, axis=1)
    us_ref[:, 0] = us_ref[:, 0] * corr + jnp.sum(pt * zs, axis=1)
    mt_ref[:, 0] = mt_new

    @pl.when(iv == nv - 1)
    def _finalize():
        lse_s_ref[...] = ms_ref[:, 0] + jnp.log(
            jnp.maximum(ls_ref[:, 0], 1e-30))
        lse_t_ref[...] = mt_ref[:, 0] + jnp.log(
            jnp.maximum(lt_ref[:, 0], 1e-30))
        lt = jnp.maximum(lt_ref[:, 0], 1e-30)
        e_t_ref[...] = ut_ref[:, 0] / lt
        e_s_ref[...] = us_ref[:, 0] / lt


def _stats_pallas(h_s, w_s, h_t, w_t, *, temperature, block_t, block_v,
                  interpret):
    N, Ds = h_s.shape
    Dt = h_t.shape[1]
    V = w_s.shape[1]
    bt = min(block_t, N)
    bv = min(block_v, V)
    assert N % bt == 0 and V % bv == 0, (N, V, bt, bv)
    grid = (N // bt, V // bv)
    kernel = functools.partial(_kernel, inv_temp=1.0 / temperature,
                               nv=V // bv)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, Ds), lambda it, iv: (it, 0)),
            pl.BlockSpec((Ds, bv), lambda it, iv: (0, iv)),
            pl.BlockSpec((bt, Dt), lambda it, iv: (it, 0)),
            pl.BlockSpec((Dt, bv), lambda it, iv: (0, iv)),
        ],
        out_specs=[pl.BlockSpec((bt,), lambda it, iv: (it,))] * 4,
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.float32)] * 4,
        scratch_shapes=[pltpu.VMEM((bt, 1), jnp.float32)] * 6,
        interpret=interpret,
    )(h_s, w_s, h_t, w_t)
    return outs                                # lse_s, lse_t, e_t, e_s


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _distill_kl_p(h_s, w_s, h_t, w_t, mask, T, block_t, block_v,
                  interpret):
    lse_s, lse_t, e_t, e_s = _stats_pallas(
        h_s, w_s, h_t, w_t, temperature=T, block_t=block_t,
        block_v=block_v, interpret=interpret)
    return _dk._kl_from_stats(lse_s, lse_t, e_t, e_s, mask)


def _fwd(h_s, w_s, h_t, w_t, mask, T, block_t, block_v, interpret):
    lse_s, lse_t, e_t, e_s = _stats_pallas(
        h_s, w_s, h_t, w_t, temperature=T, block_t=block_t,
        block_v=block_v, interpret=interpret)
    out = _dk._kl_from_stats(lse_s, lse_t, e_t, e_s, mask)
    return out, (h_s, w_s, h_t, w_t, mask, lse_s, lse_t, e_t, e_s)


def _bwd(T, block_t, block_v, interpret, res, g):
    return _dk._distill_kl_bwd(T, block_v, res, g)


_distill_kl_p.defvjp(_fwd, _bwd)


def distill_kl_pallas(h_student, w_student, h_teacher, w_teacher, *,
                      mask=None, temperature: float = 1.0,
                      interpret: bool = False, block_t: int = 256,
                      block_v: int = 512):
    N, V = h_student.shape[0], w_student.shape[1]
    bt = min(block_t, N)
    bv = min(block_v, V)
    if N % bt or V % bv:
        return _dk.distill_kl_chunked_jnp(
            h_student, w_student, h_teacher, w_teacher, mask=mask,
            temperature=temperature, block_v=block_v)
    return _distill_kl_p(h_student, w_student, h_teacher, w_teacher, mask,
                         float(temperature), bt, bv, bool(interpret))
