"""Multi-teacher knowledge distillation with data-dependent routing.

The third compound workload on the declarative API — and the proof of its
generality: TWO frozen teacher sections feed one student.  The generalist
teacher sees every sample; the *specialist* teacher activates only on
samples whose ``domain`` flag routes to it (data-dependent activation,
exactly the mechanism MLLM training uses for text-only samples), so the
wavefront scheduler groups specialist samples into fewer microbatches and
the specialist section never runs on pure-generalist microbatches.

Per §3.1 both teachers' output layers are colocated with the student
(consts ``w_a`` / ``w_b``): only hidden states cross the section
boundaries, and the student computes

    loss = (1-α)·CE + α·T²·(KL_a + KL_b·[domain])

with the chunked-vocab ``distill_kl`` kernel.  Specialist rows travel in
the capacity layout (gathered + zero-padded, like ViT embeddings) and are
scattered back to sample slots inside the student loss; the KL_b token
mask comes from scattering ``act_valid`` — an all-generalist microbatch
contributes an exact-zero KL_b (the kernel's mask normalization is
zero-safe).

The whole workload is ~60 lines of declaration (:func:`multi_teacher_spec`)
run by the generic :class:`repro.core.workload.CompoundRuntime`;
``build_colocated_step`` is the single-jit oracle the driver
(``tests/drivers/driver_multi_teacher.py``) verifies the disaggregated
execution against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import workload as wl
from repro.core.types import ArchConfig, ParallelConfig
from repro.dist import sharding as shd
from repro.distill.workload import teacher_hidden
from repro.kernels import ops as kops
from repro.models import common as cm
from repro.models import transformer as tf
from repro.optim import adamw, schedules
from repro.train.step import _act_hook_for

LM_KEYS = ("tokens", "labels", "loss_mask")


# --------------------------------------------------------------------------- #
# Shared arithmetic (oracle ≡ disaggregated)
# --------------------------------------------------------------------------- #
def specialist_hidden(pt, tb_cfg: ArchConfig, tokens, valid, *,
                      impl: str = "ref"):
    """Specialist-teacher hidden states for the gathered (capacity-layout)
    domain samples of one microbatch, padding rows masked to exact zero.
    tokens [cap, S], valid [cap] → h [cap, S, D_b]."""
    h = teacher_hidden(pt, tb_cfg, tokens, impl=impl)
    return h * valid[:, None, None].astype(h.dtype)


def student_mt_loss(ps, s_cfg: ArchConfig, batch, h_a, w_a, h_b, b_idx,
                    b_valid, w_b, *, alpha: float, temperature: float,
                    impl: str = "ref", kl_impl: str = "ref"):
    """CE + α·T²·(KL vs generalist + domain-masked KL vs specialist).
    h_b arrives in capacity layout and is scattered back to sample slots
    by ``b_idx``; the KL_b mask is the scattered ``b_valid``."""
    h_s, _ = tf.lm_forward(ps, s_cfg, batch, impl=impl, remat=True,
                           logits_out=False)
    logits = tf.unembed(ps, s_cfg, h_s)
    ce = cm.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    B, S, Ds = h_s.shape
    w_s = ps["embed"].T if s_cfg.tie_embeddings else ps["unembed"]
    sg = jax.lax.stop_gradient
    hsf = h_s.reshape(B * S, Ds)
    lm = batch["loss_mask"].reshape(B * S)
    T = temperature
    kl_a = kops.distill_kl(hsf, w_s, sg(h_a).reshape(B * S, -1), sg(w_a),
                           mask=lm, temperature=T, impl=kl_impl)
    hb = jnp.zeros((B,) + h_b.shape[1:], h_b.dtype).at[b_idx].add(h_b)
    mb = jnp.zeros((B,), jnp.float32).at[b_idx].add(b_valid)
    mask_b = (batch["loss_mask"] * mb[:, None]).reshape(B * S)
    kl_b = kops.distill_kl(hsf, w_s, sg(hb).reshape(B * S, -1), sg(w_b),
                           mask=mask_b, temperature=T, impl=kl_impl)
    loss = (1 - alpha) * ce + alpha * T * T * (kl_a + kl_b)
    return loss, {"ce": ce, "kl_a": kl_a, "kl_b": kl_b}


# --------------------------------------------------------------------------- #
# The declaration (run it with CompoundRuntime — no bespoke runtime class)
# --------------------------------------------------------------------------- #
def multi_teacher_spec(ta_cfg: ArchConfig, tb_cfg: ArchConfig,
                       s_cfg: ArchConfig, *,
                       ta_parallel: ParallelConfig,
                       tb_parallel: ParallelConfig,
                       s_parallel: ParallelConfig,
                       global_batch: int, seq_len: int, mbs: int,
                       alpha: float = 0.5, temperature: float = 2.0,
                       impl: str = "ref") -> wl.WorkloadSpec:
    """Two frozen teachers → one student, specialist routed by the
    per-sample ``domain`` flag."""
    h_a = wl.Port("hidden", (wl.SEQ, ta_cfg.d_model), ta_cfg.dtype)
    h_b = wl.Port("hidden", (wl.SEQ, tb_cfg.d_model), tb_cfg.dtype)
    kl_impl = "ref" if impl == "ref" else "auto"

    def ta_fn(pt, x):
        return {"hidden": teacher_hidden(pt, ta_cfg, x["tokens"],
                                         impl=impl)}

    def tb_fn(pt, x):
        return {"hidden": specialist_hidden(pt, tb_cfg, x["tokens"],
                                            x["act_valid"], impl=impl)}

    def s_fn(ps, x):
        batch = {k: x[k] for k in LM_KEYS}
        return student_mt_loss(
            ps, s_cfg, batch, x["teacher_a.hidden"], x["w_a"],
            x["teacher_b.hidden"], x["teacher_b.act_idx"],
            x["teacher_b.act_valid"], x["w_b"], alpha=alpha,
            temperature=temperature, impl=impl, kl_impl=kl_impl)

    tok = {"tokens": wl.Field((wl.SEQ,), "int32")}
    teacher_a = wl.SectionSpec(
        "teacher_a", ta_cfg, ta_parallel, ta_fn, tf.lm_specs(ta_cfg),
        inputs=tok, emits=(h_a,), mode="fwd_only")
    teacher_b = wl.SectionSpec(
        "teacher_b", tb_cfg, tb_parallel, tb_fn, tf.lm_specs(tb_cfg),
        inputs=tok, emits=(h_b,), mode="fwd_only",
        activation=lambda b: np.asarray(b["domain"]).astype(bool))
    student = wl.SectionSpec(
        "student", s_cfg, s_parallel, s_fn, tf.lm_specs(s_cfg),
        inputs={"tokens": wl.Field((wl.SEQ,), "int32"),
                "labels": wl.Field((wl.SEQ,), "int32"),
                "loss_mask": wl.Field((wl.SEQ,), "float32", fill=1.0)},
        consumes=(wl.Consume("teacher_a", h_a),
                  wl.Consume("teacher_b", h_b)),
        loss=True, loss_aux=True, critical=True,
        consts={"w_a": wl.Field((ta_cfg.d_model, ta_cfg.padded_vocab),
                                ta_cfg.dtype),
                "w_b": wl.Field((tb_cfg.d_model, tb_cfg.padded_vocab),
                                tb_cfg.dtype)})
    return wl.WorkloadSpec("multi_teacher",
                           (teacher_a, teacher_b, student),
                           seq_len=seq_len, global_batch=global_batch,
                           mbs=mbs)


def teacher_unembed(params_t, t_cfg: ArchConfig, mesh: Mesh):
    """A teacher's (student-colocated) output layer, replicated on the
    student mesh."""
    w = (params_t["embed"].T if t_cfg.tie_embeddings
         else params_t["unembed"])
    return jax.device_put(jax.device_get(w), shd.replicated(mesh))


# --------------------------------------------------------------------------- #
# Colocated single-jit oracle (dry-run cell + driver reference)
# --------------------------------------------------------------------------- #
def colocated_batch(batch: dict, plan: wl.IterationPlan) -> dict:
    """Permute into the plan's dispatch order, pre-split into
    [n_mb, mbs, ...], and attach the specialist capacity layout — the
    oracle's scan sees exactly the executor's microbatch composition."""
    idx = list(plan.order)
    out = {}
    for k in LM_KEYS:
        v = np.asarray(batch[k])[idx]
        out[k] = jnp.asarray(v.reshape((plan.n_mb, plan.mbs)
                                       + v.shape[1:]))
    act = plan.activation["teacher_b"]
    out["b_idx"] = jnp.asarray(act.idx)
    out["b_valid"] = jnp.asarray(act.valid)
    return out


def build_colocated_step(ta_cfg: ArchConfig, tb_cfg: ArchConfig,
                         s_cfg: ArchConfig, mesh: Mesh, *, mbs: int,
                         seq_len: int, alpha: float = 0.5,
                         temperature: float = 2.0, impl: str = "ref",
                         lr_schedule=None,
                         opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                         return_grads: bool = False):
    """One jit over the pre-microbatched batch from
    :func:`colocated_batch`: per microbatch, both teacher forwards (the
    specialist on its gathered domain samples) + the student loss,
    student grads accumulated in dispatch order, one AdamW update.
    Returns (step, shardings)."""
    s_specs = tf.lm_specs(s_cfg)
    a_specs = tf.lm_specs(ta_cfg)
    b_specs = tf.lm_specs(tb_cfg)
    sp = shd.param_shardings(s_specs, mesh, shd.rules_for(s_cfg, mesh))
    ap = shd.param_shardings(a_specs, mesh,
                             shd.rules_for(ta_cfg, mesh, teacher=True))
    bp = shd.param_shardings(b_specs, mesh,
                             shd.rules_for(tb_cfg, mesh, teacher=True))
    o_shard = shd.opt_state_shardings(s_specs, mesh,
                                      shd.rules_for(s_cfg, mesh))
    dp = shd.dp_axes(mesh) or None
    rep = shd.replicated(mesh)

    def mb_sharding(ndim):
        return NamedSharding(mesh, P(None, dp, *([None] * (ndim - 2))))

    b_shard = {"tokens": mb_sharding(3), "labels": mb_sharding(3),
               "loss_mask": mb_sharding(3), "b_idx": rep, "b_valid": rep}
    hook = _act_hook_for(mesh, mbs, seq_len)
    lr_fn = lr_schedule or functools.partial(schedules.constant,
                                             peak_lr=1e-3)
    kl_impl = "ref" if impl == "ref" else "auto"

    def mb_loss(ps, pa, pb, w_a, w_b, mb, bidx, bval):
        with cm.act_hook(hook):
            h_a = teacher_hidden(pa, ta_cfg, mb["tokens"], impl=impl)
            h_b = specialist_hidden(pb, tb_cfg, mb["tokens"][bidx], bval,
                                    impl=impl)
            loss, _ = student_mt_loss(
                ps, s_cfg, mb, h_a, w_a, h_b, bidx, bval, w_b,
                alpha=alpha, temperature=temperature, impl=impl,
                kl_impl=kl_impl)
            return loss

    grad_fn = jax.value_and_grad(mb_loss)

    def step(params_s, opt_state, params_a, params_b, w_a, w_b, batch,
             step_idx):
        n_mb = batch["tokens"].shape[0]
        mbs_tree = {k: batch[k] for k in LM_KEYS}

        def body(carry, xs):
            g_acc, l_acc = carry
            mb, bidx, bval = xs
            loss, g = grad_fn(params_s, params_a, params_b, w_a, w_b, mb,
                              bidx, bval)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + loss), None

        g0 = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params_s)
        (g_sum, l_sum), _ = jax.lax.scan(
            body, (g0, jnp.float32(0)),
            (mbs_tree, batch["b_idx"], batch["b_valid"]))
        grads = jax.tree_util.tree_map(
            lambda g, p: (g / n_mb).astype(p.dtype), g_sum, params_s)
        loss = l_sum / n_mb
        lr = lr_fn(step_idx)
        new_p, new_opt, gnorm = adamw.update(grads, opt_state, lr, opt_cfg)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "lr": lr}
        if return_grads:
            metrics["grads"] = grads
        return new_p, new_opt, metrics

    out_metrics = {"loss": rep, "grad_norm": rep, "lr": rep}
    if return_grads:
        out_metrics["grads"] = sp
    jitted = jax.jit(step,
                     in_shardings=(sp, o_shard, ap, bp, rep, rep, b_shard,
                                   rep),
                     out_shardings=(sp, o_shard, out_metrics))
    return jitted, {"student": sp, "teacher_a": ap, "teacher_b": bp,
                    "opt": o_shard, "batch": b_shard}
