"""Knowledge-distillation workload (paper §2.2, §3.1, §4.2).

Section construction follows the paper exactly:

* the **teacher body** (all layers, final norm) is a forward-only section
  producing final *hidden states* [B, S, D_t];
* the **teacher's output layer (unembedding) is colocated with the student
  section** — only hidden states cross the section boundary (d_model
  floats/token instead of vocab floats/token, a ~62× traffic cut at
  Qwen-scale vocabularies);
* the student computes CE + KL(p_teacher ‖ p_student) where both logit
  streams are produced *inside the student section*, via the chunked-vocab
  ``distill_kl`` kernel that never materializes [N, V] logits in HBM.

Two execution modes:

* ``build_colocated_step`` — single SPMD jit (dry-run / equivalence oracle);
* ``DistillRuntime``       — disaggregated: teacher and student sections on
  disjoint meshes, hidden states flowing through the MessageQueue with
  fan-out (DP^t × fanout = DP^s).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import messages as msg
from repro.core.executor import Dispatch, mark_start
from repro.core.graph import SectionGraph, build_distill_graph
from repro.core.runtime import MaestroRuntime
from repro.core.types import ArchConfig, ParallelConfig, ShapeConfig
from repro.dist import context as cpx
from repro.dist import sharding as shd
from repro.kernels import ops as kops
from repro.models import attention as att
from repro.models import common as cm
from repro.models import transformer as tf
from repro.optim import adamw, schedules


def _cp_ctx(mesh, *cfgs):
    """Attention-impl context for one section mesh: installs cp_attention
    when the mesh has a non-trivial ``seq`` axis, else a no-op.  Every
    arch running on the mesh must pass the CP support check — an
    attention-free section would otherwise never call the installed impl
    and silently replicate the seq axis.  PP for distillation sections is
    rejected by the callers (the staged loss builder only covers the
    plain LM CE tail)."""
    if dict(mesh.shape).get(shd.AXIS_SEQ, 1) > 1:
        from repro.train.step import _check_pp_cp_support
        for cfg in cfgs:
            _check_pp_cp_support(cfg, "cp")
        impl = cpx.cp_attention_impl(
            mesh, batch_axes=shd.dp_axes(mesh) or None)
        return lambda: att.attention_impl(impl)
    return contextlib.nullcontext


def _reject_pp(mesh, what: str) -> None:
    if dict(mesh.shape).get(shd.AXIS_PIPE, 1) > 1:
        raise NotImplementedError(
            f"pipeline parallelism for {what} is not implemented (the "
            "distillation loss tail — hidden-state KL — is not staged); "
            "use dp/tp/cp for distill sections")


def teacher_hidden(params_t, t_cfg: ArchConfig, tokens, *, impl="auto",
                   remat=True):
    """Teacher body forward: final hidden states (no unembedding)."""
    h, _ = tf.lm_forward(params_t, t_cfg, {"tokens": tokens},
                         impl=impl, remat=remat, logits_out=False)
    return h


def distill_loss(params_s, s_cfg: ArchConfig, batch, h_teacher,
                 teacher_unembed, *, alpha: float = 0.5,
                 temperature: float = 2.0, impl="auto", remat=True,
                 kl_impl="auto"):
    """CE + α·T²·KL from hidden states (teacher output layer colocated)."""
    h_s, aux = tf.lm_forward(params_s, s_cfg, batch, impl=impl,
                             remat=remat, logits_out=False)
    logits = tf.unembed(params_s, s_cfg, h_s)
    ce = cm.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    B, S, Ds = h_s.shape
    w_s = (params_s["embed"].T if s_cfg.tie_embeddings
           else params_s["unembed"])
    mask = batch.get("loss_mask")
    kl = kops.distill_kl(
        h_s.reshape(B * S, Ds), w_s,
        jax.lax.stop_gradient(h_teacher).reshape(B * S, -1),
        jax.lax.stop_gradient(teacher_unembed),
        mask=None if mask is None else mask.reshape(B * S),
        temperature=temperature, impl=kl_impl)
    loss = (1 - alpha) * ce + alpha * (temperature ** 2) * kl
    return loss, {"ce": ce, "kl": kl, "aux": aux}


# --------------------------------------------------------------------------- #
# Colocated SPMD step (dry-run cell + numerical oracle)
# --------------------------------------------------------------------------- #
def build_colocated_step(t_cfg: ArchConfig, s_cfg: ArchConfig, mesh: Mesh,
                         shape: ShapeConfig, parallel: ParallelConfig, *,
                         alpha=0.5, temperature=2.0, impl="ref",
                         lr_schedule=None,
                         opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()):
    """One jit: teacher fwd (frozen) + student train step. Teacher unembed
    is passed separately (it lives with the student per §3.1).

    Dispatches like ``build_train_step``: ``ParallelConfig.cp > 1`` (mesh
    ``seq`` axis) runs both teacher and student attention through
    ``cp_attention``; ``pp > 1`` raises (no staged distill loss)."""
    from repro.train.step import (_act_hook_for, _split_microbatches,
                                  num_microbatches, parallel_regime)
    regime = parallel_regime(mesh, parallel)
    _reject_pp(mesh, "the colocated distill step")
    cp_ctx = (_cp_ctx(mesh, t_cfg, s_cfg) if regime == "cp"
              else contextlib.nullcontext)
    t_rules = shd.rules_for(t_cfg, mesh, teacher=True)
    s_rules = shd.rules_for(s_cfg, mesh)
    t_specs = tf.lm_specs(t_cfg)
    s_specs = tf.lm_specs(s_cfg)
    tp_shard = shd.param_shardings(t_specs, mesh, t_rules)
    sp_shard = shd.param_shardings(s_specs, mesh, s_rules)
    o_shard = shd.opt_state_shardings(s_specs, mesh, s_rules, zero=True)
    batch_specs = {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32),
        "labels": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.float32)}
    b_shard = shd.data_shardings(mesh, batch_specs)
    dp_total = shd.axis_size(mesh, shd.dp_axes(mesh))
    n_micro = num_microbatches(shape, mesh, parallel)
    hook = _act_hook_for(mesh, shape.global_batch // n_micro, shape.seq_len)
    lr_fn = lr_schedule or functools.partial(
        schedules.warmup_cosine, peak_lr=3e-4, warmup_steps=100,
        total_steps=10_000)
    rep = shd.replicated(mesh)

    def loss_fn(p_s, mb, params_t):
        with cm.act_hook(hook), cp_ctx():
            h_t = teacher_hidden(jax.lax.stop_gradient(params_t), t_cfg,
                                 mb["tokens"], impl=impl)
            w_t = (params_t["embed"].T if t_cfg.tie_embeddings
                   else params_t["unembed"])
            # colocated SPMD: vocab-sharded naive KL — per-device logits
            # are [N, V/tp]; the chunked kernel is a *per-shard-local*
            # algorithm (it forces full-vocab gathers under SPMD) and
            # belongs to the disaggregated / Pallas-TPU paths
            return distill_loss(p_s, s_cfg, mb, h_t, w_t, alpha=alpha,
                                temperature=temperature, impl=impl,
                                kl_impl="ref_naive" if impl == "ref"
                                else "auto")

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params_s, opt_state, params_t, batch, step_idx):
        if n_micro == 1:
            (loss, met), grads = grad_fn(params_s, batch, params_t)
        else:
            mbs_tree = _split_microbatches(batch, n_micro, dp_total)

            def micro(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = grad_fn(params_s, mb, params_t)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params_s)
            (g_sum, l_sum), _ = jax.lax.scan(micro, (g0, jnp.float32(0)),
                                             mbs_tree)
            grads = jax.tree_util.tree_map(
                lambda g, p: (g / n_micro).astype(p.dtype), g_sum, params_s)
            loss = l_sum / n_micro
        lr = lr_fn(step_idx)
        new_p, new_opt, gnorm = adamw.update(grads, opt_state, lr, opt_cfg)
        return new_p, new_opt, {"loss": loss.astype(jnp.float32),
                                "grad_norm": gnorm, "lr": lr}

    jitted = jax.jit(step,
                     in_shardings=(sp_shard, o_shard, tp_shard, b_shard,
                                   rep),
                     out_shardings=(sp_shard, o_shard,
                                    {"loss": rep, "grad_norm": rep,
                                     "lr": rep}),
                     donate_argnums=(0, 1))
    return jitted, {"teacher": tp_shard, "student": sp_shard,
                    "opt": o_shard, "batch": b_shard}


# --------------------------------------------------------------------------- #
# Disaggregated runtime (paper-faithful)
# --------------------------------------------------------------------------- #
class DistillRuntime:
    """Teacher and student sections on disjoint meshes, hidden states
    flowing through the M-to-N message queue with fan-out.

    Execution is an instantiation of the generic compound executor
    (``repro.core.executor.CompoundExecutor``): the teacher's forward and
    the student's step are Dispatches on the section workers, the
    hidden-state handoff is a blocking MessageQueue pull, and every
    iteration's realized timeline is kept on ``last_execution`` —
    distillation and MLLM training share one execution engine."""

    def __init__(self, t_cfg: ArchConfig, s_cfg: ArchConfig, *,
                 teacher_parallel: ParallelConfig,
                 student_parallel: ParallelConfig,
                 devices=None, alpha=0.5, temperature=2.0, impl="ref",
                 lr=1e-3):
        fanout = student_parallel.dp // teacher_parallel.dp
        assert teacher_parallel.dp * fanout == student_parallel.dp, \
            "fanout constraint (paper eq. 1) violated"
        self.fanout = fanout
        self.t_cfg, self.s_cfg = t_cfg, s_cfg
        self.alpha, self.temperature = alpha, temperature
        self.graph = build_distill_graph(
            t_cfg, s_cfg, fanout=fanout,
            teacher_parallel=teacher_parallel,
            student_parallel=student_parallel)
        self.rt = MaestroRuntime(self.graph, devices)
        self.executor = self.rt.executor()
        self.last_execution = None
        tm, sm = self.rt.mesh("teacher"), self.rt.mesh("student")
        _reject_pp(tm, "the teacher section")
        _reject_pp(sm, "the student section")
        t_cp_ctx, s_cp_ctx = _cp_ctx(tm, t_cfg), _cp_ctx(sm, s_cfg)

        t_rules = shd.rules_for(t_cfg, tm, teacher=True)
        s_rules = shd.rules_for(s_cfg, sm)
        self.t_specs = tf.lm_specs(t_cfg)
        self.s_specs = tf.lm_specs(s_cfg)
        self.tp_shard = shd.param_shardings(self.t_specs, tm, t_rules)
        self.sp_shard = shd.param_shardings(self.s_specs, sm, s_rules)
        self.o_shard = shd.opt_state_shardings(self.s_specs, sm, s_rules)
        self.h_shard = shd.dp_sharding(sm, 3)      # [B, S, D_t] handoff

        def teacher_fwd(params_t, tokens):
            with t_cp_ctx():
                return teacher_hidden(params_t, t_cfg, tokens, impl=impl)

        def student_step(params_s, opt_state, batch, h_t, w_t, step_idx):
            def loss_fn(p):
                with s_cp_ctx():
                    return distill_loss(p, s_cfg, batch, h_t, w_t,
                                        alpha=alpha,
                                        temperature=temperature,
                                        impl=impl,
                                        kl_impl="ref" if impl == "ref"
                                        else "auto")
            (loss, met), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params_s)
            new_p, new_opt, gnorm = adamw.update(grads, opt_state,
                                                 jnp.float32(lr))
            return new_p, new_opt, {"loss": loss, "ce": met["ce"],
                                    "kl": met["kl"], "grad_norm": gnorm}

        self.teacher_fwd = jax.jit(
            teacher_fwd,
            in_shardings=(self.tp_shard, shd.dp_sharding(tm)))
        rep_s = shd.replicated(sm)
        batch_shard = {k: shd.dp_sharding(sm)
                       for k in ("tokens", "labels", "loss_mask")}
        self.student_step = jax.jit(
            student_step, donate_argnums=(1,),
            in_shardings=(self.sp_shard, self.o_shard, batch_shard,
                          self.h_shard, rep_s, rep_s),
            out_shardings=(self.sp_shard, self.o_shard,
                           {"loss": rep_s, "ce": rep_s, "kl": rep_s,
                            "grad_norm": rep_s}))

    # ------------------------------------------------------------------ #
    def init(self, rng) -> Tuple:
        r1, r2 = jax.random.split(rng)
        params_t = jax.device_put(cm.init_params(self.t_specs, r1),
                                  self.tp_shard)
        params_s = jax.device_put(cm.init_params(self.s_specs, r2),
                                  self.sp_shard)
        opt = jax.device_put(adamw.init(params_s), self.o_shard)
        return params_t, params_s, opt

    def teacher_unembed(self, params_t):
        w = (params_t["embed"].T if self.t_cfg.tie_embeddings
             else params_t["unembed"])
        return jax.device_put(jax.device_get(w),
                              shd.replicated(self.rt.mesh("student")))

    def train_iteration(self, params_t, params_s, opt, batch, step_idx, *,
                        w_t=None, timeout: float = 300.0):
        """One global-batch iteration on the compound executor: teacher
        fwd (its own mesh/worker) → hidden-state push → student pull +
        step, both as executor Dispatches so the realized timeline is
        recorded.  Returns (params_s, opt, metrics).

        ``timeout`` bounds both the cross-section pull and the drain —
        the pull now races the teacher's first-call jit compile, so it
        must outlive it (the queue's 30s default does not)."""
        q = self.rt.queue
        tm = self.rt.mesh("teacher")
        tokens_t = jax.device_put(batch["tokens"], shd.dp_sharding(tm))
        if w_t is None:
            w_t = self.teacher_unembed(params_t)
        sb = {k: jax.device_put(
            v, shd.dp_sharding(self.rt.mesh("student")))
            for k, v in batch.items()}
        key = f"h_t/{int(step_idx)}"

        def produce():
            h = self.teacher_fwd(params_t, tokens_t)
            q.push("teacher", "student", key, h)
            # returning the array lets the executor block on it, so the
            # teacher's timeline event covers the realized forward (and
            # the teacher mesh is quiet when the task ends)
            return h

        def consume():
            # the blocking pull IS the cross-section dependency: the
            # student's first touch of h_t (and its jit trace) happens
            # strictly after the teacher's push
            h_t = q.pull("teacher", "student", key, sharding=self.h_shard,
                         timeout=timeout)
            mark_start()          # teacher wait is idle, not busy
            return self.student_step(params_s, opt, sb, h_t, w_t,
                                     jnp.int32(step_idx))

        tag = f"step{int(step_idx)}"
        res = self.executor.run([Dispatch("teacher", f"fwd{int(step_idx)}",
                                          produce),
                                 Dispatch("student", tag, consume)],
                                timeout=timeout)
        self.last_execution = res
        params_s, opt, metrics = res.results[("student", tag)]
        return params_s, opt, metrics

    def shutdown(self):
        self.rt.shutdown()
