"""Knowledge-distillation workload (paper §2.2, §3.1, §4.2).

Section construction follows the paper exactly:

* the **teacher body** (all layers, final norm) is a forward-only section
  producing final *hidden states* [B, S, D_t];
* the **teacher's output layer (unembedding) is colocated with the student
  section** — only hidden states cross the section boundary (d_model
  floats/token instead of vocab floats/token, a ~62× traffic cut at
  Qwen-scale vocabularies);
* the student computes CE + KL(p_teacher ‖ p_student) where both logit
  streams are produced *inside the student section*, via the chunked-vocab
  ``distill_kl`` kernel that never materializes [N, V] logits in HBM.

Two execution modes:

* ``build_colocated_step`` — single SPMD jit (dry-run / equivalence oracle);
* ``DistillRuntime``       — disaggregated: teacher and student sections on
  disjoint meshes, hidden states flowing through the MessageQueue with
  fan-out (DP^t × fanout = DP^s).
"""
from __future__ import annotations

import contextlib
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import workload as wl
from repro.core.types import ArchConfig, ParallelConfig, ShapeConfig
from repro.dist import context as cpx
from repro.dist import sharding as shd
from repro.kernels import ops as kops
from repro.models import attention as att
from repro.models import common as cm
from repro.models import transformer as tf
from repro.optim import adamw, schedules


def _cp_ctx(mesh, *cfgs):
    """Attention-impl context for one section mesh: installs cp_attention
    when the mesh has a non-trivial ``seq`` axis, else a no-op.  Every
    arch running on the mesh must pass the CP support check — an
    attention-free section would otherwise never call the installed impl
    and silently replicate the seq axis.  PP for distillation sections is
    rejected by the callers (the staged loss builder only covers the
    plain LM CE tail)."""
    if dict(mesh.shape).get(shd.AXIS_SEQ, 1) > 1:
        from repro.train.step import _check_pp_cp_support
        for cfg in cfgs:
            _check_pp_cp_support(cfg, "cp")
        impl = cpx.cp_attention_impl(
            mesh, batch_axes=shd.dp_axes(mesh) or None)
        return lambda: att.attention_impl(impl)
    return contextlib.nullcontext


def teacher_hidden(params_t, t_cfg: ArchConfig, tokens, *, impl="auto",
                   remat=True):
    """Teacher body forward: final hidden states (no unembedding)."""
    h, _ = tf.lm_forward(params_t, t_cfg, {"tokens": tokens},
                         impl=impl, remat=remat, logits_out=False)
    return h


def distill_loss(params_s, s_cfg: ArchConfig, batch, h_teacher,
                 teacher_unembed, *, alpha: float = 0.5,
                 temperature: float = 2.0, impl="auto", remat=True,
                 kl_impl="auto"):
    """CE + α·T²·KL from hidden states (teacher output layer colocated)."""
    h_s, aux = tf.lm_forward(params_s, s_cfg, batch, impl=impl,
                             remat=remat, logits_out=False)
    logits = tf.unembed(params_s, s_cfg, h_s)
    ce = cm.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    B, S, Ds = h_s.shape
    w_s = (params_s["embed"].T if s_cfg.tie_embeddings
           else params_s["unembed"])
    mask = batch.get("loss_mask")
    kl = kops.distill_kl(
        h_s.reshape(B * S, Ds), w_s,
        jax.lax.stop_gradient(h_teacher).reshape(B * S, -1),
        jax.lax.stop_gradient(teacher_unembed),
        mask=None if mask is None else mask.reshape(B * S),
        temperature=temperature, impl=kl_impl)
    loss = (1 - alpha) * ce + alpha * (temperature ** 2) * kl
    return loss, {"ce": ce, "kl": kl, "aux": aux}


# --------------------------------------------------------------------------- #
# Colocated SPMD step (dry-run cell + numerical oracle)
# --------------------------------------------------------------------------- #
def build_colocated_step(t_cfg: ArchConfig, s_cfg: ArchConfig, mesh: Mesh,
                         shape: ShapeConfig, parallel: ParallelConfig, *,
                         alpha=0.5, temperature=2.0, impl="ref",
                         lr_schedule=None,
                         opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()):
    """One jit: teacher fwd (frozen) + student train step. Teacher unembed
    is passed separately (it lives with the student per §3.1).

    Dispatches like ``build_train_step``: ``ParallelConfig.cp > 1`` (mesh
    ``seq`` axis) runs both teacher and student attention through
    ``cp_attention``; ``pp > 1`` raises (no staged distill loss)."""
    from repro.train.step import (_act_hook_for, _split_microbatches,
                                  num_microbatches)
    # consolidated section-parallelism validation (one path for every
    # workload section, colocated or disaggregated): PP raises with the
    # section + mesh axis named (the distill loss tail is not staged)
    regime = wl.validate_section_parallel(
        "distill.colocated(teacher)", t_cfg, parallel, mesh)
    wl.validate_section_parallel(
        "distill.colocated(student)", s_cfg, parallel, mesh)
    cp_ctx = (_cp_ctx(mesh, t_cfg, s_cfg) if regime == "cp"
              else contextlib.nullcontext)
    t_rules = shd.rules_for(t_cfg, mesh, teacher=True)
    s_rules = shd.rules_for(s_cfg, mesh)
    t_specs = tf.lm_specs(t_cfg)
    s_specs = tf.lm_specs(s_cfg)
    tp_shard = shd.param_shardings(t_specs, mesh, t_rules)
    sp_shard = shd.param_shardings(s_specs, mesh, s_rules)
    o_shard = shd.opt_state_shardings(s_specs, mesh, s_rules, zero=True)
    batch_specs = {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32),
        "labels": jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.float32)}
    b_shard = shd.data_shardings(mesh, batch_specs)
    dp_total = shd.axis_size(mesh, shd.dp_axes(mesh))
    n_micro = num_microbatches(shape, mesh, parallel)
    hook = _act_hook_for(mesh, shape.global_batch // n_micro, shape.seq_len)
    lr_fn = lr_schedule or functools.partial(
        schedules.warmup_cosine, peak_lr=3e-4, warmup_steps=100,
        total_steps=10_000)
    rep = shd.replicated(mesh)

    def loss_fn(p_s, mb, params_t):
        with cm.act_hook(hook), cp_ctx():
            h_t = teacher_hidden(jax.lax.stop_gradient(params_t), t_cfg,
                                 mb["tokens"], impl=impl)
            w_t = (params_t["embed"].T if t_cfg.tie_embeddings
                   else params_t["unembed"])
            # colocated SPMD: vocab-sharded naive KL — per-device logits
            # are [N, V/tp]; the chunked kernel is a *per-shard-local*
            # algorithm (it forces full-vocab gathers under SPMD) and
            # belongs to the disaggregated / Pallas-TPU paths
            return distill_loss(p_s, s_cfg, mb, h_t, w_t, alpha=alpha,
                                temperature=temperature, impl=impl,
                                kl_impl="ref_naive" if impl == "ref"
                                else "auto")

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params_s, opt_state, params_t, batch, step_idx):
        if n_micro == 1:
            (loss, met), grads = grad_fn(params_s, batch, params_t)
        else:
            mbs_tree = _split_microbatches(batch, n_micro, dp_total)

            def micro(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = grad_fn(params_s, mb, params_t)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params_s)
            (g_sum, l_sum), _ = jax.lax.scan(micro, (g0, jnp.float32(0)),
                                             mbs_tree)
            grads = jax.tree_util.tree_map(
                lambda g, p: (g / n_micro).astype(p.dtype), g_sum, params_s)
            loss = l_sum / n_micro
        lr = lr_fn(step_idx)
        new_p, new_opt, gnorm = adamw.update(grads, opt_state, lr, opt_cfg)
        return new_p, new_opt, {"loss": loss.astype(jnp.float32),
                                "grad_norm": gnorm, "lr": lr}

    jitted = jax.jit(step,
                     in_shardings=(sp_shard, o_shard, tp_shard, b_shard,
                                   rep),
                     out_shardings=(sp_shard, o_shard,
                                    {"loss": rep, "grad_norm": rep,
                                     "lr": rep}),
                     donate_argnums=(0, 1))
    return jitted, {"teacher": tp_shard, "student": sp_shard,
                    "opt": o_shard, "batch": b_shard}


# --------------------------------------------------------------------------- #
# Declarative workload spec + thin runtime wrapper (paper-faithful)
# --------------------------------------------------------------------------- #
def distill_spec(t_cfg: ArchConfig, s_cfg: ArchConfig, *,
                 teacher_parallel: ParallelConfig,
                 student_parallel: ParallelConfig,
                 alpha: float = 0.5, temperature: float = 2.0,
                 impl: str = "ref") -> wl.WorkloadSpec:
    """KD as a declaration: a forward-only teacher section emitting final
    hidden states, and the critical student section computing CE + KL
    with the teacher's (colocated) output layer as a const.  Left
    shape-polymorphic (no global_batch/seq_len): the generic runtime
    binds shapes from the first batch, one microbatch per iteration."""
    hidden = wl.Port("hidden", (wl.SEQ, t_cfg.d_model), t_cfg.dtype)

    def teacher_fn(pt, x):
        return {"hidden": teacher_hidden(pt, t_cfg, x["tokens"],
                                         impl=impl)}

    def student_fn(ps, x):
        batch = {"tokens": x["tokens"], "labels": x["labels"],
                 "loss_mask": x["loss_mask"]}
        loss, met = distill_loss(
            ps, s_cfg, batch, x["teacher.hidden"], x["w_t"], alpha=alpha,
            temperature=temperature, impl=impl,
            kl_impl="ref" if impl == "ref" else "auto")
        return loss, {"ce": met["ce"], "kl": met["kl"]}

    teacher = wl.SectionSpec(
        "teacher", t_cfg, teacher_parallel, teacher_fn,
        tf.lm_specs(t_cfg),
        inputs={"tokens": wl.Field((wl.SEQ,), "int32")},
        emits=(hidden,), mode="fwd_only")
    student = wl.SectionSpec(
        "student", s_cfg, student_parallel, student_fn,
        tf.lm_specs(s_cfg),
        inputs={"tokens": wl.Field((wl.SEQ,), "int32"),
                "labels": wl.Field((wl.SEQ,), "int32"),
                "loss_mask": wl.Field((wl.SEQ,), "float32", fill=1.0)},
        consumes=(wl.Consume("teacher", hidden),),
        loss=True, loss_aux=True, critical=True,
        consts={"w_t": wl.Field((t_cfg.d_model, t_cfg.padded_vocab),
                                t_cfg.dtype)})
    return wl.WorkloadSpec("distill", (teacher, student))


class DistillRuntime:
    """Teacher and student sections on disjoint meshes, hidden states
    flowing through the M-to-N message queue with fan-out.

    Now a thin declaration over the generic
    :class:`~repro.core.workload.CompoundRuntime` (``distill_spec``
    above): the teacher's forward and the student's loss are plain
    section fns; executor wiring, jitted AdamW, grad-norm and the
    realized timeline are the shared machinery distillation and MLLM
    training get from one place."""

    def __init__(self, t_cfg: ArchConfig, s_cfg: ArchConfig, *,
                 teacher_parallel: ParallelConfig,
                 student_parallel: ParallelConfig,
                 devices=None, alpha=0.5, temperature=2.0, impl="ref",
                 lr=1e-3):
        fanout = student_parallel.dp // teacher_parallel.dp
        assert teacher_parallel.dp * fanout == student_parallel.dp, \
            "fanout constraint (paper eq. 1) violated"
        self.fanout = fanout
        self.t_cfg, self.s_cfg = t_cfg, s_cfg
        self.alpha, self.temperature = alpha, temperature
        spec = distill_spec(t_cfg, s_cfg,
                            teacher_parallel=teacher_parallel,
                            student_parallel=student_parallel,
                            alpha=alpha, temperature=temperature,
                            impl=impl)
        self._crt = wl.CompoundRuntime(
            spec, devices=devices, impl=impl,
            lr_schedule=functools.partial(schedules.constant,
                                          peak_lr=lr))
        self.rt = self._crt.rt
        self.graph = self._crt.graph
        self.executor = self._crt.executor
        self.last_execution = None

    # ------------------------------------------------------------------ #
    def init(self, rng) -> Tuple:
        params, opts = self._crt.init(rng)
        return params["teacher"], params["student"], opts["student"]

    def teacher_unembed(self, params_t):
        w = (params_t["embed"].T if self.t_cfg.tie_embeddings
             else params_t["unembed"])
        return jax.device_put(jax.device_get(w),
                              shd.replicated(self.rt.mesh("student")))

    def train_iteration(self, params_t, params_s, opt, batch, step_idx, *,
                        w_t=None, timeout: float = 300.0):
        """One global-batch iteration on the compound executor: teacher
        fwd (its own mesh/worker) → hidden-state push → student pull +
        loss/grads, wavefront-submitted Dispatches with the realized
        timeline on ``last_execution``.  Returns (params_s, opt,
        metrics)."""
        if w_t is None:
            w_t = self.teacher_unembed(params_t)
        params, opts, metrics = self._crt.train_iteration(
            {"teacher": params_t, "student": params_s},
            {"student": opt}, batch, step_idx,
            consts={"student": {"w_t": w_t}}, timeout=timeout)
        self.last_execution = metrics["execution"]
        return params["student"], opts["student"], metrics

    def shutdown(self):
        self._crt.shutdown()
