"""Gradient compression for DP all-reduce: bf16 and int8 + error feedback.

At 1000+-node scale the DP gradient all-reduce crosses DCI links; halving
(bf16) or quartering (int8) its bytes is a direct win on the collective
roofline term.  Int8 uses per-tensor max-abs scaling and an error-feedback
residual (the quantization error is added back into the next step's
gradient) — the standard trick that keeps SGD/Adam convergence unbiased in
the long run.

``compressed_psum_*`` are shard_map-compatible primitives (reduce across a
named axis); ``ErrorFeedback`` carries the residual state.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def _quant_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_int8(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-on-the-wire psum: quantize locally, sum int32, average scales.

    Bytes on the wire: 1/4 of fp32 (plus one scalar)."""
    q, scale = _quant_int8(x)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    scale_sum = jax.lax.psum(scale, axis_name)
    n = jax.lax.psum(jnp.float32(1.0), axis_name)
    # each shard contributed q_i * scale_i; approximate with mean scale
    return (total.astype(jnp.float32) * (scale_sum / n)).astype(x.dtype)


def compressed_psum_bf16(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    return jax.lax.psum(x.astype(jnp.bfloat16), axis_name).astype(x.dtype)


class ErrorFeedback(NamedTuple):
    residual: Any                 # same tree as grads, fp32


def ef_init(grads_like) -> ErrorFeedback:
    return ErrorFeedback(jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), grads_like))


def ef_compress_tree(grads, ef: ErrorFeedback, axis_name: str,
                     method: str = "int8"):
    """Apply error-feedback compression + psum across ``axis_name`` to a
    gradient tree (call inside shard_map). Returns (reduced, new_ef)."""
    n = None

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        if method == "int8":
            q, scale = _quant_int8(corrected)
            local_deq = _dequant_int8(q, scale)
            new_r = corrected - local_deq
            total = jax.lax.psum(q.astype(jnp.int32), axis_name)
            scale_sum = jax.lax.psum(scale, axis_name)
            cnt = jax.lax.psum(jnp.float32(1.0), axis_name)
            out = total.astype(jnp.float32) * (scale_sum / cnt) / cnt
        elif method == "bf16":
            sent = corrected.astype(jnp.bfloat16)
            new_r = corrected - sent.astype(jnp.float32)
            cnt = jax.lax.psum(jnp.float32(1.0), axis_name)
            out = jax.lax.psum(sent, axis_name).astype(jnp.float32) / cnt
        else:
            cnt = jax.lax.psum(jnp.float32(1.0), axis_name)
            out = jax.lax.psum(corrected, axis_name) / cnt
            new_r = jnp.zeros_like(corrected)
        return out.astype(g.dtype), new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    reduced = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_ef = ErrorFeedback(jax.tree_util.tree_unflatten(
        treedef, [o[1] for o in outs]))
    return reduced, new_ef


def wire_bytes(tree, method: str) -> int:
    """Bytes a DP all-reduce of ``tree`` puts on the wire per rank."""
    per = {"int8": 1, "bf16": 2, "none": 4}[method]
    return sum(x.size * per for x in jax.tree_util.tree_leaves(tree))
