"""Gradient compression for DP all-reduce: bf16 and int8 + error feedback.

At 1000+-node scale the DP gradient all-reduce crosses DCI links; halving
(bf16) or quartering (int8) its bytes is a direct win on the collective
roofline term.  Int8 uses per-tensor max-abs scaling and an error-feedback
residual (the quantization error is added back into the next step's
gradient) — the standard trick that keeps SGD/Adam convergence unbiased in
the long run.

Wire-honest reduction
---------------------
A naive ``psum(q.astype(int32))`` puts 4-byte words on the wire and saves
nothing; worse, this jaxlib's CPU backend *upcasts* a bf16 ``psum`` to an
f32 all-reduce (the convert is fused in front of the collective), so even
bf16 would ship fp32 bytes.  Both methods therefore use the classic
compressed-all-reduce decomposition, which keeps the compressed dtype on
the wire end to end:

1. compress locally, flatten, pad to a multiple of ``n`` and split into
   ``n`` chunks;
2. ``all_to_all`` the chunks (reduce-scatter's data movement:
   ``(n-1)/n`` of the payload, compressed dtype);
3. dequantize **per source** (each source's own scale — exact, unlike a
   mean-scale approximation) and sum in f32;
4. re-compress the reduced chunk and ``all_gather`` it
   (``(n-1)/n`` of the payload, compressed dtype).

Ring-model wire bytes per rank: ``2 (n-1)/n · M`` at the compressed width
vs ``2 (n-1)/n · 4M`` for the fp32 all-reduce — exactly 1/2 (bf16) and 1/4
(int8), independent of ``n`` (plus O(n) scalars for scales).

Error handling: the phase-1 quantization error is captured by the
``ErrorFeedback`` residual.  The phase-2 (re-compression of the reduced
chunk) error is *not* fed back — it is bounded by ``max|sum|/254`` per
element for int8 and one bf16 ulp (2^-8 relative) for bf16, and is
documented in docs/perf.md.

``compressed_psum_*`` are shard_map-compatible primitives (reduce across a
named axis); ``ErrorFeedback`` carries the residual state.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

METHODS = ("none", "bf16", "int8")


def _quant_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale


def _axis_size(axis_name) -> int:
    # psum of a Python constant over a named axis is static (the axis env
    # knows the size at trace time) — verified on this jax version
    return int(jax.lax.psum(1, axis_name))


def _chunk(flat: jnp.ndarray, n: int) -> Tuple[jnp.ndarray, int]:
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(n, -1), pad


def _unchunk(flat: jnp.ndarray, pad: int, shape) -> jnp.ndarray:
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum_int8(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """int8-on-the-wire sum-reduce across ``axis_name`` (inside shard_map).

    all_to_all int8 chunks → exact per-source dequant-sum in f32 →
    requantize → all_gather int8.  Wire bytes: 1/4 of the fp32 all-reduce
    (ring model), independent of the axis size."""
    n = _axis_size(axis_name)
    q, scale = _quant_int8(x)
    if n == 1:
        return _dequant_int8(q, scale).astype(x.dtype)
    chunks, pad = _chunk(q.reshape(-1), n)                    # [n, C] int8
    recv = jax.lax.all_to_all(chunks, axis_name, 0, 0, tiled=True)
    scales = jax.lax.all_gather(scale, axis_name)             # [n] f32
    part = jnp.einsum("nc,n->c", recv.astype(jnp.float32), scales)
    rq, rscale = _quant_int8(part)                            # phase 2
    out_q = jax.lax.all_gather(rq, axis_name, tiled=True)     # [n·C] int8
    out_s = jax.lax.all_gather(rscale, axis_name)             # [n] f32
    out = (out_q.reshape(n, -1).astype(jnp.float32)
           * out_s[:, None]).reshape(-1)
    return _unchunk(out, pad, x.shape).astype(x.dtype)


def compressed_psum_bf16(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """bf16-on-the-wire sum-reduce across ``axis_name`` (inside shard_map).

    Same decomposition as int8 (a plain bf16 ``psum`` is upcast to f32 by
    the backend — and so is a bf16 all_to_all: the convert fuses in front
    of the collective).  The bf16 payload is therefore *bitcast to u16*,
    a native 2-byte integer the backend ships verbatim: all_to_all u16
    chunks → bitcast back → f32 sum → round to bf16 → bitcast → all_gather.
    Wire bytes: 1/2 of the fp32 all-reduce (ring model)."""
    n = _axis_size(axis_name)
    sent = x.astype(jnp.bfloat16)
    if n == 1:
        return sent.astype(x.dtype)
    bits = jax.lax.bitcast_convert_type(sent.reshape(-1), jnp.uint16)
    chunks, pad = _chunk(bits, n)                             # [n, C] u16
    recv = jax.lax.all_to_all(chunks, axis_name, 0, 0, tiled=True)
    recv_bf = jax.lax.bitcast_convert_type(recv, jnp.bfloat16)
    part = recv_bf.astype(jnp.float32).sum(axis=0)
    out_bits = jax.lax.all_gather(
        jax.lax.bitcast_convert_type(part.astype(jnp.bfloat16), jnp.uint16),
        axis_name, tiled=True)                                # [n·C] u16
    out = jax.lax.bitcast_convert_type(out_bits, jnp.bfloat16)
    return _unchunk(out.astype(jnp.float32), pad,
                    x.shape).astype(x.dtype)


class ErrorFeedback(NamedTuple):
    residual: Any                 # same tree as grads, fp32


def ef_init(grads_like) -> ErrorFeedback:
    return ErrorFeedback(jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), grads_like))


def ef_compress_tree(grads, ef: ErrorFeedback, axis_name,
                     method: str = "int8", *, mean: bool = True):
    """Apply error-feedback compression + reduce across ``axis_name`` to a
    gradient tree (call inside shard_map). Returns (reduced, new_ef).

    ``mean=True`` averages across the axis (per-shard full gradients);
    ``mean=False`` sums (per-shard *partial* gradients, e.g. each shard
    holding its local microbatch slice's contribution to a global-mean
    loss).  The residual captures the local (phase-1) compression error;
    it is added into the next step's gradient before compressing, so the
    bias introduced by quantization cancels over steps."""
    if method not in METHODS:
        raise ValueError(f"unknown compression method {method!r}; "
                         f"expected one of {METHODS}")
    cnt = float(_axis_size(axis_name)) if mean else 1.0

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        if method == "int8":
            q, scale = _quant_int8(corrected)
            new_r = corrected - _dequant_int8(q, scale)
            out = compressed_psum_int8(corrected, axis_name) / cnt
        elif method == "bf16":
            sent = corrected.astype(jnp.bfloat16)
            new_r = corrected - sent.astype(jnp.float32)
            out = compressed_psum_bf16(corrected, axis_name) / cnt
        else:
            out = jax.lax.psum(corrected, axis_name) / cnt
            new_r = jnp.zeros_like(corrected)
        return out.astype(g.dtype), new_r

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    reduced = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_ef = ErrorFeedback(jax.tree_util.tree_unflatten(
        treedef, [o[1] for o in outs]))
    return reduced, new_ef


def wire_bytes(tree, method: str) -> int:
    """Per-rank payload bytes a DP reduction of ``tree`` compresses to
    (the ring model multiplies every method by the same ``2(n-1)/n``, so
    the payload ratio IS the wire ratio)."""
    per = {"int8": 1, "bf16": 2, "none": 4}[method]
    return sum(x.size * per for x in jax.tree_util.tree_leaves(tree))
