"""AdamW with fp32 master weights and ZeRO-shardable state.

State layout mirrors the parameter tree:  ``mu``/``nu``/``master`` get the
parameter's sharding spec *extended over free mesh axes* (ZeRO) by
``repro.dist.sharding.opt_state_shardings``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class DonatedStateError(RuntimeError):
    """A params/optimizer-state tree holds deleted (donated) buffers.

    The step jits donate their optimizer-state argument, and
    ``jax.device_put`` is a no-copy identity when the target sharding
    already matches — so "fresh" state derived from a tree a previous
    step donated can silently alias the dead buffers and crash deep
    inside the next compiled call.
    """


def deleted_leaf_paths(tree) -> list:
    """Keypaths of every leaf of ``tree`` deleted by a donating jit.
    Tracers and array-likes without real buffers are skipped, so this is
    safe to call from inside jitted update fns (returns []).  The
    donation linter (``repro.analysis.donation``) builds on this to lint
    whole runtimes instead of single trees."""
    dead = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        deleted = getattr(leaf, "is_deleted", None)
        if not callable(deleted):
            continue
        try:
            if deleted():
                dead.append(jax.tree_util.keystr(path))
        except Exception:      # tracer / array-like without real buffers
            continue
    return dead


def check_live(tree, what: str = "optimizer state") -> None:
    """Raise :class:`DonatedStateError` if any leaf of ``tree`` was
    deleted by a donating jit.  A no-op under tracing (tracers carry no
    buffers), so it is safe to call from inside jitted update fns."""
    dead = deleted_leaf_paths(tree)
    if dead:
        raise DonatedStateError(
            f"{what} contains deleted (donated) buffers (first dead "
            f"leaf: {dead[0]!r}) — this tree was consumed by a previous "
            "donating update step. Re-`place` fresh state "
            "(CompoundRuntime.place / jax.device_put of a host copy) "
            "instead of re-using a tree that has already been donated.")


class AdamWState(NamedTuple):
    step: jnp.ndarray            # scalar int32
    mu: Any                      # fp32 tree
    nu: Any                      # fp32 tree
    master: Any                  # fp32 master weights


class AdamWConfig(NamedTuple):
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init(params) -> AdamWState:
    # copy=True: master must never alias params (donation safety)
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.array(x, jnp.float32, copy=True), t)
    zeros = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree_util.tree_map(jnp.copy, zeros), f32(params))


def state_specs(param_specs):
    """ShapeDtypeStruct tree of the state given param ShapeDtypeStructs."""
    f32 = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_specs)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), f32, f32, f32)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state: AdamWState, lr: jnp.ndarray,
           cfg: AdamWConfig = AdamWConfig(), param_dtype=jnp.bfloat16,
           gnorm=None):
    """One AdamW step. Returns (new_params_in_param_dtype, new_state,
    grad_norm).

    ``gnorm`` overrides the clip norm with a precomputed value — the
    disaggregated runtimes pass the *joint* norm across all sections so
    per-section updates clip exactly like one colocated update would.
    Passing it with clipping disabled raises: the caller clearly expects
    the joint norm to drive the update, and it would be silently ignored.
    """
    check_live(state, "optimizer state")
    if gnorm is not None and cfg.clip_norm <= 0:
        raise ValueError(
            f"adamw.update: gnorm= was passed but clipping is disabled "
            f"(clip_norm={cfg.clip_norm}) — the precomputed joint norm "
            "would be silently ignored; enable clip_norm or drop gnorm=")
    if gnorm is None:
        gnorm = global_norm(grads)
    scale = jnp.where(gnorm > cfg.clip_norm, cfg.clip_norm / gnorm, 1.0) \
        if cfg.clip_norm > 0 else jnp.float32(1.0)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, mu, nu, m):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / bc1
        nu_hat = nu / bc2
        step_v = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        m = m - lr * (step_v + cfg.weight_decay * m)
        return mu, nu, m

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    flat_ma = treedef.flatten_up_to(state.master)
    out = [upd(g, mu, nu, m) for g, mu, nu, m in
           zip(flat_g, flat_mu, flat_nu, flat_ma)]
    mu = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    nu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    master = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree_util.tree_map(
        lambda m, g: m.astype(g.dtype) if g.dtype != jnp.float32 else m,
        master, grads)
    return new_params, AdamWState(step, mu, nu, master), gnorm
