"""Multimodal-LLM compound workload (paper §2.1/§4.1) on the compound
executor: ViT encoder section + LLM backbone section with data-dependent
activation and wavefront-scheduled dispatch."""
