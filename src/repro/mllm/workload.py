"""MLLM compound workload: ViT encoder section → LLM backbone section.

The headline Maestro workload (§2.1/§4.1): modality sections activate
*per sample* — text-only samples bypass the vision section entirely — and
the wavefront scheduler reorders samples so the critical (LLM) section
never stalls on vision work.  Two execution modes share one arithmetic:

* :func:`build_colocated_step` — ONE jit: scan over microbatches, each
  doing ViT-encode of its image samples (gathered to the static per-
  microbatch capacity) + LM loss with image-slot injection, grads
  accumulated in microbatch order.  This is the numerical oracle.
* :class:`MLLMRuntime` — disaggregated on the compound executor: the ViT
  section runs fwd/bwd tasks for *image-bearing microbatches only* on its
  own carved mesh, embeddings / embedding-cotangents cross the
  MessageQueue, and the per-iteration microbatch composition comes from
  the wavefront dispatch order.

Because both modes perform the same per-microbatch computations in the
same order (the dynamic path only *skips* work whose contribution is an
exact zero), the disaggregated per-step loss and grads match the
colocated oracle bit-for-bit on equal section layouts — driver-verified
on mixed and all-text batches (``tests/drivers/driver_mllm_runtime.py``).

Static vs dynamic shapes: each microbatch has a *static* vision capacity
(= its sample count); image samples are gathered into that capacity and
zero-padded — padding only ever exists inside a microbatch.  Whether a
microbatch dispatches vision work at all is dynamic (data-dependent
activation).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import workload as wl
from repro.core.scheduler import ScheduleResult
from repro.core.types import ArchConfig, ParallelConfig
from repro.dist import sharding as shd
from repro.models import common as cm
from repro.models import vlm
from repro.models.model import Model, build_model
from repro.optim import adamw, schedules
from repro.train.step import _act_hook_for

#: batch keys the LM step consumes (vision arrives as ``image_embeds``)
LM_KEYS = ("tokens", "labels", "loss_mask", "image_pos", "image_valid")


# --------------------------------------------------------------------------- #
# Shared per-microbatch arithmetic (oracle ≡ disaggregated, bit-for-bit)
# --------------------------------------------------------------------------- #
def vit_forward(pv, vit_cfg: ArchConfig, patches, valid, *,
                impl: str = "ref", remat: bool = True):
    """ViT-encode the gathered image samples of one microbatch and mask
    padding rows.  patches [cap, P, pd], valid [cap] → emb [cap, K, Vd]."""
    emb = vlm.vit_encode(pv, vit_cfg, patches, impl=impl, remat=remat)
    return emb * valid[:, None, None].astype(emb.dtype)


def lm_microbatch_loss(pl, model: Model, mb: dict, emb, vidx):
    """LM loss of one microbatch: scatter the (masked) vision embeddings
    back into per-sample image slots, then the backbone loss with
    image-slot injection.  emb [cap, K, Vd], vidx [cap] local indices."""
    mbs_n = mb["tokens"].shape[0]
    img = jnp.zeros((mbs_n,) + emb.shape[1:], emb.dtype).at[vidx].add(emb)
    lmb = {k: mb[k] for k in LM_KEYS if k in mb}
    lmb["image_embeds"] = img
    loss, _ = model.loss(pl, lmb)
    return loss


# --------------------------------------------------------------------------- #
# Per-iteration plan: wavefront order → microbatch composition
# --------------------------------------------------------------------------- #
class IterationPlan(wl.IterationPlan):
    """The generic :class:`repro.core.workload.IterationPlan` with the
    MLLM-historical accessors (the ViT is the one activated section)."""

    @property
    def image_mbs(self):
        return self.activation["vit"].active_mbs

    @property
    def vis_idx(self):
        return self.activation["vit"].idx

    @property
    def vis_valid(self):
        return self.activation["vit"].valid


def build_plan(order: Sequence[int], has_image: np.ndarray, mbs: int,
               schedule: Optional[ScheduleResult] = None) -> IterationPlan:
    act = wl.build_activation(order, has_image, mbs)
    return IterationPlan(tuple(order), mbs, len(order) // mbs,
                         {"vit": act}, schedule)


def colocated_batch(batch: dict, plan: IterationPlan) -> dict:
    """Lay one global batch out for the colocated oracle: permute into the
    plan's dispatch order and pre-split into [n_mb, mbs, ...] so the
    oracle's scan sees exactly the executor's microbatch composition."""
    idx = list(plan.order)
    out = {}
    for k in LM_KEYS + ("patches",):
        v = np.asarray(batch[k])[idx]
        out[k] = jnp.asarray(
            v.reshape((plan.n_mb, plan.mbs) + v.shape[1:]))
    out["vis_idx"] = jnp.asarray(plan.vis_idx)
    out["vis_valid"] = jnp.asarray(plan.vis_valid)
    return out


# --------------------------------------------------------------------------- #
# Colocated single-jit oracle
# --------------------------------------------------------------------------- #
def build_colocated_step(vit_cfg: ArchConfig, lm_cfg: ArchConfig,
                         mesh: Mesh, *, mbs: int, seq_len: int,
                         impl: str = "ref", lr_schedule=None,
                         opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                         return_grads: bool = False):
    """One jit over the pre-microbatched batch from
    :func:`colocated_batch`: per microbatch, ViT fwd on the gathered image
    samples + LM loss, per-microbatch joint grads accumulated in dispatch
    order, one AdamW update.  Returns (step, shardings)."""
    model = build_model(lm_cfg, impl=impl)
    v_specs = vlm.vit_specs(vit_cfg)
    l_specs = model.specs()
    l_rules = shd.rules_for(lm_cfg, mesh)
    v_rules = shd.rules_for(vit_cfg, mesh)
    p_shard = {"lm": shd.param_shardings(l_specs, mesh, l_rules),
               "vit": shd.param_shardings(v_specs, mesh, v_rules)}
    ol = shd.opt_state_shardings(l_specs, mesh, l_rules)
    ov = shd.opt_state_shardings(v_specs, mesh, v_rules)
    o_shard = adamw.AdamWState(step=ol.step, mu={"lm": ol.mu, "vit": ov.mu},
                               nu={"lm": ol.nu, "vit": ov.nu},
                               master={"lm": ol.master, "vit": ov.master})
    dp = shd.dp_axes(mesh) or None
    rep = shd.replicated(mesh)

    def mb_sharding(ndim):
        return NamedSharding(mesh, P(None, dp, *([None] * (ndim - 2))))

    b_shard = {"tokens": mb_sharding(3), "labels": mb_sharding(3),
               "loss_mask": mb_sharding(3), "image_pos": mb_sharding(3),
               "image_valid": mb_sharding(3), "patches": mb_sharding(4),
               "vis_idx": rep, "vis_valid": rep}
    hook = _act_hook_for(mesh, mbs, seq_len)
    lr_fn = lr_schedule or functools.partial(schedules.constant,
                                             peak_lr=1e-3)

    def joint_loss(ps, mb, vidx, vval):
        with cm.act_hook(hook):
            sub = mb["patches"][vidx]
            emb = vit_forward(ps["vit"], vit_cfg, sub, vval, impl=impl)
            return lm_microbatch_loss(ps["lm"], model, mb, emb, vidx)

    grad_fn = jax.value_and_grad(joint_loss)

    def step(params, opt_state, batch, step_idx):
        n_mb = batch["tokens"].shape[0]
        mbs_tree = {k: batch[k] for k in LM_KEYS + ("patches",)}

        def body(carry, xs):
            g_acc, l_acc = carry
            mb, vidx, vval = xs
            loss, g = grad_fn(params, mb, vidx, vval)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + loss), None

        g0 = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
        (g_sum, l_sum), _ = jax.lax.scan(
            body, (g0, jnp.float32(0)),
            (mbs_tree, batch["vis_idx"], batch["vis_valid"]))
        grads = jax.tree_util.tree_map(
            lambda g, p: (g / n_mb).astype(p.dtype), g_sum, params)
        loss = l_sum / n_mb
        lr = lr_fn(step_idx)
        new_p, new_opt, gnorm = adamw.update(grads, opt_state, lr, opt_cfg)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "lr": lr}
        if return_grads:
            metrics["grads"] = grads
        return new_p, new_opt, metrics

    out_metrics = {"loss": rep, "grad_norm": rep, "lr": rep}
    if return_grads:
        out_metrics["grads"] = p_shard
    jitted = jax.jit(step,
                     in_shardings=(p_shard, o_shard, b_shard, rep),
                     out_shardings=(p_shard, o_shard, out_metrics))
    return jitted, {"params": p_shard, "opt": o_shard, "batch": b_shard}


def init_compound_params(vit_cfg: ArchConfig, lm_cfg: ArchConfig, rng):
    """Joint {vit, lm} params on the default device (place with
    ``jax.device_put`` onto either the oracle mesh or section meshes)."""
    model = build_model(lm_cfg)
    r_v, r_l = jax.random.split(rng)
    return {"vit": cm.init_params(vlm.vit_specs(vit_cfg), r_v),
            "lm": cm.init_params(model.specs(), r_l)}


# --------------------------------------------------------------------------- #
# Declarative workload spec + thin runtime wrapper
# --------------------------------------------------------------------------- #
def mllm_spec(vit_cfg: ArchConfig, lm_cfg: ArchConfig, *,
              vit_parallel: ParallelConfig, lm_parallel: ParallelConfig,
              global_batch: int, seq_len: int, mbs: int,
              impl: str = "ref") -> wl.WorkloadSpec:
    """The MLLM workload as a declaration: a data-dependent ViT section
    emitting per-microbatch vision embeddings, and the critical LM
    section scattering them into image slots.  Everything else — carved
    meshes, jits, AdamW, joint grad-norm, wavefront dispatch — is the
    generic :class:`repro.core.workload.CompoundRuntime`."""
    model = build_model(lm_cfg, impl=impl)
    K, Vd = lm_cfg.max_image_tokens, lm_cfg.vision_dim
    P = K * vlm.downsample_factor(vit_cfg)
    pd = vit_cfg.frontend_dim
    emb = wl.Port("emb", (K, Vd), vit_cfg.dtype)

    def vit_fn(pv, x):
        return {"emb": vit_forward(pv, vit_cfg, x["patches"],
                                   x["act_valid"], impl=impl)}

    def llm_fn(pl, x):
        mb = {k: x[k] for k in LM_KEYS}
        return lm_microbatch_loss(pl, model, mb, x["vit.emb"],
                                  x["vit.act_idx"])

    vit = wl.SectionSpec(
        "vit", vit_cfg, vit_parallel, vit_fn, vlm.vit_specs(vit_cfg),
        inputs={"patches": wl.Field((P, pd), vit_cfg.dtype)},
        emits=(emb,),
        activation=lambda b: np.asarray(b["has_image"]).astype(bool),
        seq_len=P)
    llm = wl.SectionSpec(
        "llm", lm_cfg, lm_parallel, llm_fn, model.specs(),
        inputs={"tokens": wl.Field((wl.SEQ,), "int32"),
                "labels": wl.Field((wl.SEQ,), "int32"),
                "loss_mask": wl.Field((wl.SEQ,), "float32", fill=1.0),
                "image_pos": wl.Field((K,), "int32"),
                "image_valid": wl.Field((K,), "int32")},
        consumes=(wl.Consume("vit", emb),),
        loss=True, critical=True)
    return wl.WorkloadSpec("mllm", (vit, llm), seq_len=seq_len,
                           global_batch=global_batch, mbs=mbs)


class MLLMRuntime:
    """ViT and LLM sections on disjoint carved meshes, driven by the
    generic :class:`~repro.core.workload.CompoundRuntime` — this class is
    now only the historical parameter/metric surface (params keyed
    ``{"vit", "lm"}``, ``n_vit_tasks``, the MLLM ``IterationPlan``) over
    the declarative spec above.  Section parallelism goes through the
    consolidated ``validate_section_parallel`` path, so dp/tp *and* CP
    configs (the paper gives the ViT's long patch sequences to CP) run
    through the executor; only PP still raises."""

    def __init__(self, vit_cfg: ArchConfig, lm_cfg: ArchConfig, *,
                 vit_parallel: ParallelConfig, lm_parallel: ParallelConfig,
                 global_batch: int, seq_len: int, mbs: int,
                 devices=None, impl: str = "ref", lr_schedule=None,
                 opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                 lookahead: int = 0):
        assert global_batch % mbs == 0, (global_batch, mbs)
        self.vit_cfg, self.lm_cfg = vit_cfg, lm_cfg
        self.impl = impl
        self.opt_cfg = opt_cfg
        self.B, self.S, self.mbs = global_batch, seq_len, mbs
        self.n_mb = global_batch // mbs
        self.K = lm_cfg.max_image_tokens
        self.Vd = lm_cfg.vision_dim
        self.P = self.K * vlm.downsample_factor(vit_cfg)
        self.pd = vit_cfg.frontend_dim
        spec = mllm_spec(vit_cfg, lm_cfg, vit_parallel=vit_parallel,
                         lm_parallel=lm_parallel,
                         global_batch=global_batch, seq_len=seq_len,
                         mbs=mbs, impl=impl)
        self._crt = wl.CompoundRuntime(
            spec, devices=devices, impl=impl,
            lr_schedule=lr_schedule or functools.partial(
                schedules.constant, peak_lr=1e-3),
            opt_cfg=opt_cfg, lookahead=lookahead)
        self.rt = self._crt.rt
        self.executor = self._crt.executor
        self.graph = self._crt.graph

    # ------------------------------------------------------------------ #
    def init(self, rng):
        params = init_compound_params(self.vit_cfg, self.lm_cfg, rng)
        return self.place(params)

    def place(self, params):
        """Place a joint {vit, lm} param tree onto the section meshes and
        build matching optimizer states."""
        p, o = self._crt.place({"vit": params["vit"],
                                "llm": params["lm"]})
        return ({"vit": p["vit"], "lm": p["llm"]},
                {"vit": o["vit"], "lm": o["llm"]})

    def plan_iteration(self, has_image, *, reorder: bool = True
                       ) -> IterationPlan:
        p = self._crt.plan_iteration(
            {"has_image": np.asarray(has_image)}, reorder=reorder)
        return IterationPlan(p.order, p.mbs, p.n_mb, p.activation,
                             p.schedule)

    # ------------------------------------------------------------------ #
    @staticmethod
    def _remap_metrics(metrics, return_grads):
        # n_vit_tasks keeps its historical meaning — data-dependent
        # compute tasks only — so the worker-side ``upd`` (which every
        # trainable section always runs) is excluded
        metrics["n_vit_tasks"] = metrics["n_tasks"].get("vit", 0) - 1
        if return_grads:
            g = metrics["grads"]
            metrics["grads"] = {"lm": g["llm"], "vit": g["vit"]}
        return metrics

    def train_iteration(self, params, opts, batch, step_idx, *,
                        reorder: bool = True,
                        plan: Optional[IterationPlan] = None,
                        return_grads: bool = False,
                        timeout: float = 300.0):
        """One serialized global-batch iteration through the executor.
        Returns (params, opts, metrics) with metrics carrying the
        realized ExecutionResult (timeline, makespan, utilization) and
        the plan."""
        if plan is None:
            plan = self.plan_iteration(np.asarray(batch["has_image"]),
                                       reorder=reorder)
        p, o, metrics = self._crt.train_iteration(
            {"vit": params["vit"], "llm": params["lm"]},
            {"vit": opts["vit"], "llm": opts["lm"]},
            batch, step_idx, plan=plan, return_grads=return_grads,
            timeout=timeout)
        self._remap_metrics(metrics, return_grads)
        return ({"vit": p["vit"], "lm": p["llm"]},
                {"vit": o["vit"], "lm": o["llm"]}, metrics)

    # ------------------------------------------------------------------ #
    # streaming surface (cross-iteration lookahead)
    # ------------------------------------------------------------------ #
    def install(self, params, opts):
        """Adopt (params, opts) — keyed ``{"vit", "lm"}`` — as the
        streaming state advanced by worker-side updates."""
        self._crt.install({"vit": params["vit"], "llm": params["lm"]},
                          {"vit": opts["vit"], "llm": opts["lm"]})

    def state(self):
        p, o = self._crt.state()
        return ({"vit": p["vit"], "lm": p["llm"]},
                {"vit": o["vit"], "lm": o["llm"]})

    @property
    def in_flight(self) -> int:
        return self._crt.in_flight

    @property
    def lookahead(self) -> int:
        return self._crt.lookahead

    @lookahead.setter
    def lookahead(self, depth: int) -> None:
        self._crt.lookahead = int(depth)

    def submit_iteration(self, batch, step_idx, *,
                         reorder: bool = True,
                         plan: Optional[IterationPlan] = None,
                         return_grads: bool = False,
                         timeout: float = 300.0) -> int:
        if plan is None:
            plan = self.plan_iteration(np.asarray(batch["has_image"]),
                                       reorder=reorder)
        return self._crt.submit_iteration(
            batch, step_idx, plan=plan, return_grads=return_grads,
            timeout=timeout)

    def retire(self, *, timeout: float = 300.0):
        metrics = self._crt.retire(timeout=timeout)
        return self._remap_metrics(metrics, "grads" in metrics)

    def drain(self, *, timeout: float = 300.0):
        return [self._remap_metrics(m, "grads" in m)
                for m in self._crt.drain(timeout=timeout)]

    def shutdown(self):
        self._crt.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False
