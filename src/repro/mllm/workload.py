"""MLLM compound workload: ViT encoder section → LLM backbone section.

The headline Maestro workload (§2.1/§4.1): modality sections activate
*per sample* — text-only samples bypass the vision section entirely — and
the wavefront scheduler reorders samples so the critical (LLM) section
never stalls on vision work.  Two execution modes share one arithmetic:

* :func:`build_colocated_step` — ONE jit: scan over microbatches, each
  doing ViT-encode of its image samples (gathered to the static per-
  microbatch capacity) + LM loss with image-slot injection, grads
  accumulated in microbatch order.  This is the numerical oracle.
* :class:`MLLMRuntime` — disaggregated on the compound executor: the ViT
  section runs fwd/bwd tasks for *image-bearing microbatches only* on its
  own carved mesh, embeddings / embedding-cotangents cross the
  MessageQueue, and the per-iteration microbatch composition comes from
  the wavefront dispatch order.

Because both modes perform the same per-microbatch computations in the
same order (the dynamic path only *skips* work whose contribution is an
exact zero), the disaggregated per-step loss and grads match the
colocated oracle bit-for-bit on equal section layouts — driver-verified
on mixed and all-text batches (``tests/drivers/driver_mllm_runtime.py``).

Static vs dynamic shapes: each microbatch has a *static* vision capacity
(= its sample count); image samples are gathered into that capacity and
zero-padded — padding only ever exists inside a microbatch.  Whether a
microbatch dispatches vision work at all is dynamic (data-dependent
activation).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import cost_model as cmdl
from repro.core.executor import Dispatch, mark_start, order_samples
from repro.core.graph import build_vlm_graph
from repro.core.runtime import MaestroRuntime
from repro.core.scheduler import ScheduleResult
from repro.core.types import ArchConfig, ParallelConfig
from repro.dist import sharding as shd
from repro.models import common as cm
from repro.models import vlm
from repro.models.model import Model, build_model
from repro.optim import adamw, schedules
from repro.train.step import _act_hook_for

#: batch keys the LM step consumes (vision arrives as ``image_embeds``)
LM_KEYS = ("tokens", "labels", "loss_mask", "image_pos", "image_valid")


def _reject_pp_cp(parallel: ParallelConfig, what: str) -> None:
    if parallel.pp > 1 or parallel.cp > 1:
        raise NotImplementedError(
            f"pp/cp for {what} is not wired through the MLLM runtime yet; "
            "use dp/tp per section (ROADMAP open item)")


# --------------------------------------------------------------------------- #
# Shared per-microbatch arithmetic (oracle ≡ disaggregated, bit-for-bit)
# --------------------------------------------------------------------------- #
def vit_forward(pv, vit_cfg: ArchConfig, patches, valid, *,
                impl: str = "ref", remat: bool = True):
    """ViT-encode the gathered image samples of one microbatch and mask
    padding rows.  patches [cap, P, pd], valid [cap] → emb [cap, K, Vd]."""
    emb = vlm.vit_encode(pv, vit_cfg, patches, impl=impl, remat=remat)
    return emb * valid[:, None, None].astype(emb.dtype)


def lm_microbatch_loss(pl, model: Model, mb: dict, emb, vidx):
    """LM loss of one microbatch: scatter the (masked) vision embeddings
    back into per-sample image slots, then the backbone loss with
    image-slot injection.  emb [cap, K, Vd], vidx [cap] local indices."""
    mbs_n = mb["tokens"].shape[0]
    img = jnp.zeros((mbs_n,) + emb.shape[1:], emb.dtype).at[vidx].add(emb)
    lmb = {k: mb[k] for k in LM_KEYS if k in mb}
    lmb["image_embeds"] = img
    loss, _ = model.loss(pl, lmb)
    return loss


# --------------------------------------------------------------------------- #
# Per-iteration plan: wavefront order → microbatch composition
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class IterationPlan:
    """Host-side dispatch plan for one global batch."""
    order: Tuple[int, ...]        # sample permutation (dispatch order)
    mbs: int
    n_mb: int
    vis_idx: np.ndarray           # [n_mb, cap] local image-sample indices
    vis_valid: np.ndarray         # [n_mb, cap] 1.0 for real image samples
    image_mbs: Tuple[int, ...]    # microbatches that activate the ViT
    schedule: Optional[ScheduleResult] = None


def build_plan(order: Sequence[int], has_image: np.ndarray, mbs: int,
               schedule: Optional[ScheduleResult] = None) -> IterationPlan:
    n = len(order)
    assert n % mbs == 0, (n, mbs)
    n_mb = n // mbs
    ordered_has = np.asarray(has_image).astype(bool)[list(order)]
    vis_idx = np.zeros((n_mb, mbs), np.int32)
    vis_valid = np.zeros((n_mb, mbs), np.float32)
    image_mbs = []
    for i in range(n_mb):
        loc = np.where(ordered_has[i * mbs:(i + 1) * mbs])[0]
        vis_idx[i, :len(loc)] = loc
        vis_valid[i, :len(loc)] = 1.0
        if len(loc):
            image_mbs.append(i)
    return IterationPlan(tuple(order), mbs, n_mb, vis_idx, vis_valid,
                         tuple(image_mbs), schedule)


def colocated_batch(batch: dict, plan: IterationPlan) -> dict:
    """Lay one global batch out for the colocated oracle: permute into the
    plan's dispatch order and pre-split into [n_mb, mbs, ...] so the
    oracle's scan sees exactly the executor's microbatch composition."""
    idx = list(plan.order)
    out = {}
    for k in LM_KEYS + ("patches",):
        v = np.asarray(batch[k])[idx]
        out[k] = jnp.asarray(
            v.reshape((plan.n_mb, plan.mbs) + v.shape[1:]))
    out["vis_idx"] = jnp.asarray(plan.vis_idx)
    out["vis_valid"] = jnp.asarray(plan.vis_valid)
    return out


# --------------------------------------------------------------------------- #
# Colocated single-jit oracle
# --------------------------------------------------------------------------- #
def build_colocated_step(vit_cfg: ArchConfig, lm_cfg: ArchConfig,
                         mesh: Mesh, *, mbs: int, seq_len: int,
                         impl: str = "ref", lr_schedule=None,
                         opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                         return_grads: bool = False):
    """One jit over the pre-microbatched batch from
    :func:`colocated_batch`: per microbatch, ViT fwd on the gathered image
    samples + LM loss, per-microbatch joint grads accumulated in dispatch
    order, one AdamW update.  Returns (step, shardings)."""
    model = build_model(lm_cfg, impl=impl)
    v_specs = vlm.vit_specs(vit_cfg)
    l_specs = model.specs()
    l_rules = shd.rules_for(lm_cfg, mesh)
    v_rules = shd.rules_for(vit_cfg, mesh)
    p_shard = {"lm": shd.param_shardings(l_specs, mesh, l_rules),
               "vit": shd.param_shardings(v_specs, mesh, v_rules)}
    ol = shd.opt_state_shardings(l_specs, mesh, l_rules)
    ov = shd.opt_state_shardings(v_specs, mesh, v_rules)
    o_shard = adamw.AdamWState(step=ol.step, mu={"lm": ol.mu, "vit": ov.mu},
                               nu={"lm": ol.nu, "vit": ov.nu},
                               master={"lm": ol.master, "vit": ov.master})
    dp = shd.dp_axes(mesh) or None
    rep = shd.replicated(mesh)

    def mb_sharding(ndim):
        return NamedSharding(mesh, P(None, dp, *([None] * (ndim - 2))))

    b_shard = {"tokens": mb_sharding(3), "labels": mb_sharding(3),
               "loss_mask": mb_sharding(3), "image_pos": mb_sharding(3),
               "image_valid": mb_sharding(3), "patches": mb_sharding(4),
               "vis_idx": rep, "vis_valid": rep}
    hook = _act_hook_for(mesh, mbs, seq_len)
    lr_fn = lr_schedule or functools.partial(schedules.constant,
                                             peak_lr=1e-3)

    def joint_loss(ps, mb, vidx, vval):
        with cm.act_hook(hook):
            sub = mb["patches"][vidx]
            emb = vit_forward(ps["vit"], vit_cfg, sub, vval, impl=impl)
            return lm_microbatch_loss(ps["lm"], model, mb, emb, vidx)

    grad_fn = jax.value_and_grad(joint_loss)

    def step(params, opt_state, batch, step_idx):
        n_mb = batch["tokens"].shape[0]
        mbs_tree = {k: batch[k] for k in LM_KEYS + ("patches",)}

        def body(carry, xs):
            g_acc, l_acc = carry
            mb, vidx, vval = xs
            loss, g = grad_fn(params, mb, vidx, vval)
            g_acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + loss), None

        g0 = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)
        (g_sum, l_sum), _ = jax.lax.scan(
            body, (g0, jnp.float32(0)),
            (mbs_tree, batch["vis_idx"], batch["vis_valid"]))
        grads = jax.tree_util.tree_map(
            lambda g, p: (g / n_mb).astype(p.dtype), g_sum, params)
        loss = l_sum / n_mb
        lr = lr_fn(step_idx)
        new_p, new_opt, gnorm = adamw.update(grads, opt_state, lr, opt_cfg)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "lr": lr}
        if return_grads:
            metrics["grads"] = grads
        return new_p, new_opt, metrics

    out_metrics = {"loss": rep, "grad_norm": rep, "lr": rep}
    if return_grads:
        out_metrics["grads"] = p_shard
    jitted = jax.jit(step,
                     in_shardings=(p_shard, o_shard, b_shard, rep),
                     out_shardings=(p_shard, o_shard, out_metrics))
    return jitted, {"params": p_shard, "opt": o_shard, "batch": b_shard}


def init_compound_params(vit_cfg: ArchConfig, lm_cfg: ArchConfig, rng):
    """Joint {vit, lm} params on the default device (place with
    ``jax.device_put`` onto either the oracle mesh or section meshes)."""
    model = build_model(lm_cfg)
    r_v, r_l = jax.random.split(rng)
    return {"vit": cm.init_params(vlm.vit_specs(vit_cfg), r_v),
            "lm": cm.init_params(model.specs(), r_l)}


# --------------------------------------------------------------------------- #
# Disaggregated runtime on the compound executor
# --------------------------------------------------------------------------- #
class MLLMRuntime:
    """ViT and LLM sections on disjoint carved meshes, driven by the
    compound executor with wavefront-scheduled microbatch dispatch.

    Per iteration: cost-model 6-tuples → ``wavefront_schedule`` (or FIFO)
    → sample permutation → contiguous microbatches.  The ViT worker runs
    fwd tasks for image-bearing microbatches (embeddings pushed through
    the MessageQueue) and bwd tasks after the LM returns embedding
    cotangents; the LM worker consumes every microbatch in dispatch
    order.  All-text microbatches never touch the ViT section."""

    def __init__(self, vit_cfg: ArchConfig, lm_cfg: ArchConfig, *,
                 vit_parallel: ParallelConfig, lm_parallel: ParallelConfig,
                 global_batch: int, seq_len: int, mbs: int,
                 devices=None, impl: str = "ref", lr_schedule=None,
                 opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()):
        _reject_pp_cp(vit_parallel, "the ViT section")
        _reject_pp_cp(lm_parallel, "the LLM section")
        assert global_batch % mbs == 0, (global_batch, mbs)
        self.vit_cfg, self.lm_cfg = vit_cfg, lm_cfg
        self.impl = impl
        self.opt_cfg = opt_cfg
        self.lr_fn = lr_schedule or functools.partial(schedules.constant,
                                                      peak_lr=1e-3)
        self.B, self.S, self.mbs = global_batch, seq_len, mbs
        self.n_mb = global_batch // mbs
        self.K = lm_cfg.max_image_tokens
        self.Vd = lm_cfg.vision_dim
        ds = vlm.downsample_factor(vit_cfg)
        self.P = self.K * ds
        self.pd = vit_cfg.frontend_dim

        self.graph = build_vlm_graph(vit_cfg, lm_cfg,
                                     vit_parallel=vit_parallel,
                                     lm_parallel=lm_parallel)
        # scheduler sees the ViT's true sequence (raw patches per sample)
        self.graph.sections["vit"] = self.graph.sections["vit"].replace(
            seq_scale=self.P / max(seq_len, 1))
        self.rt = MaestroRuntime(self.graph, devices)
        self.executor = self.rt.executor()
        self.model = build_model(lm_cfg, impl=impl)
        vm, lmesh = self.rt.mesh("vit"), self.rt.mesh("llm")

        v_specs = vlm.vit_specs(vit_cfg)
        l_specs = self.model.specs()
        self.v_specs, self.l_specs = v_specs, l_specs
        self.vp_shard = shd.param_shardings(
            v_specs, vm, shd.rules_for(vit_cfg, vm))
        self.lp_shard = shd.param_shardings(
            l_specs, lmesh, shd.rules_for(lm_cfg, lmesh))
        self.vo_shard = shd.opt_state_shardings(
            v_specs, vm, shd.rules_for(vit_cfg, vm))
        self.lo_shard = shd.opt_state_shardings(
            l_specs, lmesh, shd.rules_for(lm_cfg, lmesh))
        self._patch_shard = shd.dp_sharding(vm, 3)
        self._valid_shard_v = shd.dp_sharding(vm, 1)
        self._emb_shard_v = shd.dp_sharding(vm, 3)
        self._emb_shard_l = shd.dp_sharding(lmesh, 3)
        self._mb_shard = {k: shd.dp_sharding(lmesh, 2) for k in LM_KEYS}
        rep_l = shd.replicated(lmesh)
        v_hook = _act_hook_for(vm, mbs, self.P)
        l_hook = _act_hook_for(lmesh, mbs, seq_len)

        def vit_fwd(pv, patches, valid):
            with cm.act_hook(v_hook):
                return vit_forward(pv, vit_cfg, patches, valid, impl=impl)

        def vit_bwd(pv, patches, valid, ct):
            def fwd(p):
                with cm.act_hook(v_hook):
                    return vit_forward(p, vit_cfg, patches, valid,
                                       impl=impl)
            _, vjp = jax.vjp(fwd, pv)
            return vjp(ct)[0]

        def llm_grad(pl, mb, emb, vidx):
            def loss_fn(p, e):
                with cm.act_hook(l_hook):
                    return lm_microbatch_loss(p, self.model, mb, e, vidx)
            loss, (g_pl, g_emb) = jax.value_and_grad(
                loss_fn, argnums=(0, 1))(pl, emb)
            return loss, g_pl, g_emb

        self._vit_fwd = jax.jit(
            vit_fwd, in_shardings=(self.vp_shard, self._patch_shard,
                                   self._valid_shard_v))
        self._vit_bwd = jax.jit(
            vit_bwd, in_shardings=(self.vp_shard, self._patch_shard,
                                   self._valid_shard_v, self._emb_shard_v),
            out_shardings=self.vp_shard)
        self._llm_grad = jax.jit(
            llm_grad, in_shardings=(self.lp_shard, self._mb_shard,
                                    self._emb_shard_l, rep_l),
            out_shardings=(rep_l, self.lp_shard, self._emb_shard_l))
        # jitted per-section updates: the same fused elementwise program
        # the colocated step runs (eager op-by-op AdamW rounds differently
        # — no FMA fusion — and would drift an ulp per step)
        def upd(g, st, lr, gn):
            return adamw.update(g, st, lr, opt_cfg, gnorm=gn)

        rep_v = shd.replicated(vm)
        self._update_l = jax.jit(
            upd, in_shardings=(self.lp_shard, self.lo_shard, rep_l, rep_l),
            out_shardings=(self.lp_shard, self.lo_shard, rep_l))
        self._update_v = jax.jit(
            upd, in_shardings=(self.vp_shard, self.vo_shard, rep_v, rep_v),
            out_shardings=(self.vp_shard, self.vo_shard, rep_v))

        def ssq_vec(g):
            return jnp.stack([jnp.sum(jnp.square(x.astype(jnp.float32)))
                              for x in jax.tree_util.tree_leaves(g)])

        # jitted per-leaf sums of squares: the same compiled square+sum
        # subgraph the oracle's in-jit global_norm runs (eager op-by-op
        # reduction rounds an ulp differently)
        self._ssq_l = jax.jit(ssq_vec, in_shardings=(self.lp_shard,),
                              out_shardings=rep_l)
        self._ssq_v = jax.jit(ssq_vec, in_shardings=(self.vp_shard,),
                              out_shardings=rep_v)
        self._warmup()

    # ------------------------------------------------------------------ #
    def _warmup(self):
        """Trace + compile every jit from the main thread: the act-hook
        context is process-global, so concurrent first-call tracing from
        two section workers would race."""
        pv = jax.device_put(cm.init_params(self.v_specs,
                                           jax.random.PRNGKey(0)),
                            self.vp_shard)
        pl = jax.device_put(cm.init_params(self.l_specs,
                                           jax.random.PRNGKey(1)),
                            self.lp_shard)
        dt = jnp.float32 if self.vit_cfg.dtype == "float32" else jnp.bfloat16
        patches = jnp.zeros((self.mbs, self.P, self.pd), dt)
        valid = jnp.zeros((self.mbs,), jnp.float32)
        emb = self._vit_fwd(pv, patches, valid)
        self._vit_bwd(pv, patches, valid, emb)
        mb = {"tokens": jnp.zeros((self.mbs, self.S), jnp.int32),
              "labels": jnp.zeros((self.mbs, self.S), jnp.int32),
              "loss_mask": jnp.ones((self.mbs, self.S), jnp.float32),
              "image_pos": jnp.zeros((self.mbs, self.K), jnp.int32),
              "image_valid": jnp.zeros((self.mbs, self.K), jnp.int32)}
        self._llm_grad(pl, mb,
                       jax.device_put(emb, self._emb_shard_l),
                       jnp.arange(self.mbs, dtype=jnp.int32))
        jax.block_until_ready(emb)

    # ------------------------------------------------------------------ #
    def init(self, rng):
        params = init_compound_params(self.vit_cfg, self.lm_cfg, rng)
        return self.place(params)

    def place(self, params):
        """Place a joint {vit, lm} param tree onto the section meshes and
        build matching optimizer states."""
        pv = jax.device_put(params["vit"], self.vp_shard)
        pl = jax.device_put(params["lm"], self.lp_shard)
        opts = {"vit": jax.device_put(adamw.init(pv), self.vo_shard),
                "lm": jax.device_put(adamw.init(pl), self.lo_shard)}
        return {"vit": pv, "lm": pl}, opts

    def plan_iteration(self, has_image, *, reorder: bool = True
                       ) -> IterationPlan:
        has = np.asarray(has_image).astype(bool)
        samples = cmdl.sample_tuples(self.graph, {"vit": has}, self.S,
                                     n=len(has))
        order, sched = order_samples(samples, reorder=reorder)
        return build_plan(order, has, self.mbs, schedule=sched)

    # ------------------------------------------------------------------ #
    def train_iteration(self, params, opts, batch, step_idx, *,
                        reorder: bool = True,
                        plan: Optional[IterationPlan] = None,
                        return_grads: bool = False,
                        timeout: float = 300.0):
        """One global-batch iteration through the executor.  Returns
        (params, opts, metrics) with metrics carrying the realized
        ExecutionResult (timeline, makespan, utilization) and the plan."""
        host = {k: np.asarray(v) for k, v in batch.items()}
        if plan is None:
            plan = self.plan_iteration(host["has_image"], reorder=reorder)
        idx = list(plan.order)
        ordered = {k: v[idx] for k, v in host.items() if k != "has_image"}
        n_mb, m = plan.n_mb, plan.mbs
        image_set = set(plan.image_mbs)
        pv, pl = params["vit"], params["lm"]
        q = self.rt.queue
        it = f"it{int(step_idx)}"
        vit_ctx: Dict[int, tuple] = {}
        vit_acc = {"g": None}
        llm_acc = {"g": None, "loss": jnp.float32(0.0)}

        def vit_fwd_task(i):
            def fn():
                rows = slice(i * m, (i + 1) * m)
                sub = ordered["patches"][rows][plan.vis_idx[i]]
                sub_d = jax.device_put(jnp.asarray(sub),
                                       self._patch_shard)
                vval = jax.device_put(jnp.asarray(plan.vis_valid[i]),
                                      self._valid_shard_v)
                emb = self._vit_fwd(pv, sub_d, vval)
                vit_ctx[i] = (sub_d, vval)
                q.push("vit", "llm", f"{it}/emb{i}", emb)
                return emb
            return fn

        def vit_bwd_task(i):
            def fn():
                ct = q.pull("llm", "vit", f"{it}/demb{i}",
                            sharding=self._emb_shard_v, timeout=timeout)
                mark_start()      # the stall above is idle, not busy
                sub_d, vval = vit_ctx.pop(i)
                g = self._vit_bwd(pv, sub_d, vval, ct)
                g0 = vit_acc["g"]
                if g0 is None:
                    # seed with f32 zeros like the oracle's scan carry —
                    # seeding with the raw (param-dtype) grad would keep
                    # a single-image-mb bf16 section accumulating in
                    # bf16 and double-round the /n_mb normalization
                    g0 = jax.tree_util.tree_map(
                        lambda x: jnp.zeros(x.shape, jnp.float32), g)
                vit_acc["g"] = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g0, g)
                # block before finishing: the section mesh must be quiet
                # when another thread (main: gnorm/update) launches its
                # next collective program (XLA CPU rendezvous contract)
                jax.block_until_ready(vit_acc["g"])
                return True
            return fn

        def llm_task(i):
            def fn():
                if i in image_set:
                    emb = q.pull("vit", "llm", f"{it}/emb{i}",
                                 sharding=self._emb_shard_l,
                                 timeout=timeout)
                    mark_start()  # waiting on the ViT is a stall the
                    #               scheduler should have hidden
                else:
                    # all-text microbatch: the ViT never runs; its
                    # contribution is the exact zero the oracle computes
                    emb = jax.device_put(
                        jnp.zeros((m, self.K, self.Vd),
                                  jnp.float32 if self.vit_cfg.dtype ==
                                  "float32" else jnp.bfloat16),
                        self._emb_shard_l)
                rows = slice(i * m, (i + 1) * m)
                mb = {k: jax.device_put(jnp.asarray(ordered[k][rows]),
                                        self._mb_shard[k])
                      for k in LM_KEYS}
                vidx = jnp.asarray(plan.vis_idx[i])
                loss, g_pl, g_emb = self._llm_grad(pl, mb, emb, vidx)
                if i in image_set:
                    q.push("llm", "vit", f"{it}/demb{i}", g_emb)
                llm_acc["loss"] = llm_acc["loss"] + loss
                g0 = llm_acc["g"]
                if g0 is None:
                    g0 = jax.tree_util.tree_map(
                        lambda x: jnp.zeros(x.shape, jnp.float32), pl)
                llm_acc["g"] = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g0, g_pl)
                jax.block_until_ready((llm_acc["g"], llm_acc["loss"]))
                return loss
            return fn

        dispatches: List[Dispatch] = []
        for i in plan.image_mbs:
            dispatches.append(Dispatch("vit", f"fwd{i}", vit_fwd_task(i)))
        for i in range(n_mb):
            dispatches.append(Dispatch("llm", f"mb{i}", llm_task(i)))
        for i in plan.image_mbs:
            dispatches.append(Dispatch("vit", f"bwd{i}", vit_bwd_task(i)))
        execution = self.executor.run(dispatches, timeout=timeout)

        # ---- finalize: accumulate → normalize → joint-norm AdamW ------
        if vit_acc["g"] is None:        # all-text batch: exact-zero grads
            vit_acc["g"] = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), pv)
        g_lm = jax.tree_util.tree_map(
            lambda g, p: (g / n_mb).astype(p.dtype), llm_acc["g"], pl)
        g_vit = jax.tree_util.tree_map(
            lambda g, p: (g / n_mb).astype(p.dtype), vit_acc["g"], pv)
        loss = llm_acc["loss"] / n_mb
        gnorm = self._joint_gnorm(g_lm, g_vit)
        lr = self.lr_fn(jnp.int32(step_idx))
        new_pl, new_ol, _ = self._update_l(g_lm, opts["lm"], lr, gnorm)
        new_pv, new_ov, _ = self._update_v(g_vit, opts["vit"], lr, gnorm)
        # synchronize the (async-dispatched, main-thread) update programs
        # before returning: the next iteration's worker threads launch
        # collective-bearing programs on the same section meshes, and XLA
        # CPU deadlocks when two host threads interleave collective
        # launches across one device set (rendezvous mismatch)
        jax.block_until_ready((new_pl, new_ol, new_pv, new_ov))
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "lr": lr, "execution": execution, "plan": plan,
                   "n_vit_tasks": 2 * len(plan.image_mbs)}
        if return_grads:
            metrics["grads"] = {"lm": g_lm, "vit": g_vit}
        return ({"vit": new_pv, "lm": new_pl},
                {"vit": new_ov, "lm": new_ol}, metrics)

    def _joint_gnorm(self, g_lm, g_vit):
        """Global grad norm across BOTH sections (the colocated semantics:
        one clip threshold for the whole compound model), assembled from
        per-section per-leaf sums of squares in joint-tree leaf order.
        The leaves live on disjoint committed meshes, so they cannot be
        stacked device-side — one batched ``device_get`` bridges them."""
        lm_v, vit_v = jax.device_get(         # single batched sync
            [self._ssq_l(g_lm), self._ssq_v(g_vit)])
        return jnp.sqrt(jnp.sum(jnp.asarray(
            np.concatenate([lm_v, vit_v]))))

    def shutdown(self):
        self.rt.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False
