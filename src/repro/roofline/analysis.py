"""Roofline-term extraction from compiled HLO.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE (verified in
this container), which would undercount every scanned-layer model by ~L×.
This module therefore parses the post-optimization HLO text itself:

* per-computation symbol tables (instruction → dtype/shape/bytes)
* while-loop trip counts extracted from condition computations; multipliers
  propagated through the call graph (while bodies ×trips, fusions inherit)
* FLOPs per computation: ``dot``/``convolution`` exactly; elementwise,
  transcendental and ``reduce`` at 1 FLOP/element — counted inside fusion
  computations too
* HBM traffic ≈ Σ (operand + result bytes) over *kernel-level* instructions:
  fusion internals and loop-control ops excluded (a fusion is one kernel;
  a while's carried tuple moves inside its body, which is already counted)
* collective bytes per family (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute): operand sizes × multiplier

Shapes in post-SPMD HLO are **per-device**, so all numbers are per-chip and
roofline terms divide by per-chip peaks.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# `%name = <type> opcode(<rest...>`; type is lazily matched so the opcode is
# the first bare word directly followed by '('.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_START_RE = re.compile(r"^\s*(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "sine", "cosine", "divide", "expm1", "log1p", "atan2",
                   "erf", "logistic", "cbrt", "exponential-minus-one"}
_ELEMENTWISE = {"add", "subtract", "multiply", "maximum", "minimum", "abs",
                "negate", "compare", "select", "and", "or", "xor", "not",
                "clamp", "floor", "ceil", "round-nearest-afz", "sign",
                "round-nearest-even", "convert"}
_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "iota", "partition-id", "replica-id",
               "opt-barrier", "domain", "while", "conditional", "call"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
    return n


def _first_shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    if m.group(2) == "":
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str

    @property
    def bytes(self) -> int:
        return _shape_bytes(self.type_str)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, Instr] = field(default_factory=dict)


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m:
                cur = Computation(m.group(1))
            continue
        if line.strip().startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2).strip(), m.group(3),
                        m.group(4))
            cur.instrs.append(ins)
            cur.table[ins.name] = ins
    if cur is not None:
        comps[cur.name] = cur
    return comps


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _operand_names(rest: str) -> List[str]:
    depth, cur = 1, []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        cur.append(ch)
    return _OPERAND_RE.findall("".join(cur))


def _trip_count(cond: Computation) -> int:
    consts = []
    for ins in cond.instrs:
        if ins.opcode == "constant":
            mm = re.match(r"(-?\d+)", ins.rest)
            if mm:
                consts.append(int(mm.group(1)))
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else 1


def _callees(ins: Instr) -> Dict[str, str]:
    out = {}
    for key in ("body", "condition", "to_apply", "calls"):
        m = re.search(key + r"=%?([\w.\-]+)", ins.rest)
        if m:
            out[key] = m.group(1)
    m = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
    if m:
        for i, name in enumerate(_OPERAND_RE.findall(m.group(1))):
            out[f"branch{i}"] = name
    return out


def _multipliers(comps: Dict[str, Computation], entry: str):
    """Two maps: flops multiplier (enters fusions) and traffic multiplier
    (fusion internals excluded)."""
    mf = {name: 0.0 for name in comps}
    mt = {name: 0.0 for name in comps}
    if entry not in comps:
        entry = next(iter(comps))
    mf[entry] = mt[entry] = 1.0
    for _ in range(len(comps) + 2):
        changed = False
        for name, comp in comps.items():
            f, t = mf.get(name, 0.0), mt.get(name, 0.0)
            if f == 0.0 and t == 0.0:
                continue
            for ins in comp.instrs:
                cal = _callees(ins)
                if not cal:
                    continue
                if ins.opcode == "while":
                    trips = (_trip_count(comps[cal["condition"]])
                             if cal.get("condition") in comps else 1)
                    targets = [(cal.get("body"), trips, trips),
                               (cal.get("condition"), trips + 1, 0)]
                elif ins.opcode == "fusion":
                    targets = [(c, 1, 0) for c in cal.values()]
                elif ins.opcode == "conditional":
                    targets = [(c, 1, 1) for k, c in cal.items()
                               if k.startswith("branch")]
                else:  # call / to_apply (reduce, sort, map, custom-call)
                    # reducer bodies run per output element — approximate as
                    # flops-only with multiplier 1 (reduce flops are counted
                    # at the reduce op itself)
                    targets = [(c, 0, 0) for c in cal.values()]
                for tgt, ffac, tfac in targets:
                    if tgt not in comps:
                        continue
                    if mf[tgt] < f * ffac:
                        mf[tgt] = f * ffac
                        changed = True
                    if mt[tgt] < t * tfac:
                        mt[tgt] = t * tfac
                        changed = True
        if not changed:
            break
    return mf, mt


def _dot_flops(ins: Instr, table: Dict[str, Instr]) -> float:
    out_elems = _shape_elems(ins.type_str)
    ops = _operand_names(ins.rest)
    contract = 1
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if mm and ops:
        lhs = table.get(ops[0])
        if lhs is not None:
            dims = _first_shape_dims(lhs.type_str) or []
            for d in mm.group(1).split(","):
                if d and int(d) < len(dims):
                    contract *= dims[int(d)]
    return 2.0 * out_elems * contract


def _conv_flops(ins: Instr, table: Dict[str, Instr]) -> float:
    out_elems = _shape_elems(ins.type_str)
    ops = _operand_names(ins.rest)
    if len(ops) < 2:
        return 2.0 * out_elems
    ker = table.get(ops[1])
    kelems = _shape_elems(ker.type_str) if ker else 1
    out_dims = _first_shape_dims(ins.type_str) or [1]
    of = out_dims[-1] if out_dims else 1
    return 2.0 * out_elems * max(kelems // max(of, 1), 1)


def _nth_operand_bytes(ins: Instr, comp: Computation, n: int) -> int:
    ops = _operand_names(ins.rest)
    if n < len(ops) and ops[n] in comp.table:
        return comp.table[ops[n]].bytes
    return 0


def _traffic(ins: Instr, operand_bytes: int, comp: Computation,
             comps: Dict[str, Computation]) -> float:
    """HBM bytes for one kernel-level instruction, modeling in-place
    slice updates (scan carries, cache writes, accumulators) at slice size
    instead of full-buffer size."""
    op = ins.opcode
    if op == "dynamic-slice":
        return 2.0 * ins.bytes + 16
    if op == "dynamic-update-slice":
        return 2.0 * _nth_operand_bytes(ins, comp, 1) + 16
    if op == "gather":
        return 2.0 * ins.bytes + _nth_operand_bytes(ins, comp, 1)
    if op == "scatter":
        upd = _nth_operand_bytes(ins, comp, 2)
        idx = _nth_operand_bytes(ins, comp, 1)
        return 3.0 * upd + idx          # read+modify+write at update size
    total = float(operand_bytes + ins.bytes)
    if op == "fusion":
        cal = _callees(ins).get("calls")
        callee = comps.get(cal) if cal else None
        if callee is not None:
            discount = 0.0
            for ci in callee.instrs:
                if ci.opcode == "dynamic-update-slice":
                    discount += 2.0 * max(
                        ci.bytes - _nth_operand_bytes(ci, callee, 1), 0)
                elif ci.opcode == "dynamic-slice":
                    full = _nth_operand_bytes(ci, callee, 0)
                    discount += max(full - ci.bytes, 0)
            total = max(total - discount, 64.0)
    return total


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    transcendental: float = 0.0
    while_trip_counts: Dict[str, int] = field(default_factory=dict)
    # traffic inside deeply-nested loop bodies (multiplier ≥ threshold):
    # on TPU these are the flash/SSD kernel interiors whose block tensors
    # live in VMEM — the Pallas kernels eliminate this HBM traffic, so the
    # kernel-adjusted memory term subtracts it (plus analytic kernel IO)
    deep_loop_bytes: float = 0.0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


DEEP_LOOP_MULT = 1024          # a computation executed ≥ this many times
#                                per step is kernel-interior, not HBM-level


def analyze_hlo(text: str) -> HloStats:
    comps = parse_hlo(text)
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    entry = m.group(1) if m else next(iter(comps))
    mf, mt = _multipliers(comps, entry)
    st = HloStats()
    for name, comp in comps.items():
        kf, kt = mf.get(name, 0.0), mt.get(name, 0.0)
        if kf == 0.0 and kt == 0.0:
            continue
        for ins in comp.instrs:
            op = ins.opcode
            operand_bytes = 0
            if kt > 0.0 and op not in _NO_TRAFFIC:
                for on in _operand_names(ins.rest):
                    o = comp.table.get(on)
                    if o is not None and o.opcode != "constant":
                        operand_bytes += o.bytes
                traffic = kt * _traffic(ins, operand_bytes, comp, comps)
                st.hbm_bytes += traffic
                if kt >= DEEP_LOOP_MULT:
                    st.deep_loop_bytes += traffic
            elif op.endswith("-start") or op in _COLLECTIVES:
                for on in _operand_names(ins.rest):
                    o = comp.table.get(on)
                    if o is not None and o.opcode != "constant":
                        operand_bytes += o.bytes
            # ---- collectives (counted under flops multiplier: they happen
            # whether or not they're inside a fusion region) ----
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES and kf > 0.0:
                st.collective_bytes[base] = (
                    st.collective_bytes.get(base, 0.0)
                    + kf * max(operand_bytes, ins.bytes))
            # ---- flops ----
            if kf == 0.0:
                continue
            if op == "dot":
                st.flops += kf * _dot_flops(ins, comp.table)
            elif op == "convolution":
                st.flops += kf * _conv_flops(ins, comp.table)
            elif op in _TRANSCENDENTAL:
                n = _shape_elems(ins.type_str)
                st.flops += kf * n
                st.transcendental += kf * n
            elif op in _ELEMENTWISE:
                st.flops += kf * _shape_elems(ins.type_str)
            elif op == "reduce":
                ops_n = _operand_names(ins.rest)
                if ops_n:
                    o = comp.table.get(ops_n[0])
                    if o is not None:
                        st.flops += kf * _shape_elems(o.type_str)
            elif op == "while":
                cal = _callees(ins)
                if cal.get("condition") in comps:
                    st.while_trip_counts[ins.name] = _trip_count(
                        comps[cal["condition"]])
    return st


# --------------------------------------------------------------------------- #
# Targeted extraction: per-op-shape dot FLOPs and ring-model collective
# wire bytes.  These back the step-roofline assertions (vocab-parallel
# CE no longer paying pp× unembed FLOPs; compressed DP grad all-reduce
# halving/quartering wire bytes) — see benchmarks/bench_step_roofline.py.
# --------------------------------------------------------------------------- #
_GROUPS_SET_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([\d,]+)\]<=\[")


def _group_size(ins: Instr) -> int:
    """Participant count of a collective from its replica_groups attr.
    Handles both the explicit ``{{0,1},{2,3}}`` and the iota
    ``[4,2]<=[8]`` (4 groups of 2) forms; 1 when absent/unparseable."""
    m = _GROUPS_IOTA_RE.search(ins.rest)
    if m:
        dims = [int(d) for d in m.group(1).split(",") if d]
        return dims[-1] if dims else 1
    m = _GROUPS_SET_RE.search(ins.rest)
    if m:
        first = m.group(1).split("}")[0]
        return len([t for t in first.strip("{}").split(",") if t.strip()])
    return 1


def _first_dtype(type_str: str) -> str:
    m = _SHAPE_RE.search(type_str)
    return m.group(1) if m else "?"


@dataclass
class CollectiveOp:
    """One collective instruction with its ring-model wire cost."""
    family: str
    dtype: str
    group_size: int
    payload_bytes: float        # per-device buffer the op moves
    wire_bytes: float           # ring model: bytes on the wire per device
    count: float                # execution multiplier


def _ring_wire(family: str, n: int, operand_bytes: float,
               result_bytes: float) -> float:
    """Per-device wire bytes of one collective on an n-way ring.

    all-reduce moves 2(n-1)/n of the payload (reduce-scatter +
    all-gather phases); all-gather / reduce-scatter / all-to-all move
    (n-1)/n of the *full* buffer (result for all-gather, operand
    otherwise); collective-permute ships its payload once."""
    if n <= 1:
        return 0.0
    if family == "all-reduce":
        return 2.0 * (n - 1) / n * operand_bytes
    if family == "all-gather":
        return (n - 1) / n * result_bytes
    if family in ("reduce-scatter", "all-to-all"):
        return (n - 1) / n * max(operand_bytes, result_bytes)
    return float(operand_bytes)     # collective-permute


def collective_ops(text: str) -> List[CollectiveOp]:
    """All executed collectives with replica-group-aware ring wire
    bytes, multiplier-scaled (while bodies × trips)."""
    comps = parse_hlo(text)
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    entry = m.group(1) if m else next(iter(comps))
    mf, _ = _multipliers(comps, entry)
    out: List[CollectiveOp] = []
    for name, comp in comps.items():
        kf = mf.get(name, 0.0)
        if kf == 0.0:
            continue
        for ins in comp.instrs:
            base = (ins.opcode[:-6] if ins.opcode.endswith("-start")
                    else ins.opcode)
            if base not in _COLLECTIVES:
                continue
            operand_bytes = 0
            for on in _operand_names(ins.rest):
                o = comp.table.get(on)
                if o is not None and o.opcode != "constant":
                    operand_bytes += o.bytes
            n = _group_size(ins)
            payload = float(max(operand_bytes, ins.bytes))
            out.append(CollectiveOp(
                base, _first_dtype(ins.type_str), n, payload,
                kf * _ring_wire(base, n, float(operand_bytes),
                                float(ins.bytes)),
                kf))
    return out


def wire_bytes_by_dtype(text: str) -> Dict[str, float]:
    """Ring-model collective wire bytes per element dtype — the knob the
    compressed DP all-reduce turns (f32 → u16-bitcast bf16 → s8)."""
    out: Dict[str, float] = {}
    for op in collective_ops(text):
        out[op.dtype] = out.get(op.dtype, 0.0) + op.wire_bytes
    return out


def total_wire_bytes(text: str) -> float:
    return sum(wire_bytes_by_dtype(text).values())


def dot_flops_matching(text: str, out_last_dim: int) -> float:
    """Multiplier-scaled FLOPs of every ``dot`` whose OUTPUT last dim is
    ``out_last_dim`` — post-SPMD shapes are per-device, so matching on
    the local vocab-shard width isolates the unembed projection."""
    comps = parse_hlo(text)
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    entry = m.group(1) if m else next(iter(comps))
    mf, _ = _multipliers(comps, entry)
    total = 0.0
    for name, comp in comps.items():
        kf = mf.get(name, 0.0)
        if kf == 0.0:
            continue
        for ins in comp.instrs:
            if ins.opcode != "dot":
                continue
            dims = _first_shape_dims(ins.type_str)
            if dims and dims[-1] == out_last_dim:
                total += kf * _dot_flops(ins, comp.table)
    return total


def dot_flops_by_width(text: str) -> Dict[int, float]:
    """Multiplier-scaled dot FLOPs keyed by OUTPUT last dim — the full
    width histogram behind :func:`dot_flops_matching`.  The declarative
    HLO gates (``repro.analysis.hlo_gates``) quote it on failure so a
    missing width is diagnosable from the finding alone."""
    comps = parse_hlo(text)
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    entry = m.group(1) if m else next(iter(comps))
    mf, _ = _multipliers(comps, entry)
    out: Dict[int, float] = {}
    for name, comp in comps.items():
        kf = mf.get(name, 0.0)
        if kf == 0.0:
            continue
        for ins in comp.instrs:
            if ins.opcode != "dot":
                continue
            dims = _first_shape_dims(ins.type_str)
            if dims:
                w = dims[-1]
                out[w] = out.get(w, 0.0) + kf * _dot_flops(
                    ins, comp.table)
    return out


def collective_families(text: str) -> Dict[str, float]:
    """Executed collective families -> total ring-model wire bytes.
    The 'no unexpected all-gathers / silent replication' gates compare
    this against a regime's declared profile."""
    out: Dict[str, float] = {}
    for op in collective_ops(text):
        out[op.family] = out.get(op.family, 0.0) + op.wire_bytes
    return out


# --------------------------------------------------------------------------- #
def cp_attention_comm(mode: str, *, H: int, KV: int, D: int, cp: int,
                      B: int = 1, S: Optional[int] = None,
                      itemsize: int = 4,
                      overlap_chunks: int = 1) -> Dict[str, float]:
    """Analytic per-device ring wire bytes of one ``cp_attention`` forward.

    Models the a2a chains the modes issue (backward transposes the same
    collectives, so relative ordering is unchanged):

    * ``ulysses``      — Q/K/V a2a in, O a2a out.  With ``overlap_chunks``
      = c > 1 the K/V a2as split into c per-chunk a2as: per-collective
      payload shrinks ÷c while total wire bytes stay constant (that is the
      overlap lever — smaller messages pipeline behind chunk flash
      compute).
    * ``ulysses_mqa``  — KV heads replicated ×(cp / gcd(KV, cp)) so they
      head-shard, then plain ulysses a2as.
    * ``allgather``    — K and V all-gathered to the full sequence.

    Per device, in units of (cp−1)/cp · B·S·D·itemsize:
    ulysses = (2H + 2KV)/cp; ulysses_mqa = 2H/cp + 2KV/gcd(KV, cp);
    allgather = 2KV.  ulysses_mqa beats allgather iff
    H/(cp·KV) + 1/gcd(KV, cp) < 1 — a GQA-at-large-cp win; for pure MQA
    (KV = 1) it never wins, which is why mode="auto" consults this model
    instead of always preferring a2a.
    """
    S = cp if S is None else S
    if S % cp:
        raise ValueError(f"S={S} must divide cp={cp}")
    sc = S // cp                      # local sequence shard
    f = (cp - 1) / cp
    unit = float(B * D * itemsize)
    qo_payload = sc * H * unit        # local [B, S/cp, H, D] buffer
    if mode == "ulysses":
        if H % cp or KV % cp:
            raise ValueError(f"ulysses needs H%cp==0 and KV%cp==0 "
                             f"(H={H}, KV={KV}, cp={cp})")
        c = max(int(overlap_chunks), 1)
        if sc % c:
            raise ValueError(f"overlap_chunks={c} must divide S/cp={sc}")
        kv_payload = sc * KV * unit / c
        return {"wire_bytes": f * (2 * qo_payload + 2 * sc * KV * unit),
                "collectives": 2 + 2 * c,
                "max_payload_bytes": max(qo_payload, kv_payload),
                "min_payload_bytes": min(qo_payload, kv_payload)}
    if mode == "ulysses_mqa":
        r = cp // math.gcd(KV, cp)
        kv_r = KV * r
        if H % cp or (H % KV) or (H // KV) % r:
            raise ValueError(f"ulysses_mqa infeasible for H={H}, KV={KV}, "
                             f"cp={cp} (needs H%cp==0 and r=cp/gcd | H/KV)")
        kv_payload = sc * kv_r * unit
        return {"wire_bytes": f * (2 * qo_payload + 2 * kv_payload),
                "collectives": 4,
                "max_payload_bytes": max(qo_payload, kv_payload),
                "min_payload_bytes": min(qo_payload, kv_payload)}
    if mode == "allgather":
        kv_payload = S * KV * unit    # gathered full-sequence K (or V)
        return {"wire_bytes": f * 2 * kv_payload,
                "collectives": 2,
                "max_payload_bytes": kv_payload,
                "min_payload_bytes": kv_payload}
    raise ValueError(f"unknown cp_attention mode {mode!r}")


# --------------------------------------------------------------------------- #
def roofline_terms(stats: HloStats, *, hw=None) -> Dict[str, float]:
    """Three roofline terms in seconds (per chip; HLO is post-SPMD)."""
    from repro.core.types import V5E
    hw = hw or V5E
    compute_s = stats.flops / hw.peak_flops_bf16
    memory_s = stats.hbm_bytes / hw.hbm_bandwidth
    collective_s = stats.total_collective_bytes / hw.ici_bandwidth
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda t: t[1])[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant}
