"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
per-cell JSONs written by ``repro.launch.dryrun``.

    PYTHONPATH=src python -m repro.roofline.report experiments/dryrun
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def load(dir_path):
    cells = []
    for f in sorted(Path(dir_path).glob("*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def hbm_gib(d) -> float:
    m = d.get("memory", {})
    return (m.get("argument_size_in_bytes", 0)
            + m.get("temp_size_in_bytes", 0)
            + m.get("output_size_in_bytes", 0)
            - m.get("alias_size_in_bytes", 0)) / 2 ** 30


def roofline_table(cells, mesh="single") -> str:
    hdr = ("| arch | shape | HBM/chip | compute_s | memory_s "
           "| mem_s (kernel-adj) | collective_s | dominant | useful |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for d in cells:
        if d.get("mesh") != mesh or "arch" not in d:
            continue
        if "skipped" in d:
            rows.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | — "
                        f"| *skipped: sub-quadratic-only shape* | — |")
            continue
        if "error" in d:
            rows.append(f"| {d['arch']} | {d['shape']} | ERROR | | | | | | |")
            continue
        r = d["roofline"]
        dom = r["dominant"]
        adj = d.get("kernel_adjusted_memory_s", r["memory_s"])
        # dominant after kernel adjustment
        terms = {"compute": r["compute_s"], "memory": adj,
                 "collective": r["collective_s"]}
        dom_adj = max(terms, key=terms.get)
        rows.append(
            f"| {d['arch']} | {d['shape']} | {hbm_gib(d):.1f} GiB "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} | {adj:.3f} "
            f"| {r['collective_s']:.3f} | {dom}→{dom_adj} "
            f"| {d['useful_flops_ratio']:.3f} |")
    return hdr + "\n".join(rows)


def summary(cells) -> str:
    ok = sum(1 for d in cells if "roofline" in d)
    sk = sum(1 for d in cells if "skipped" in d)
    er = sum(1 for d in cells if "error" in d)
    over = [f"{d['arch']}/{d['shape']}/{d['mesh']}" for d in cells
            if "roofline" in d and hbm_gib(d) > 16.0]
    lines = [f"cells: {ok} compiled, {sk} skipped, {er} errors"]
    if over:
        lines.append(f"over 16 GiB HBM: {', '.join(over)}")
    return "\n".join(lines)


def fractions(cells, mesh="single") -> str:
    """Roofline fraction per train cell: bound-term / achieved-term ratio
    proxy = compute_s / max(term)s — how close the compiled program is to
    its compute roofline (1.0 = compute-bound at peak)."""
    hdr = ("| arch | shape | roofline fraction (as-lowered) "
           "| (kernel-adjusted) |\n|---|---|---|---|\n")
    rows = []
    for d in cells:
        if d.get("mesh") != mesh or "roofline" not in d \
                or "arch" not in d:
            continue
        r = d["roofline"]
        adj = d.get("kernel_adjusted_memory_s", r["memory_s"])
        lower = max(r["compute_s"], 1e-12)
        f1 = lower / max(r["compute_s"], r["memory_s"], r["collective_s"])
        f2 = lower / max(r["compute_s"], adj, r["collective_s"])
        rows.append(f"| {d['arch']} | {d['shape']} | {f1:.3f} | {f2:.3f} |")
    return hdr + "\n".join(rows)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    cells = load(d)
    print(summary(cells))
    print()
    print("## single-pod (16×16)")
    print(roofline_table(cells, "single"))
    print()
    print("## multi-pod (2×16×16)")
    print(roofline_table(cells, "multi"))
    print()
    print("## roofline fractions (single-pod)")
    print(fractions(cells, "single"))


if __name__ == "__main__":
    main()
