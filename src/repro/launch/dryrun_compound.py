import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + (" " + os.environ["XLA_FLAGS"] if "XLA_FLAGS" in os.environ else ""))

"""Compound-workload dry-run: lower + compile a compound training cell on
the production mesh — the cells most representative of the paper's
technique.

* ``--workload distill`` (default): the colocated distillation step
  (teacher fwd + student train with hidden-state handoff, §3.1).
* ``--workload mllm``: the colocated MLLM oracle step from
  ``repro.mllm.workload`` — the single-jit formulation the disaggregated
  executor runtime is bit-for-bit equivalent to (scan over microbatches,
  ViT encode + LM loss with image-slot injection).

    PYTHONPATH=src python -m repro.launch.dryrun_compound \
        [--workload distill --teacher granite-3-8b --student granite-3-8b]
    PYTHONPATH=src python -m repro.launch.dryrun_compound \
        --workload mllm [--arch pixtral-12b]
"""
import argparse
import json
import time
from pathlib import Path


def _emit(rec: dict, out_dir: str, name: str) -> None:
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / name).write_text(json.dumps(rec, indent=2))
    print(json.dumps(rec["roofline"]))
    print("useful:", rec["useful_flops_ratio"])
    print("wrote", out / name)


def _analyze(compiled, rec: dict, model_flops: float, n_devices: int):
    from repro.roofline.analysis import analyze_hlo, roofline_terms
    mem = compiled.memory_analysis()
    rec["memory"] = {k: int(getattr(mem, k)) for k in
                     ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes")}
    stats = analyze_hlo(compiled.as_text())
    rec["roofline"] = roofline_terms(stats)
    rec["hlo"] = {"flops_per_device": stats.flops,
                  "hbm_bytes_per_device": stats.hbm_bytes,
                  "deep_loop_bytes_per_device": stats.deep_loop_bytes,
                  "collective_bytes_per_device": stats.collective_bytes}
    rec["model_flops"] = model_flops
    rec["useful_flops_ratio"] = model_flops / max(
        stats.flops * n_devices, 1)
    return rec


def _run_distill(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.types import ParallelConfig, ShapeConfig
    from repro.distill.workload import build_colocated_step
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as tf
    from repro.models.common import param_shapes
    from repro.optim import adamw

    t_cfg = get_config(args.teacher)
    s_cfg = get_config(args.student)
    mesh = make_production_mesh(cp=args.cp)
    shape = ShapeConfig("distill", "train", args.seq, args.batch)
    step, _ = build_colocated_step(t_cfg, s_cfg, mesh, shape,
                                   ParallelConfig(mbs=args.mbs or 1,
                                                  cp=args.cp),
                                   impl="ref")
    t_shapes = param_shapes(tf.lm_specs(t_cfg))
    s_shapes = param_shapes(tf.lm_specs(s_cfg))
    o_shapes = adamw.state_specs(s_shapes)
    b_shapes = {
        "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((args.batch, args.seq),
                                          jnp.float32)}
    t0 = time.time()
    with mesh:
        lowered = step.lower(s_shapes, o_shapes, t_shapes, b_shapes,
                             jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    rec = {"workload": f"distill:{args.teacher}->{args.student}",
           "mesh": "single", "compile_s": time.time() - t0}
    toks = args.batch * args.seq
    model_flops = (6 * s_cfg.active_params()
                   + 2 * t_cfg.active_params()) * toks
    _analyze(compiled, rec, model_flops, mesh.devices.size)
    _emit(rec, args.out,
          f"compound_distill__{args.teacher}__{args.student}__single.json")


def _run_mllm(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.launch.mesh import make_production_mesh
    from repro.mllm.workload import build_colocated_step
    from repro.models import transformer as tf
    from repro.models.common import param_shapes
    from repro.models.vlm import vit_config, vit_specs
    from repro.optim import adamw

    lm_cfg = get_config(args.arch)
    assert lm_cfg.vision_dim, f"{args.arch} is not a VLM arch"
    seq, batch = args.seq, args.batch
    tiny = bool(os.environ.get("REPRO_DRYRUN_TINY"))
    if tiny:
        lm_cfg = reduce_config(lm_cfg).replace(
            vision_dim=64, max_image_tokens=8)
        seq, batch = min(seq, 128), min(batch, 8)
        vit_cfg = vit_config(num_layers=2, d_model=64, num_heads=4,
                             d_ff=128, patch_dim=32, downsample=4,
                             out_dim=lm_cfg.vision_dim)
    else:
        # the paper's 0.4B-class ViT encoder feeding the backbone
        vit_cfg = vit_config(out_dim=lm_cfg.vision_dim)
    mbs = args.mbs if args.mbs is not None else min(8, batch)
    if batch % mbs:
        raise ValueError(
            f"--batch {batch} is not a multiple of mbs={mbs}: the "
            "microbatched step would lower for a different sample count "
            "than the reported model_flops")
    n_mb = batch // mbs
    K = lm_cfg.max_image_tokens or min(seq // 4, 2048)
    lm_cfg = lm_cfg.replace(max_image_tokens=K)
    from repro.launch.mesh import mesh_from_env
    from repro.models.vlm import downsample_factor
    P = K * downsample_factor(vit_cfg)
    mesh = mesh_from_env() or make_production_mesh()
    step, _ = build_colocated_step(vit_cfg, lm_cfg, mesh, mbs=mbs,
                                   seq_len=seq, impl="ref")
    p_shapes = {"lm": param_shapes(tf.lm_specs(lm_cfg)),
                "vit": param_shapes(vit_specs(vit_cfg))}
    o_shapes = adamw.state_specs(p_shapes)
    i32, f32 = jnp.int32, jnp.float32
    dt = jnp.bfloat16 if lm_cfg.dtype == "bfloat16" else jnp.float32
    b_shapes = {
        "tokens": jax.ShapeDtypeStruct((n_mb, mbs, seq), i32),
        "labels": jax.ShapeDtypeStruct((n_mb, mbs, seq), i32),
        "loss_mask": jax.ShapeDtypeStruct((n_mb, mbs, seq), f32),
        "image_pos": jax.ShapeDtypeStruct((n_mb, mbs, K), i32),
        "image_valid": jax.ShapeDtypeStruct((n_mb, mbs, K), i32),
        "patches": jax.ShapeDtypeStruct((n_mb, mbs, P,
                                         vit_cfg.frontend_dim), dt),
        "vis_idx": jax.ShapeDtypeStruct((n_mb, mbs), i32),
        "vis_valid": jax.ShapeDtypeStruct((n_mb, mbs), f32)}
    t0 = time.time()
    with mesh:
        lowered = step.lower(p_shapes, o_shapes, b_shapes,
                             jax.ShapeDtypeStruct((), i32))
        compiled = lowered.compile()
    rec = {"workload": f"mllm:{vit_cfg.name}->{args.arch}",
           "mesh": "single", "compile_s": time.time() - t0,
           "n_microbatches": n_mb, "mbs": mbs,
           "image_tokens": K, "vit_patches": P}
    toks = batch * seq
    vit_toks = batch * P
    model_flops = (6 * lm_cfg.active_params() * toks
                   + 6 * vit_cfg.total_params() * vit_toks)
    _analyze(compiled, rec, model_flops, mesh.devices.size)
    _emit(rec, args.out,
          f"compound_mllm__{vit_cfg.name}__{args.arch}__single.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="distill",
                    choices=("distill", "mllm"))
    ap.add_argument("--teacher", default="granite-3-8b")
    ap.add_argument("--student", default="granite-3-8b")
    ap.add_argument("--arch", default="pixtral-12b",
                    help="mllm backbone arch (must have a vision stub)")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--mbs", type=int, default=None,
                    help="micro-batch size (default: 1 for distill, "
                         "min(8, batch) for mllm)")
    ap.add_argument("--cp", type=int, default=1,
                    help="context parallelism: carve a seq axis out of "
                         "the data axis (teacher+student attention run "
                         "through cp_attention; distill only)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    if args.workload == "mllm":
        _run_mllm(args)
    else:
        _run_distill(args)


if __name__ == "__main__":
    main()
