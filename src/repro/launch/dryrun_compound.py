import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + (" " + os.environ["XLA_FLAGS"] if "XLA_FLAGS" in os.environ else ""))

"""Compound-workload dry-run: lower + compile the colocated distillation
step (teacher fwd + student train with hidden-state handoff, §3.1) on the
production mesh — the cell most representative of the paper's technique.

    PYTHONPATH=src python -m repro.launch.dryrun_compound \
        [--teacher granite-3-8b --student granite-3-8b]
"""
import argparse
import json
import time
from pathlib import Path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--teacher", default="granite-3-8b")
    ap.add_argument("--student", default="granite-3-8b")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--mbs", type=int, default=1)
    ap.add_argument("--cp", type=int, default=1,
                    help="context parallelism: carve a seq axis out of "
                         "the data axis (teacher+student attention run "
                         "through cp_attention)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.types import ParallelConfig, ShapeConfig, V5E
    from repro.distill.workload import build_colocated_step
    from repro.launch.dryrun import _analytic_kernel_io
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as tf
    from repro.models.common import param_shapes
    from repro.optim import adamw
    from repro.roofline.analysis import analyze_hlo, roofline_terms

    t_cfg = get_config(args.teacher)
    s_cfg = get_config(args.student)
    mesh = make_production_mesh(cp=args.cp)
    shape = ShapeConfig("distill", "train", args.seq, args.batch)
    step, _ = build_colocated_step(t_cfg, s_cfg, mesh, shape,
                                   ParallelConfig(mbs=args.mbs,
                                                  cp=args.cp),
                                   impl="ref")
    t_shapes = param_shapes(tf.lm_specs(t_cfg))
    s_shapes = param_shapes(tf.lm_specs(s_cfg))
    o_shapes = adamw.state_specs(s_shapes)
    b_shapes = {
        "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((args.batch, args.seq),
                                          jnp.float32)}
    t0 = time.time()
    with mesh:
        lowered = step.lower(s_shapes, o_shapes, t_shapes, b_shapes,
                             jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    rec = {"workload": f"distill:{args.teacher}->{args.student}",
           "mesh": "single", "compile_s": time.time() - t0}
    mem = compiled.memory_analysis()
    rec["memory"] = {k: int(getattr(mem, k)) for k in
                     ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes")}
    stats = analyze_hlo(compiled.as_text())
    rec["roofline"] = roofline_terms(stats)
    rec["hlo"] = {"flops_per_device": stats.flops,
                  "hbm_bytes_per_device": stats.hbm_bytes,
                  "deep_loop_bytes_per_device": stats.deep_loop_bytes,
                  "collective_bytes_per_device": stats.collective_bytes}
    # student train + teacher fwd model flops
    toks = args.batch * args.seq
    rec["model_flops"] = (6 * s_cfg.active_params()
                          + 2 * t_cfg.active_params()) * toks
    rec["useful_flops_ratio"] = rec["model_flops"] / max(
        stats.flops * mesh.devices.size, 1)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    name = f"compound_distill__{args.teacher}__{args.student}__single.json"
    (out / name).write_text(json.dumps(rec, indent=2))
    print(json.dumps(rec["roofline"]))
    print("useful:", rec["useful_flops_ratio"])
    print("wrote", out / name)


if __name__ == "__main__":
    main()
