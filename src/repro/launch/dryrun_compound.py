import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + (" " + os.environ["XLA_FLAGS"] if "XLA_FLAGS" in os.environ else ""))

"""Compound-workload dry-run: lower + compile a compound training cell on
the production mesh — the cells most representative of the paper's
technique.

Registered workloads (``--workload``, one runner per registry entry):

* ``distill`` (default): the colocated distillation step (teacher fwd +
  student train with hidden-state handoff, §3.1).
* ``mllm``: the colocated MLLM oracle step from ``repro.mllm.workload``
  — the single-jit formulation the disaggregated executor runtime is
  bit-for-bit equivalent to (scan over microbatches, ViT encode + LM
  loss with image-slot injection).
* ``multi_teacher``: the colocated multi-teacher distillation step from
  ``repro.distill.multi_teacher`` — two frozen teachers (specialist
  domain-routed) + chunked-vocab KL student, the reference for the
  declarative ``WorkloadSpec``/``CompoundRuntime`` third workload.

    PYTHONPATH=src python -m repro.launch.dryrun_compound \
        [--workload distill --teacher granite-3-8b --student granite-3-8b]
    PYTHONPATH=src python -m repro.launch.dryrun_compound \
        --workload mllm [--arch pixtral-12b]
    PYTHONPATH=src python -m repro.launch.dryrun_compound \
        --workload multi_teacher [--teacher2 granite-3-8b]

``REPRO_DRYRUN_TINY=1`` reduces every workload to an 8-device-friendly
cell (pair with ``REPRO_DRYRUN_DEVICES`` / ``REPRO_DRYRUN_MESH``) — the
CI driver-smoke job lowers every registered workload that way.
"""
import argparse
import json
import time
from pathlib import Path


def _emit(rec: dict, out_dir: str, name: str) -> None:
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / name).write_text(json.dumps(rec, indent=2))
    print(json.dumps(rec["roofline"]))
    print("useful:", rec["useful_flops_ratio"])
    print("wrote", out / name)


def _analyze(compiled, rec: dict, model_flops: float, n_devices: int):
    from repro.roofline.analysis import analyze_hlo, roofline_terms
    mem = compiled.memory_analysis()
    rec["memory"] = {k: int(getattr(mem, k)) for k in
                     ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes")}
    stats = analyze_hlo(compiled.as_text())
    rec["roofline"] = roofline_terms(stats)
    rec["hlo"] = {"flops_per_device": stats.flops,
                  "hbm_bytes_per_device": stats.hbm_bytes,
                  "deep_loop_bytes_per_device": stats.deep_loop_bytes,
                  "collective_bytes_per_device": stats.collective_bytes}
    rec["model_flops"] = model_flops
    rec["useful_flops_ratio"] = model_flops / max(
        stats.flops * n_devices, 1)
    return rec


def _run_distill(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.types import ParallelConfig, ShapeConfig
    from repro.distill.workload import build_colocated_step
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as tf
    from repro.models.common import param_shapes
    from repro.optim import adamw

    from repro.configs import reduce_config
    from repro.launch.mesh import mesh_from_env

    t_cfg = get_config(args.teacher)
    s_cfg = get_config(args.student)
    seq, batch = args.seq, args.batch
    if os.environ.get("REPRO_DRYRUN_TINY"):
        t_cfg, s_cfg = reduce_config(t_cfg), reduce_config(s_cfg)
        seq, batch = min(seq, 128), min(batch, 8)
    mesh = mesh_from_env() or make_production_mesh(cp=args.cp)
    shape = ShapeConfig("distill", "train", seq, batch)
    step, _ = build_colocated_step(t_cfg, s_cfg, mesh, shape,
                                   ParallelConfig(mbs=args.mbs or 1,
                                                  cp=args.cp),
                                   impl="ref")
    t_shapes = param_shapes(tf.lm_specs(t_cfg))
    s_shapes = param_shapes(tf.lm_specs(s_cfg))
    o_shapes = adamw.state_specs(s_shapes)
    b_shapes = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((batch, seq), jnp.float32)}
    t0 = time.time()
    with mesh:
        lowered = step.lower(s_shapes, o_shapes, t_shapes, b_shapes,
                             jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    rec = {"workload": f"distill:{args.teacher}->{args.student}",
           "mesh": "single", "compile_s": time.time() - t0}
    toks = batch * seq
    model_flops = (6 * s_cfg.active_params()
                   + 2 * t_cfg.active_params()) * toks
    _analyze(compiled, rec, model_flops, mesh.devices.size)
    _emit(rec, args.out,
          f"compound_distill__{args.teacher}__{args.student}__single.json")


def _run_mllm(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.launch.mesh import make_production_mesh
    from repro.mllm.workload import build_colocated_step
    from repro.models import transformer as tf
    from repro.models.common import param_shapes
    from repro.models.vlm import vit_config, vit_specs
    from repro.optim import adamw

    lm_cfg = get_config(args.arch)
    assert lm_cfg.vision_dim, f"{args.arch} is not a VLM arch"
    seq, batch = args.seq, args.batch
    tiny = bool(os.environ.get("REPRO_DRYRUN_TINY"))
    if tiny:
        lm_cfg = reduce_config(lm_cfg).replace(
            vision_dim=64, max_image_tokens=8)
        seq, batch = min(seq, 128), min(batch, 8)
        vit_cfg = vit_config(num_layers=2, d_model=64, num_heads=4,
                             d_ff=128, patch_dim=32, downsample=4,
                             out_dim=lm_cfg.vision_dim)
    else:
        # the paper's 0.4B-class ViT encoder feeding the backbone
        vit_cfg = vit_config(out_dim=lm_cfg.vision_dim)
    mbs = args.mbs if args.mbs is not None else min(8, batch)
    if batch % mbs:
        raise ValueError(
            f"--batch {batch} is not a multiple of mbs={mbs}: the "
            "microbatched step would lower for a different sample count "
            "than the reported model_flops")
    n_mb = batch // mbs
    K = lm_cfg.max_image_tokens or min(seq // 4, 2048)
    lm_cfg = lm_cfg.replace(max_image_tokens=K)
    from repro.launch.mesh import mesh_from_env
    from repro.models.vlm import downsample_factor
    P = K * downsample_factor(vit_cfg)
    mesh = mesh_from_env() or make_production_mesh()
    step, _ = build_colocated_step(vit_cfg, lm_cfg, mesh, mbs=mbs,
                                   seq_len=seq, impl="ref")
    p_shapes = {"lm": param_shapes(tf.lm_specs(lm_cfg)),
                "vit": param_shapes(vit_specs(vit_cfg))}
    o_shapes = adamw.state_specs(p_shapes)
    i32, f32 = jnp.int32, jnp.float32
    dt = jnp.bfloat16 if lm_cfg.dtype == "bfloat16" else jnp.float32
    b_shapes = {
        "tokens": jax.ShapeDtypeStruct((n_mb, mbs, seq), i32),
        "labels": jax.ShapeDtypeStruct((n_mb, mbs, seq), i32),
        "loss_mask": jax.ShapeDtypeStruct((n_mb, mbs, seq), f32),
        "image_pos": jax.ShapeDtypeStruct((n_mb, mbs, K), i32),
        "image_valid": jax.ShapeDtypeStruct((n_mb, mbs, K), i32),
        "patches": jax.ShapeDtypeStruct((n_mb, mbs, P,
                                         vit_cfg.frontend_dim), dt),
        "vis_idx": jax.ShapeDtypeStruct((n_mb, mbs), i32),
        "vis_valid": jax.ShapeDtypeStruct((n_mb, mbs), f32)}
    t0 = time.time()
    with mesh:
        lowered = step.lower(p_shapes, o_shapes, b_shapes,
                             jax.ShapeDtypeStruct((), i32))
        compiled = lowered.compile()
    rec = {"workload": f"mllm:{vit_cfg.name}->{args.arch}",
           "mesh": "single", "compile_s": time.time() - t0,
           "n_microbatches": n_mb, "mbs": mbs,
           "image_tokens": K, "vit_patches": P}
    toks = batch * seq
    vit_toks = batch * P
    model_flops = (6 * lm_cfg.active_params() * toks
                   + 6 * vit_cfg.total_params() * vit_toks)
    _analyze(compiled, rec, model_flops, mesh.devices.size)
    _emit(rec, args.out,
          f"compound_mllm__{vit_cfg.name}__{args.arch}__single.json")


def _run_multi_teacher(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.distill.multi_teacher import build_colocated_step
    from repro.launch.mesh import make_production_mesh, mesh_from_env
    from repro.models import transformer as tf
    from repro.models.common import param_shapes
    from repro.optim import adamw

    ta_cfg = get_config(args.teacher)
    tb_cfg = get_config(args.teacher2)
    s_cfg = get_config(args.student)
    seq, batch = args.seq, args.batch
    if os.environ.get("REPRO_DRYRUN_TINY"):
        ta_cfg, tb_cfg = reduce_config(ta_cfg), reduce_config(tb_cfg)
        s_cfg = reduce_config(s_cfg)
        seq, batch = min(seq, 128), min(batch, 8)
    mbs = args.mbs if args.mbs is not None else min(8, batch)
    if batch % mbs:
        raise ValueError(f"--batch {batch} is not a multiple of "
                         f"mbs={mbs}")
    n_mb = batch // mbs
    mesh = mesh_from_env() or make_production_mesh()
    step, _ = build_colocated_step(ta_cfg, tb_cfg, s_cfg, mesh, mbs=mbs,
                                   seq_len=seq, impl="ref")
    s_shapes = param_shapes(tf.lm_specs(s_cfg))
    a_shapes = param_shapes(tf.lm_specs(ta_cfg))
    b_shapes_t = param_shapes(tf.lm_specs(tb_cfg))
    o_shapes = adamw.state_specs(s_shapes)
    i32, f32 = jnp.int32, jnp.float32
    batch_shapes = {
        "tokens": jax.ShapeDtypeStruct((n_mb, mbs, seq), i32),
        "labels": jax.ShapeDtypeStruct((n_mb, mbs, seq), i32),
        "loss_mask": jax.ShapeDtypeStruct((n_mb, mbs, seq), f32),
        "b_idx": jax.ShapeDtypeStruct((n_mb, mbs), i32),
        "b_valid": jax.ShapeDtypeStruct((n_mb, mbs), f32)}
    dt_a = jnp.bfloat16 if ta_cfg.dtype == "bfloat16" else f32
    dt_b = jnp.bfloat16 if tb_cfg.dtype == "bfloat16" else f32
    wa = jax.ShapeDtypeStruct((ta_cfg.d_model, ta_cfg.padded_vocab), dt_a)
    wb = jax.ShapeDtypeStruct((tb_cfg.d_model, tb_cfg.padded_vocab), dt_b)
    t0 = time.time()
    with mesh:
        lowered = step.lower(s_shapes, o_shapes, a_shapes, b_shapes_t,
                             wa, wb, batch_shapes,
                             jax.ShapeDtypeStruct((), i32))
        compiled = lowered.compile()
    rec = {"workload": (f"multi_teacher:{args.teacher}+{args.teacher2}"
                        f"->{args.student}"),
           "mesh": "single", "compile_s": time.time() - t0,
           "n_microbatches": n_mb, "mbs": mbs}
    toks = batch * seq
    model_flops = (6 * s_cfg.active_params() + 2 * ta_cfg.active_params()
                   + 2 * tb_cfg.active_params()) * toks
    _analyze(compiled, rec, model_flops, mesh.devices.size)
    _emit(rec, args.out,
          f"compound_multi_teacher__{args.teacher}__{args.teacher2}"
          f"__{args.student}__single.json")


#: every registered compound workload (CI lowers each of these)
WORKLOADS = {
    "distill": _run_distill,
    "mllm": _run_mllm,
    "multi_teacher": _run_multi_teacher,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="distill",
                    choices=tuple(WORKLOADS))
    ap.add_argument("--teacher", default="granite-3-8b")
    ap.add_argument("--student", default="granite-3-8b")
    ap.add_argument("--arch", default="pixtral-12b",
                    help="mllm backbone arch (must have a vision stub)")
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--mbs", type=int, default=None,
                    help="micro-batch size (default: 1 for distill, "
                         "min(8, batch) for mllm)")
    ap.add_argument("--cp", type=int, default=1,
                    help="context parallelism: carve a seq axis out of "
                         "the data axis (teacher+student attention run "
                         "through cp_attention; distill only)")
    ap.add_argument("--teacher2", default="granite-3-8b",
                    help="specialist teacher for --workload multi_teacher")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    WORKLOADS[args.workload](args)


if __name__ == "__main__":
    main()
