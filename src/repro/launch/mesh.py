"""Production mesh builders.

Functions (not module-level constants) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pp: int = 1,
                         cp: int = 1):
    """Production mesh; ``pp``/``cp`` > 1 carve ``pipe``/``seq`` axes out
    of the data axis (same device count), following the
    ``repro.dist.sharding`` axis contract — so the dry-run lowers the same
    PP/CP step the runtime executes on carved section meshes."""
    if pp == 1 and cp == 1:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return jax.make_mesh(shape, axes)
    assert 16 % (pp * cp) == 0, (pp, cp)
    shape = (16 // (pp * cp), pp, cp, 16)
    axes = ("data", "pipe", "seq", "model")
    if multi_pod:
        shape = (2,) + shape
        axes = ("pod",) + axes
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_from_env(var: str = "REPRO_DRYRUN_MESH"):
    """Mesh from a comma-separated dims env var, or None when unset.

    2/3 dims map to the classic ``(pod,)data,model`` axes; 4/5 dims to
    the full section-mesh contract ``(pod,)data,pipe,seq,model`` (the
    PP/CP dry-run cells).  Single source of the env↔axis-name mapping
    for every dry-run CLI."""
    import os
    spec = os.environ.get(var)
    if not spec:
        return None
    dims = tuple(int(x) for x in spec.split(","))
    names = (("pod", "data", "pipe", "seq", "model") if len(dims) > 3
             else ("pod", "data", "model"))
    return make_mesh(dims, names[-len(dims):])


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (virtual) devices this host exposes."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))
