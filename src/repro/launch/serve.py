"""Batched serving driver: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch lm-20m --batch 4 \
        --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.launch.train import PRESETS
from repro.models.model import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-20m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    if args.arch in PRESETS:
        cfg = PRESETS[args.arch]
    elif args.reduced:
        cfg = get_reduced(args.arch)
    else:
        cfg = get_config(args.arch)
    cfg = cfg.replace(dtype=args.dtype)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    t0 = time.time()
    logits, cache = model.prefill(params, {"tokens": prompts},
                                  extra_cache=args.gen)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    decode = jax.jit(model.decode)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, out[-1],
                               jnp.int32(args.prompt_len + i))
        out.append(jnp.argmax(logits, -1)[:, None].astype(jnp.int32))
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
