"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch lm-100m --steps 200

Any assigned arch id works (``--arch granite-3-8b --reduced`` for the CPU
smoke variant; full configs need real accelerators).  Presets:

* ``lm-100m`` — ~100M-param llama-style LM (the deliverable-(b) scale)
* ``lm-20m``  — CPU-friendly variant used in the checked-in example run
"""
from __future__ import annotations

import argparse
import functools
import time

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.core.types import ArchConfig, ParallelConfig, ShapeConfig
from repro.data.synthetic import lm_batches
from repro.models.model import build_model
from repro.optim import adamw, schedules
from repro.train import step as step_mod
from repro.train.loop import train

PRESETS = {
    "lm-100m": ArchConfig(name="lm-100m", family="dense", num_layers=12,
                          d_model=768, num_heads=12, num_kv_heads=4,
                          d_ff=2048, vocab_size=32768, head_dim=64),
    "lm-20m": ArchConfig(name="lm-20m", family="dense", num_layers=6,
                         d_model=384, num_heads=6, num_kv_heads=2,
                         d_ff=1024, vocab_size=8192, head_dim=64),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-20m",
                    help=f"preset {list(PRESETS)} or one of {ARCH_NAMES}")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config of an assigned arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mbs", type=int, default=0,
                    help="microbatch size per DP shard (0 = whole batch)")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--data", type=int, default=1, help="data-axis size")
    ap.add_argument("--model", type=int, default=1, help="model-axis size")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    if args.arch in PRESETS:
        cfg = PRESETS[args.arch]
    elif args.reduced:
        cfg = get_reduced(args.arch)
    else:
        cfg = get_config(args.arch)
    cfg = cfg.replace(dtype=args.dtype)

    mesh = jax.make_mesh((args.data, args.model), ("data", "model"))
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    mbs = args.mbs or max(args.batch // args.data, 1)
    parallel = ParallelConfig(dp=args.data, tp=args.model, mbs=mbs)

    model = build_model(cfg)
    step, shardings = step_mod.build_train_step(
        model, mesh, parallel, shape,
        lr_schedule=functools.partial(
            schedules.warmup_cosine, peak_lr=args.lr,
            warmup_steps=max(args.steps // 10, 1),
            total_steps=args.steps))
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"mesh=({args.data},{args.model}) mbs={mbs}")
    opt = adamw.init(params)
    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    with mesh:
        params = jax.device_put(params, shardings["params"])
        opt = jax.device_put(opt, shardings["opt"])
        t0 = time.time()
        res = train(step, params=params, opt_state=opt,
                    batches=lm_batches(batch=args.batch, seq_len=args.seq,
                                       vocab=cfg.vocab_size, seed=0),
                    num_steps=args.steps, checkpointer=ck,
                    checkpoint_every=args.ckpt_every, log_every=10)
        dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"done: {res.steps_run} steps, loss "
          f"{res.losses[0]:.3f} -> {res.losses[-1]:.3f}, "
          f"{toks/dt:.0f} tok/s, stragglers={res.stragglers}")


if __name__ == "__main__":
    main()
