import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + (" " + os.environ["XLA_FLAGS"] if "XLA_FLAGS" in os.environ else ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware, that

* the sharding config is coherent (SPMD partitioner accepts it),
* it fits per-chip HBM (``compiled.memory_analysis()``),
* and it yields the roofline terms (``cost_analysis`` + HLO parsing with
  while-trip-count correction — see ``repro.roofline.analysis``).

Usage:
  python -m repro.launch.dryrun --arch granite-20b --shape train_4k \
      --mesh single --out experiments/dryrun        # one cell
  python -m repro.launch.dryrun --all [--mesh both]  # full sweep, one
      subprocess per cell (isolation against OOM/compiler failures)
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path


def _cell_name(arch: str, shape: str, mesh: str) -> str:
    return f"{arch}__{shape}__{mesh}"


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             mbs: int = 1, sp: bool = False, pp: int = 1,
             cp: int = 1) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.types import SHAPES, ParallelConfig
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import build_model
    from repro.optim import adamw
    from repro.roofline.analysis import analyze_hlo, roofline_terms
    from repro.train import step as step_mod

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    tiny = bool(os.environ.get("REPRO_DRYRUN_TINY"))
    if tiny:
        from repro.configs import reduce_config
        from repro.core.types import ShapeConfig
        cfg = reduce_config(cfg)
        shape = ShapeConfig(shape.name, shape.kind,
                            min(shape.seq_len, 128),
                            min(shape.global_batch, 8))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "params": cfg.total_params(), "active_params": cfg.active_params()}

    if shape_name == "long_500k" and not cfg.sub_quadratic:
        rec["skipped"] = ("pure full-attention arch: 500K-token decode "
                          "needs sub-quadratic attention (DESIGN.md "
                          "§Arch-applicability)")
        return rec

    from repro.launch.mesh import mesh_from_env
    mesh = mesh_from_env()
    if mesh is None:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"),
                                    pp=pp, cp=cp)
    n_dev = mesh.devices.size
    from repro.dist.sharding import head_pad_for
    pad = head_pad_for(cfg, mesh.shape["model"])
    if pad:
        cfg = cfg.replace(head_pad=pad)
        rec["head_pad"] = pad
    vpad = (-cfg.vocab_size) % mesh.shape["model"]
    if vpad:
        cfg = cfg.replace(vocab_pad=vpad)
        rec["vocab_pad"] = vpad
    model = build_model(cfg, impl="ref")
    t0 = time.time()

    if shape.kind == "train":
        # pp/cp are read back from the mesh so REPRO_DRYRUN_MESH-built
        # meshes validate too — build_train_step rejects any mismatch
        mesh_sizes = dict(mesh.shape)
        parallel = ParallelConfig(mbs=mbs, sequence_parallel=sp,
                                  pp=mesh_sizes.get("pipe", 1),
                                  cp=mesh_sizes.get("seq", 1))
        step, _ = step_mod.build_train_step(model, mesh, parallel, shape)
        pshapes = model.param_shapes()
        oshapes = adamw.state_specs(pshapes)
        bshapes = model.input_specs(shape)
        with mesh:
            lowered = step.lower(pshapes, oshapes, bshapes,
                                 jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.kind == "prefill":
        step, _ = step_mod.build_prefill_step(model, mesh, shape)
        with mesh:
            lowered = step.lower(model.param_shapes(),
                                 model.input_specs(shape))
    else:  # decode
        step, _ = step_mod.build_decode_step(model, mesh, shape)
        with mesh:
            lowered = step.lower(model.param_shapes(),
                                 model.cache_specs(shape),
                                 model.input_specs(shape)["token"],
                                 jax.ShapeDtypeStruct((), jnp.int32))
    rec["lower_s"] = time.time() - t0

    t0 = time.time()
    with mesh:
        compiled = lowered.compile()
    rec["compile_s"] = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes") if hasattr(mem, k)}
        print("memory_analysis:", rec["memory"])
    except Exception as e:          # pragma: no cover
        rec["memory_error"] = repr(e)

    try:
        ca = compiled.cost_analysis()
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))}
        print("cost_analysis flops:", rec["cost_analysis"].get("flops"))
    except Exception as e:          # pragma: no cover
        rec["cost_analysis_error"] = repr(e)

    text = compiled.as_text()
    stats = analyze_hlo(text)
    terms = roofline_terms(stats)
    # tokens processed per executed step
    if shape.kind == "decode":
        tokens = shape.global_batch
        flops_per_tok = 2 * cfg.active_params()
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops_per_tok = 2 * cfg.active_params()
    else:
        tokens = shape.global_batch * shape.seq_len
        flops_per_tok = 6 * cfg.active_params()
    model_flops = flops_per_tok * tokens
    hlo_total = stats.flops * n_dev
    # kernel-adjusted memory: deep-loop (flash/SSD interior) traffic lives
    # in VMEM under the Pallas kernels; replace it with analytic kernel IO
    # (read q,k,v + write o, fwd + recompute-bwd ≈ 3×)
    from repro.core.types import V5E
    kio = _analytic_kernel_io(cfg, shape, n_dev)
    adj_bytes = max(stats.hbm_bytes - stats.deep_loop_bytes, 0.0) + kio
    rec.update({
        "devices": n_dev,
        "hlo": {"flops_per_device": stats.flops,
                "hbm_bytes_per_device": stats.hbm_bytes,
                "deep_loop_bytes_per_device": stats.deep_loop_bytes,
                "collective_bytes_per_device": stats.collective_bytes,
                "transcendental_per_device": stats.transcendental},
        "roofline": terms,
        "kernel_adjusted_memory_s": adj_bytes / V5E.hbm_bandwidth,
        "model_flops": model_flops,
        "useful_flops_ratio": model_flops / hlo_total if hlo_total else 0.0,
        "tokens": tokens,
    })
    print("roofline:", json.dumps(terms))
    print("kernel_adjusted_memory_s:", rec["kernel_adjusted_memory_s"])
    print("useful_flops_ratio:", rec["useful_flops_ratio"])
    return rec


def _analytic_kernel_io(cfg, shape, n_dev: int) -> float:
    """Per-device HBM bytes the Pallas flash/SSD kernels actually move:
    q/k/v reads + o write, forward + recompute backward (≈3× forward IO),
    per attention layer per token on this device."""
    if shape.kind == "decode":
        return 0.0
    tokens_per_dev = shape.global_batch * shape.seq_len / max(n_dev, 1)
    attn_layers = sum(1 for i in range(cfg.num_layers)
                      if cfg.is_attn_layer(i)) + cfg.encoder_layers
    H = max(cfg.num_heads + cfg.head_pad, 1)
    KV = max(cfg.num_kv_heads, 1)
    hd = cfg.hd if cfg.num_heads else 0
    per_tok = (H + 2 * KV + H) * hd * 4.0        # q,k,v read + o write, fp32
    mult = 3.0 if shape.kind == "train" else 1.0
    io = tokens_per_dev * attn_layers * per_tok * mult
    if cfg.family in ("ssm", "hybrid"):
        ssm_layers = sum(1 for i in range(cfg.num_layers)
                         if not cfg.is_attn_layer(i))
        d_in = cfg.ssm_expand * cfg.d_model
        io += tokens_per_dev * ssm_layers * (2 * d_in + 2 * cfg.ssm_state) \
            * 4.0 * mult
    return io


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mbs", type=int, default=1)
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel residual stream (train cells)")
    ap.add_argument("--pp", type=int, default=1,
                    help="pipeline stages: carve a pipe axis out of the "
                         "data axis (train cells run the GPipe loss)")
    ap.add_argument("--cp", type=int, default=1,
                    help="context parallelism: carve a seq axis out of "
                         "the data axis (train cells run cp_attention)")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have results")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if not args.all:
        name = _cell_name(args.arch, args.shape, args.mesh)
        try:
            rec = run_cell(args.arch, args.shape, args.mesh, out_dir,
                           mbs=args.mbs, sp=args.sp, pp=args.pp,
                           cp=args.cp)
        except Exception:
            rec = {"arch": args.arch, "shape": args.shape,
                   "mesh": args.mesh, "error": traceback.format_exc()}
            (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=2))
            print(rec["error"], file=sys.stderr)
            sys.exit(1)
        (out_dir / f"{name}.json").write_text(json.dumps(rec, indent=2))
        print(f"wrote {out_dir / (name + '.json')}")
        return

    # sweep: one subprocess per cell
    from repro.configs import ARCH_NAMES
    from repro.core.types import SHAPES
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = [(a, s, m) for a in ARCH_NAMES for s in SHAPES for m in meshes]
    failures = []
    for a, s, m in cells:
        name = _cell_name(a, s, m)
        path = out_dir / f"{name}.json"
        if path.exists() and not args.force:
            try:
                if "error" not in json.loads(path.read_text()):
                    print(f"skip (done): {name}")
                    continue
            except Exception:
                pass
        print(f"=== {name} ===", flush=True)
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
             "--shape", s, "--mesh", m, "--out", str(out_dir),
             "--mbs", str(args.mbs)],
            timeout=args.timeout, capture_output=True, text=True)
        dur = time.time() - t0
        if proc.returncode != 0:
            failures.append(name)
            path.write_text(json.dumps(
                {"arch": a, "shape": s, "mesh": m,
                 "error": proc.stderr[-4000:]}, indent=2))
            print(f"FAIL ({dur:.0f}s): {name}\n{proc.stderr[-2000:]}",
                  flush=True)
        else:
            print(f"ok ({dur:.0f}s): {name}", flush=True)
    print(f"sweep done; {len(failures)} failures: {failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
