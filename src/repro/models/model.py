"""Model factory: one uniform API over every architecture family.

``build_model(cfg)`` returns a :class:`Model` with

* ``specs()``               — ParamSpec tree (single source of truth)
* ``init(rng)``             — materialized params
* ``loss(params, batch)``   — training forward (scalar loss, metrics)
* ``prefill(params, batch)``— (last logits, cache)
* ``decode(params, cache, token, pos)`` — one serving step
* ``input_specs(shape)``    — ShapeDtypeStruct stand-ins for every input of
  the given :class:`ShapeConfig` cell (the dry-run contract)
* ``cache_specs(shape)``    — ShapeDtypeStruct tree of the decode cache
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig, ShapeConfig
from repro.models import common as cm
from repro.models import encdec as ed
from repro.models import mamba as mb
from repro.models import transformer as tf
from repro.models.attention import attn_specs  # noqa: F401 (re-export)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    _specs: Any
    loss: Callable
    prefill: Optional[Callable]
    decode: Optional[Callable]
    forward: Callable
    # kernel selection / remat the closures above were built with — the PP
    # step builder needs them to construct a stage-partitioned loss that
    # matches ``loss`` exactly
    impl: str = "auto"
    remat: bool = True

    def specs(self):
        return self._specs

    def init(self, rng):
        return cm.init_params(self._specs, rng)

    def param_shapes(self):
        return cm.param_shapes(self._specs)

    # ------------------------------------------------------------------ #
    def input_specs(self, shape: ShapeConfig) -> dict:
        return input_specs(self.cfg, shape)

    def cache_specs(self, shape: ShapeConfig):
        return cache_specs(self.cfg, shape)


def _img_tokens(cfg: ArchConfig, seq_len: int) -> int:
    """Static image-token capacity per sample for VLM archs."""
    if not cfg.vision_dim:
        return 0
    cap = cfg.max_image_tokens or min(seq_len // 4, 2048)
    return min(cap, seq_len)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no
    allocation) for every model input of this (arch × shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((B, 1), i32)}
    batch: dict = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        batch["loss_mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_frames, cfg.frontend_dim), jnp.bfloat16)
    if cfg.vision_dim:
        K = _img_tokens(cfg, S)
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, K, cfg.vision_dim), jnp.bfloat16)
        batch["image_pos"] = jax.ShapeDtypeStruct((B, K), i32)
        batch["image_valid"] = jax.ShapeDtypeStruct((B, K), i32)
    return batch


def cache_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Decode-cache ShapeDtypeStruct tree for a decode cell: a cache holding
    ``seq_len`` context (rolling window for SWA archs)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16
    clen = tf.kv_cache_len(cfg, S)
    kv = cfg.num_kv_heads
    hd = cfg.hd if cfg.num_heads else 0

    def attn_cache():
        return {"k": jax.ShapeDtypeStruct((B, clen, kv, hd), dt),
                "v": jax.ShapeDtypeStruct((B, clen, kv, hd), dt)}

    if cfg.family == "audio":
        F = cfg.frontend_frames
        self_c = {"k": jax.ShapeDtypeStruct((B, clen, kv, hd), dt),
                  "v": jax.ShapeDtypeStruct((B, clen, kv, hd), dt)}
        cross_c = {"k": jax.ShapeDtypeStruct((B, F, kv, hd), dt),
                   "v": jax.ShapeDtypeStruct((B, F, kv, hd), dt)}
        layer = {"self": self_c, "cross": cross_c}
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + s.shape,
                                           s.dtype), layer)

    pk, reps = tf.group_layout(cfg)
    period = {}
    for j, (mixer, ffn) in enumerate(pk):
        period[f"sub{j}"] = (attn_cache() if mixer == "attn"
                             else mb.cache_spec(cfg, B))
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((reps,) + s.shape, s.dtype), period)


# --------------------------------------------------------------------------- #
def build_model(cfg: ArchConfig, *, impl: str = "auto",
                remat: bool = True) -> Model:
    if cfg.family == "audio":
        specs = ed.encdec_specs(cfg)

        def loss(p, batch):
            return ed.encdec_loss(p, cfg, batch, impl=impl, remat=remat)

        def forward(p, batch):
            enc = ed.encode(p, cfg, batch["frames"], impl=impl, remat=remat)
            x = ed.decode_train(p, cfg, batch["tokens"], enc, impl=impl,
                                remat=remat)
            return tf.unembed(p, cfg, x)

        def prefill(p, batch, extra_cache=0):
            return ed.encdec_prefill(p, cfg, batch, impl=impl, remat=remat,
                                     extra_cache=extra_cache)

        def decode(p, cache, token, pos):
            return ed.encdec_decode(p, cfg, cache, token, pos)

        return Model(cfg, specs, loss, prefill, decode, forward,
                     impl=impl, remat=remat)

    if cfg.family == "vit":
        specs = tf.lm_specs(cfg)

        def loss(p, batch):          # encoder-only: masked-emb regression
            logits, aux = tf.lm_forward(p, cfg, batch, causal=False,
                                        impl=impl, remat=remat)
            ce = cm.cross_entropy(logits, batch["labels"],
                                  batch.get("loss_mask"))
            return ce, {"ce": ce, "aux": aux}

        def forward(p, batch):
            return tf.lm_forward(p, cfg, batch, causal=False, impl=impl,
                                 remat=remat)[0]

        return Model(cfg, specs, loss, None, None, forward,
                     impl=impl, remat=remat)

    specs = tf.lm_specs(cfg)

    def loss(p, batch):
        return tf.lm_loss(p, cfg, batch, impl=impl, remat=remat)

    def forward(p, batch):
        return tf.lm_forward(p, cfg, batch, impl=impl, remat=remat)[0]

    def prefill(p, batch, extra_cache=0):
        return tf.lm_prefill(p, cfg, batch, impl=impl, remat=remat,
                             extra_cache=extra_cache)

    def decode(p, cache, token, pos):
        return tf.lm_decode(p, cfg, cache, token, pos)

    return Model(cfg, specs, loss, prefill, decode, forward,
                 impl=impl, remat=remat)
