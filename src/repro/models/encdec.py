"""Whisper-style encoder–decoder backbone.

Per the assignment, the conv/audio frontend is a STUB: ``input_specs()``
delivers precomputed frame embeddings [B, F, frontend_dim].  The backbone is
12 bidirectional encoder layers + 12 decoder layers (causal self-attention +
cross-attention + GELU MLP).  Positional scheme: RoPE on self-attention
(deviation from Whisper's learned absolute embeddings — noted in DESIGN.md);
cross-attention is position-free as in the original.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.models import attention as att
from repro.models import common as cm
from repro.models import mlp as mlpm
from repro.models.common import ParamSpec
from repro.models.transformer import apply_norm, norm_specs, unembed


def _enc_layer_specs(cfg: ArchConfig) -> dict:
    return {"norm1": norm_specs(cfg), "attn": att.attn_specs(cfg),
            "norm2": norm_specs(cfg), "mlp": mlpm.mlp_specs(cfg)}


def _dec_layer_specs(cfg: ArchConfig) -> dict:
    return {"norm1": norm_specs(cfg), "self_attn": att.attn_specs(cfg),
            "norm_c": norm_specs(cfg), "cross_attn": att.attn_specs(cfg),
            "norm2": norm_specs(cfg), "mlp": mlpm.mlp_specs(cfg)}


def encdec_specs(cfg: ArchConfig) -> dict:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "frontend_proj": ParamSpec((cfg.frontend_dim, cfg.d_model),
                                   ("frames_dim", "embed"), "normal",
                                   dt, (0,)),
        "enc_layers": cm.stack_specs(_enc_layer_specs(cfg),
                                     cfg.encoder_layers),
        "enc_norm": norm_specs(cfg),
        "embed": ParamSpec((cfg.padded_vocab, cfg.d_model),
                           ("vocab", "embed"), "embed", dt),
        "dec_layers": cm.stack_specs(_dec_layer_specs(cfg), cfg.num_layers),
        "final_norm": norm_specs(cfg),
        "unembed": ParamSpec((cfg.d_model, cfg.padded_vocab),
                             ("embed", "vocab"), "normal", dt, (0,)),
    }


def encode(p, cfg: ArchConfig, frames, *, impl: str = "auto",
           remat: bool = True):
    x = jnp.einsum("bfe,ed->bfd",
                   frames.astype(p["frontend_proj"].dtype),
                   p["frontend_proj"])

    def body(x, lp):
        def fn(lp, x):
            h = apply_norm(lp["norm1"], x, cfg)
            h = att.attention(lp["attn"], h, cfg, causal=False, impl=impl)
            x = x + h
            h = apply_norm(lp["norm2"], x, cfg)
            return x + mlpm.mlp(lp["mlp"], h, cfg)
        if remat:
            fn = jax.checkpoint(fn)
        return fn(lp, x), None

    x, _ = jax.lax.scan(body, x, p["enc_layers"])
    return apply_norm(p["enc_norm"], x, cfg)


def _cross_kv(lp, enc_out):
    k = jnp.einsum("btd,dhk->bthk", enc_out, lp["cross_attn"]["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, lp["cross_attn"]["wv"])
    return k, v


def decode_train(p, cfg: ArchConfig, tokens, enc_out, *, impl="auto",
                 remat: bool = True):
    x = jnp.take(p["embed"], tokens, axis=0)

    def body(x, lp):
        def fn(lp, x):
            h = apply_norm(lp["norm1"], x, cfg)
            h = att.attention(lp["self_attn"], h, cfg, causal=True,
                              impl=impl)
            x = x + h
            h = apply_norm(lp["norm_c"], x, cfg)
            kv = _cross_kv(lp, enc_out)
            h = att.attention(lp["cross_attn"], h, cfg, causal=False,
                              kv_override=kv, rope=False, impl=impl)
            x = x + h
            h = apply_norm(lp["norm2"], x, cfg)
            return x + mlpm.mlp(lp["mlp"], h, cfg)
        if remat:
            fn = jax.checkpoint(fn)
        return fn(lp, x), None

    x, _ = jax.lax.scan(body, x, p["dec_layers"])
    return apply_norm(p["final_norm"], x, cfg)


def encdec_loss(p, cfg: ArchConfig, batch, *, impl="auto", remat=True):
    enc_out = encode(p, cfg, batch["frames"], impl=impl, remat=remat)
    x = decode_train(p, cfg, batch["tokens"], enc_out, impl=impl,
                     remat=remat)
    logits = unembed(p, cfg, x)
    ce = cm.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def encdec_prefill(p, cfg: ArchConfig, batch, *, impl="auto", remat=True,
                   extra_cache: int = 0):
    """Returns (last logits [B, V], cache). Cache per layer: self KV +
    precomputed cross KV."""
    enc_out = encode(p, cfg, batch["frames"], impl=impl, remat=remat)
    tokens = batch["tokens"]
    S = tokens.shape[1] + extra_cache
    x = jnp.take(p["embed"], tokens, axis=0)

    def body(x, lp):
        h = apply_norm(lp["norm1"], x, cfg)
        h, self_cache = att.attention_prefill(lp["self_attn"], h, cfg,
                                              cache_len=S, impl=impl)
        x = x + h
        h = apply_norm(lp["norm_c"], x, cfg)
        ck, cv = _cross_kv(lp, enc_out)
        h = att.attention(lp["cross_attn"], h, cfg, causal=False,
                          kv_override=(ck, cv), rope=False, impl=impl)
        x = x + h
        h = apply_norm(lp["norm2"], x, cfg)
        x = x + mlpm.mlp(lp["mlp"], h, cfg)
        return x, {"self": self_cache, "cross": {"k": ck, "v": cv}}

    x, cache = jax.lax.scan(body, x, p["dec_layers"])
    x = apply_norm(p["final_norm"], x, cfg)
    return unembed(p, cfg, x[:, -1:])[:, 0], cache


def encdec_decode(p, cfg: ArchConfig, cache, token, pos):
    x = jnp.take(p["embed"], token, axis=0)

    def body(x, inp):
        lp, lcache = inp
        h = apply_norm(lp["norm1"], x, cfg)
        h, nc = att.attention_decode(lp["self_attn"], h, lcache["self"],
                                     cfg, pos=pos)
        x = x + h
        h = apply_norm(lp["norm_c"], x, cfg)
        h = att.attention_decode(lp["cross_attn"], h, None, cfg, pos=pos,
                                 kv_override=(lcache["cross"]["k"],
                                              lcache["cross"]["v"]))
        x = x + h
        h = apply_norm(lp["norm2"], x, cfg)
        x = x + mlpm.mlp(lp["mlp"], h, cfg)
        return x, {"self": nc, "cross": lcache["cross"]}

    x, new_cache = jax.lax.scan(body, x, (p["dec_layers"], cache))
    x = apply_norm(p["final_norm"], x, cfg)
    return unembed(p, cfg, x)[:, 0], new_cache
