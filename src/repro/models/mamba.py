"""Mamba-2 block (SSD) with chunked-scan training and recurrent decode.

Layout follows the Mamba-2 reference: a single input projection produces
(z, x, B, C, dt); (x, B, C) go through a short depthwise causal conv; the SSD
scan runs per head; the output is gated by silu(z), RMS-normed, projected.

Decode cache per layer: ``{"conv": [B, conv_w-1, d_conv_ch],
"ssm": [B, nheads, headdim, n]}``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.kernels import ops as kops
from repro.kernels.ssd_scan import ssd_decode_step
from repro.models import common as cm
from repro.models.common import ParamSpec


def dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_headdim
    n = cfg.ssm_state
    conv_ch = d_in + 2 * n
    return d_in, nheads, n, conv_ch


def mamba_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_in, nheads, n, conv_ch = dims(cfg)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "in_proj": ParamSpec((d, 2 * d_in + 2 * n + nheads),
                             ("embed", "d_inner"), "normal", dt, (0,)),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_ch), ("conv", "d_inner"),
                            "normal", dt, (0,)),
        "conv_b": ParamSpec((conv_ch,), ("d_inner",), "zeros", dt),
        "A_log": ParamSpec((nheads,), ("ssm_heads",), "zeros", jnp.float32),
        "D": ParamSpec((nheads,), ("ssm_heads",), "ones", jnp.float32),
        "dt_bias": ParamSpec((nheads,), ("ssm_heads",), "zeros", jnp.float32),
        "norm": ParamSpec((d_in,), ("d_inner",), "ones", jnp.float32),
        "out_proj": ParamSpec((d_in, d), ("d_inner", "embed"),
                              "normal", dt, (0,)),
    }


def _split(zxbcdt, cfg: ArchConfig):
    d_in, nheads, n, _ = dims(cfg)
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + d_in + 2 * n]
    dt_raw = zxbcdt[..., d_in + d_in + 2 * n:]
    return z, xBC, dt_raw


def _causal_conv(xBC, w, b, prev=None):
    """Depthwise causal conv along seq. xBC [B,S,Ch]; w [W,Ch].

    prev: optional [B, W-1, Ch] left-context (for chunked prefill); returns
    (out [B,S,Ch], new_state [B, W-1, Ch]).
    """
    W = w.shape[0]
    Bsz = xBC.shape[0]
    if prev is None:
        prev = jnp.zeros((Bsz, W - 1, xBC.shape[-1]), xBC.dtype)
    xp = jnp.concatenate([prev, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * w[i] for i in range(W))
    out = out + b
    new_state = xp[:, xp.shape[1] - (W - 1):]
    return jax.nn.silu(out.astype(jnp.float32)).astype(xBC.dtype), new_state


def mamba(p, x, cfg: ArchConfig, *, cache=None, return_cache: bool = False,
          impl: str = "auto"):
    """Full-sequence Mamba-2. x: [B,S,D]."""
    B, S, _ = x.shape
    d_in, nheads, n, conv_ch = dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt_raw = _split(zxbcdt, cfg)
    conv_prev = cache["conv"] if cache is not None else None
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_prev)
    xs = xBC[..., :d_in].reshape(B, S, nheads, cfg.ssm_headdim)
    Bm = xBC[..., d_in:d_in + n]
    Cm = xBC[..., d_in + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    init_state = cache["ssm"] if cache is not None else None
    if return_cache:
        from repro.kernels.ssd_scan import ssd_chunked_jnp
        y, state = ssd_chunked_jnp(xs, dt, A, Bm, Cm, p["D"],
                                   initial_state=init_state,
                                   return_state=True)
    else:
        y = kops.ssd_scan(xs, dt, A, Bm, Cm, p["D"], impl=impl)
        state = None
    y = y.reshape(B, S, d_in)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                    p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if return_cache:
        return out, {"conv": conv_state, "ssm": state}
    return out


def mamba_decode(p, x, cache, cfg: ArchConfig):
    """One-token decode. x: [B,1,D]; cache {'conv','ssm'}."""
    B = x.shape[0]
    d_in, nheads, n, conv_ch = dims(cfg)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt_raw = _split(zxbcdt, cfg)
    # conv over stored window + current input
    W = cfg.ssm_conv
    window = jnp.concatenate([cache["conv"], xBC], axis=1)   # [B,W,Ch]
    out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC1 = jax.nn.silu(out.astype(jnp.float32)).astype(x.dtype)[:, None]
    new_conv = window[:, 1:]
    xt = xBC1[:, 0, :d_in].reshape(B, nheads, cfg.ssm_headdim)
    Bt = xBC1[:, 0, d_in:d_in + n]
    Ct = xBC1[:, 0, d_in + n:]
    dtt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    state, y = ssd_decode_step(cache["ssm"], xt, dtt, A, Bt, Ct, p["D"])
    y = y.reshape(B, 1, d_in)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                    p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": new_conv, "ssm": state}


def cache_spec(cfg: ArchConfig, batch: int):
    d_in, nheads, n, conv_ch = dims(cfg)
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_ch), dt),
        "ssm": jax.ShapeDtypeStruct((batch, nheads, cfg.ssm_headdim, n),
                                    jnp.float32),
    }
