"""Top-k mixture-of-experts with capacity-slotted dispatch (GShard-style).

Dispatch is *grouped by sample* so that, with batch sharded over the data
axis, routing decisions and capacity bookkeeping stay local to each data
shard; only the expert einsum crosses the expert-parallel (model) axis —
that crossing is the EP all-to-all, inserted by the SPMD partitioner.

Per group (one sample): tokens choose top-k experts; positions inside each
expert's capacity buffer come from a cumulative count over (token, k) slots;
overflow tokens are dropped (residual passthrough), as in GShard/Switch with
``capacity_factor``.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.models.common import ParamSpec


def moe_specs(cfg: ArchConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "router": ParamSpec((d, e), ("embed_nosplit", "experts_r"),
                            "normal", jnp.float32, (0,)),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "mlp"),
                            "normal", dt, (1,)),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "mlp"),
                          "normal", dt, (1,)),
        "w_down": ParamSpec((e, f, d), ("experts", "mlp", "embed"),
                            "normal", dt, (1,)),
    }


def capacity(tokens_per_group: int, cfg: ArchConfig) -> int:
    c = math.ceil(tokens_per_group * cfg.experts_per_token
                  / cfg.num_experts * cfg.capacity_factor)
    return max(int(c), cfg.experts_per_token)


def moe(p, x, cfg: ArchConfig, *,
        return_stats: bool = False) -> Tuple[jnp.ndarray, ...]:
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar).

    return_stats=True additionally returns the router statistics the aux
    loss is built from — ``stats[0] = frac`` (mean routed assignments per
    expert), ``stats[1] = prob`` (mean router probability per expert), both
    [E] fp32, token-means over this call's batch.  Both are *linear* in the
    token population, so callers that split a batch into microbatches
    (``repro.dist.pipeline.build_pp_loss``) can average stats across
    microbatches/shards and recover the exact full-batch aux
    ``E * sum(frac * prob) / K`` — the scalar aux itself is nonlinear in
    (frac, prob) and cannot be averaged."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = capacity(S, cfg)

    logits = (x.astype(jnp.float32) @ p["router"])          # [B,S,E]
    gate_logits, idx = jax.lax.top_k(logits, K)              # [B,S,K]
    gates = jax.nn.softmax(gate_logits, axis=-1)             # renorm top-k

    # position of each (token, k) inside its expert's capacity buffer
    oh = jax.nn.one_hot(idx, E, dtype=jnp.int32)             # [B,S,K,E]
    flat = oh.reshape(B, S * K, E)
    pos = jnp.cumsum(flat, axis=1) - 1                       # [B,S*K,E]
    pos = jnp.sum(pos.reshape(B, S, K, E) * oh, axis=-1)     # [B,S,K]
    keep = pos < C
    pos_c = jnp.clip(pos, 0, C - 1)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E), axis=2),
                    axis=(0, 1))                             # tokens per e
    prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * prob) / K

    # dispatch: xe [B, E, C, D].  The scatter is batch-local by
    # construction (indices only permute within a sample); the explicit
    # constraint stops GSPMD from conservatively all-reducing the
    # dispatch buffers (observed: 1.2 TB/step fp32 all-reduces on
    # mixtral-8x22b before this — EXPERIMENTS.md §Perf)
    from repro.models import common as cm
    b_ix = jnp.broadcast_to(jnp.arange(B)[:, None, None], idx.shape)
    w = jnp.where(keep, 1.0, 0.0).astype(x.dtype)
    xe = jnp.zeros((B, E, C, D), x.dtype)
    xe = xe.at[b_ix, idx, pos_c].add(x[:, :, None, :] * w[..., None])
    xe = cm.shard_act(xe, "moe_dispatch")

    # expert computation (SwiGLU) — crosses the EP axis
    g = cm.grad_dtype_barrier(jnp.einsum("becd,edf->becf", xe, p["w_gate"]))
    u = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])
    ye = cm.shard_act(ye, "moe_dispatch")

    # combine: y[b,s] = sum_k gate * keep * ye[b, idx, pos]
    gathered = ye[b_ix, idx, pos_c]                          # [B,S,K,D]
    gw = (gates.astype(jnp.float32)
          * keep.astype(jnp.float32)).astype(x.dtype)
    y = jnp.sum(gathered * gw[..., None], axis=2)
    if return_stats:
        stats = jnp.stack([frac, prob]).astype(jnp.float32)
        return y, aux, stats
    return y, aux
