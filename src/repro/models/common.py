"""Common model building blocks: param-spec registry, norms, RoPE, inits.

The framework is pure JAX (no flax).  Every model defines a *param-spec tree*:
a nested dict whose leaves are :class:`ParamSpec` — (shape, dtype, logical
axes, init).  From that single source of truth we derive

* materialized parameters         (``init_params``)
* ``jax.ShapeDtypeStruct`` stand-ins for the dry-run (``param_shapes``)
* ``NamedSharding`` trees          (``repro.dist.sharding``)
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: tuple
    axes: tuple            # logical axis name (or None) per dim; len == ndim
    init: str = "normal"   # normal | zeros | ones | scaled | embed
    dtype: Any = jnp.bfloat16
    fan_in_dims: tuple = ()   # dims contracted at use time (for scaled init)


def _leaf_is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=_leaf_is_spec)


def param_shapes(spec_tree):
    """ShapeDtypeStruct tree — no allocation; used by the dry-run."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree)


def _init_one(spec: ParamSpec, key) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        # 1/sqrt(d) keeps tied-unembedding logits O(1) at init
        std = 1.0 / math.sqrt(spec.shape[-1])
        return (jax.random.normal(key, spec.shape, jnp.float32) * std
                ).astype(spec.dtype)
    # scaled / normal: truncated-normal with fan-in scaling
    fan_in = 1
    dims = spec.fan_in_dims or tuple(range(max(len(spec.shape) - 1, 1)))
    for d in dims:
        fan_in *= spec.shape[d]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, jnp.float32)
            * std).astype(spec.dtype)


def init_params(spec_tree, key):
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree,
                                                 is_leaf=_leaf_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Prepend a stacked `layers` dim to every spec (for scan-over-layers)."""
    return tree_map_specs(
        lambda s: ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init,
                            s.dtype,
                            tuple(d + 1 for d in s.fan_in_dims)),
        spec_tree)


# --------------------------------------------------------------------------- #
# Activation-sharding hook (set by the step builder under a mesh context;
# models call shard_act at section boundaries so per-section sharding
# constraints reach inside scan bodies)
# --------------------------------------------------------------------------- #
import contextlib

_ACT_HOOK = None


def shard_act(x, kind: str):
    return _ACT_HOOK(x, kind) if _ACT_HOOK is not None else x


@contextlib.contextmanager
def act_hook(fn):
    global _ACT_HOOK
    prev = _ACT_HOOK
    _ACT_HOOK = fn
    try:
        yield
    finally:
        _ACT_HOOK = prev


# --------------------------------------------------------------------------- #
# Gradient-dtype barrier
# --------------------------------------------------------------------------- #
# fp32 casts inside norms/activations (for numerics) silently PROMOTE the
# whole backward pass to fp32: the cotangent of `x.astype(f32)` w.r.t. a
# bf16 x is fp32, and it stays fp32 through every transpose-einsum below —
# doubling activation-grad HBM traffic and TP all-reduce bytes (measured:
# fp32 [mbs,S,D] all-reduces dominating qwen2.5/mixtral collective terms,
# EXPERIMENTS.md §Perf).  This identity op casts the cotangent back to the
# primal dtype, keeping forward numerics (fp32 accumulate) unchanged.
@jax.custom_vjp
def grad_dtype_barrier(x):
    return x


def _gdb_fwd(x):
    # residual: zero-size array carrying only the primal dtype
    return x, jnp.zeros((0,), x.dtype)


def _gdb_bwd(res, g):
    return (g.astype(res.dtype),)


grad_dtype_barrier.defvjp(_gdb_fwd, _gdb_bwd)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    x = grad_dtype_barrier(x)
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps):
    x = grad_dtype_barrier(x)
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


def norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed_nosplit",), "ones")


# --------------------------------------------------------------------------- #
# Rotary position embeddings
# --------------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    x = grad_dtype_barrier(x)
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta))        # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                     # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Misc
# --------------------------------------------------------------------------- #
def dense(x: jnp.ndarray, w: jnp.ndarray, contract: int = 1) -> jnp.ndarray:
    """x @ w contracting the last `contract` dims of x with the first of w."""
    nx, nw = x.ndim, w.ndim
    return jax.lax.dot_general(
        x, w,
        (((tuple(range(nx - contract, nx))), tuple(range(contract))), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


def softmax_fp32(logits: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    return jax.nn.softmax(logits.astype(jnp.float32), axis=axis)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy in fp32. logits [..., V], labels [...]."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
