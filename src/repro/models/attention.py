"""GQA attention with RoPE, optional QKV bias, sliding window, KV cache.

Three entry points:

* ``attention``         — full-sequence (train / prefill); flash path.
* ``attention_prefill`` — full-sequence + writes the KV cache.
* ``attention_decode``  — one new token against a (possibly rolling) cache.

Cache layout (per layer): ``{"k": [B, C, KV, hd], "v": [B, C, KV, hd]}``
where C = cache capacity (= seq_len, or sliding_window for SWA archs).
Keys are stored post-RoPE.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.kernels import ops as kops
from repro.models import common as cm
from repro.models.common import ParamSpec


# --------------------------------------------------------------------------- #
# Pluggable full-sequence attention implementation (set by the step builder
# under a mesh context, like common.act_hook).  This is how distributed
# attention — repro.dist.context.cp_attention for CP sections — reaches
# inside every model's self-attention without the models knowing about
# meshes.  The callable contract:
#     impl(q, k, v, *, causal, window, segment_q, segment_kv, scale) -> o
# with q [B, S, H, D] (head-padded), k/v [B, S, KV, D], o like q.
# --------------------------------------------------------------------------- #
_ATTN_IMPL = None


@contextlib.contextmanager
def attention_impl(fn):
    """Install ``fn`` as the full-sequence attention implementation for the
    duration of the context (trace-time; serving paths are unaffected)."""
    global _ATTN_IMPL
    prev = _ATTN_IMPL
    _ATTN_IMPL = fn
    try:
        yield
    finally:
        _ATTN_IMPL = prev


def attn_specs(cfg: ArchConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim"),
                        "normal", dt, (0,)),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"),
                        "normal", dt, (0,)),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim"),
                        "normal", dt, (0,)),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"),
                        "normal", dt, (0, 1)),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), "zeros", dt)
        specs["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros", dt)
        specs["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros", dt)
    return specs


def _project_qkv(p, x, cfg: ArchConfig, positions, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if rope:
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _pad_q_heads(q, cfg: ArchConfig):
    """Append cfg.head_pad zero Q-heads, preserving KV-group layout.

    The zero heads make (H + pad) divide the TP axis so attention compute
    shards cleanly; they are sliced off again before the output projection
    — numerics are unchanged (verified in tests)."""
    if not cfg.head_pad:
        return q
    B, S, H, D = q.shape
    KV = cfg.num_kv_heads
    G = H // KV
    Gp = (H + cfg.head_pad) // KV
    qg = q.reshape(B, S, KV, G, D)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, Gp - G), (0, 0)))
    return qg.reshape(B, S, KV * Gp, D)


def _unpad_o_heads(o, cfg: ArchConfig, H: int):
    if not cfg.head_pad:
        return o
    B, S, Hp, D = o.shape
    KV = cfg.num_kv_heads
    G = H // KV
    og = o.reshape(B, S, KV, Hp // KV, D)[:, :, :, :G]
    return og.reshape(B, S, H, D)


def attention(p, x, cfg: ArchConfig, *, causal: bool = True,
              positions: Optional[jnp.ndarray] = None,
              segment_ids: Optional[jnp.ndarray] = None,
              kv_override=None, rope: bool = True,
              impl: str = "auto") -> jnp.ndarray:
    """Full-sequence attention. x: [B, S, D]."""
    B, S, _ = x.shape
    H = cfg.num_heads
    if positions is None:
        positions = jnp.arange(S)[None, :].astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, rope)
    if kv_override is not None:                       # cross-attention
        k, v = kv_override
    q = cm.shard_act(_pad_q_heads(q, cfg), "attn_q")
    if _ATTN_IMPL is not None:
        o = _ATTN_IMPL(q, k, v, causal=causal, window=cfg.sliding_window,
                       segment_q=segment_ids, segment_kv=segment_ids,
                       scale=None)
    else:
        o = kops.flash_attention(
            q, k, v, causal=causal, window=cfg.sliding_window,
            segment_q=segment_ids, segment_kv=segment_ids, impl=impl)
    o = _unpad_o_heads(cm.shard_act(o, "attn_q"), cfg, H)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def attention_prefill(p, x, cfg: ArchConfig, *, cache_len: int,
                      impl: str = "auto"):
    """Causal attention over the prompt; returns (out, cache).

    cache_len — cache capacity.  For SWA archs this may be < S: the cache
    keeps only the trailing ``cache_len`` positions (rolling layout: slot =
    pos % cache_len).
    """
    B, S, _ = x.shape
    H = cfg.num_heads
    positions = jnp.arange(S)[None, :].astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, rope=True)
    q = cm.shard_act(_pad_q_heads(q, cfg), "attn_q")
    o = kops.flash_attention(q, k, v, causal=True,
                             window=cfg.sliding_window, impl=impl)
    o = _unpad_o_heads(cm.shard_act(o, "attn_q"), cfg, H)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if cache_len >= S:
        pad = cache_len - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        # rolling window: keep the last cache_len keys at slot pos % cache_len
        tail_k = k[:, S - cache_len:]
        tail_v = v[:, S - cache_len:]
        shift = S % cache_len
        kc = jnp.roll(tail_k, shift, axis=1)
        vc = jnp.roll(tail_v, shift, axis=1)
    return out, {"k": kc, "v": vc}


def attention_decode(p, x, cache, cfg: ArchConfig, *, pos: jnp.ndarray,
                     kv_override=None):
    """One-token decode. x: [B, 1, D]; pos: scalar int32 absolute position."""
    B = x.shape[0]
    C = cache["k"].shape[1] if cache is not None else 0
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions, rope=kv_override is None)
    if kv_override is not None:                       # cross-attn: static cache
        kc, vc = kv_override
        return _attend_full(q, kc, vc, p, cfg, valid=None)
    slot = jnp.mod(pos, C)
    kc = cache["k"].at[:, slot].set(k[:, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[:, slot].set(v[:, 0].astype(cache["v"].dtype))
    # absolute position of each slot s: pos - ((pos - s) mod C); valid if >= 0
    slots = jnp.arange(C)
    abs_pos = pos - jnp.mod(pos - slots, C)
    valid = abs_pos >= 0
    if cfg.sliding_window > 0:
        valid &= (pos - abs_pos) < cfg.sliding_window
    out = _attend_full(q, kc, vc, p, cfg, valid=valid)
    return out, {"k": kc, "v": vc}


def _attend_full(q, kc, vc, p, cfg, valid):
    """Direct (non-flash) attention of a single query over a full cache."""
    B, S, H, D = q.shape
    KV = kc.shape[2]
    G = H // KV
    qr = q.reshape(B, S, KV, G, D).astype(jnp.float32)
    logits = jnp.einsum("bskgd,btkd->bkgst", qr,
                        kc.astype(jnp.float32)) * (D ** -0.5)
    if valid is not None:
        logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    prob = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", prob, vc.astype(jnp.float32))
    o = o.reshape(B, S, H, D).astype(q.dtype)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])
