"""Decoder-only LM covering the dense / MoE / SSM / hybrid families.

Layers are *grouped* for scan-over-layers: a group is a (possibly
heterogeneous) period of sub-layers whose parameters are stacked over the
number of repeats.  Dense/MoE/SSM models have one group with period 1; jamba
has period ``attn_period`` (8) with mamba/attention mixers and MLP/MoE FFNs
interleaved.  Each sub-layer body is wrapped in ``jax.checkpoint``
(activation remat) — compile time and HBM stay bounded at 500K-token shapes.

VLM archs (``cfg.vision_dim > 0``) additionally scatter projected patch
embeddings (delivered by the stubbed frontend) into the token stream.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.models import attention as att
from repro.models import common as cm
from repro.models import mamba as mb
from repro.models import mlp as mlpm
from repro.models import moe as moem
from repro.models.common import ParamSpec


# --------------------------------------------------------------------------- #
# Norm helpers
# --------------------------------------------------------------------------- #
def norm_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    if cfg.norm_type == "ln":
        return {"scale": ParamSpec((d,), ("embed_nosplit",), "ones"),
                "bias": ParamSpec((d,), ("embed_nosplit",), "zeros")}
    return {"scale": ParamSpec((d,), ("embed_nosplit",), "ones")}


def apply_norm(p, x, cfg: ArchConfig):
    if cfg.norm_type == "ln":
        return cm.layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return cm.rms_norm(x, p["scale"], cfg.norm_eps)


# --------------------------------------------------------------------------- #
# Layer-kind layout
# --------------------------------------------------------------------------- #
def layer_kinds(cfg: ArchConfig) -> list:
    """Per layer: (mixer, ffn) with mixer ∈ {attn, mamba}, ffn ∈ {mlp, moe,
    None}."""
    kinds = []
    for i in range(cfg.num_layers):
        mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
        if cfg.is_moe_layer(i):
            ffn = "moe"
        elif cfg.d_ff > 0:
            ffn = "mlp"
        else:
            ffn = None
        kinds.append((mixer, ffn))
    return kinds


def group_layout(cfg: ArchConfig) -> Tuple[list, int]:
    """Returns (period_kinds, repeats). The whole stack is `repeats` copies
    of `period_kinds` (scan-over-layers granularity)."""
    kinds = layer_kinds(cfg)
    period = cfg.attn_period if cfg.attn_period else 1
    if cfg.moe_period:
        import math
        period = math.lcm(period, cfg.moe_period)
    assert cfg.num_layers % period == 0, (cfg.name, period)
    reps = cfg.num_layers // period
    pk = kinds[:period]
    for r in range(reps):
        assert kinds[r * period:(r + 1) * period] == pk
    return pk, reps


def _sublayer_specs(cfg: ArchConfig, mixer: str, ffn: Optional[str]) -> dict:
    s: dict = {"norm1": norm_specs(cfg)}
    if mixer == "attn":
        s["attn"] = att.attn_specs(cfg)
    else:
        s["mamba"] = mb.mamba_specs(cfg)
    if ffn is not None:
        s["norm2"] = norm_specs(cfg)
        s[ffn] = mlpm.mlp_specs(cfg) if ffn == "mlp" else moem.moe_specs(cfg)
    return s


def lm_specs(cfg: ArchConfig) -> dict:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    pk, reps = group_layout(cfg)
    period = {}
    for j, (mixer, ffn) in enumerate(pk):
        period[f"sub{j}"] = _sublayer_specs(cfg, mixer, ffn)
    specs = {
        "embed": ParamSpec((cfg.padded_vocab, cfg.d_model),
                           ("vocab", "embed"), "embed", dt),
        "final_norm": norm_specs(cfg),
        "layers": cm.stack_specs(period, reps),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                     ("embed", "vocab"), "normal", dt, (0,))
    if cfg.vision_dim:
        specs["vision_proj"] = ParamSpec((cfg.vision_dim, cfg.d_model),
                                         ("vision", "embed"), "normal",
                                         dt, (0,))
    return specs


# --------------------------------------------------------------------------- #
# Forward passes
# --------------------------------------------------------------------------- #
def _sublayer_fwd(lp, x, cfg: ArchConfig, mixer: str, ffn: Optional[str],
                  *, causal: bool, segment_ids, impl: str,
                  collect_stats: bool = False,
                  tp_axis: Optional[str] = None, tp_attn: bool = False,
                  tp_ffn: bool = False):
    """One (mixer, ffn) sub-layer.  Returns (x, aux); with
    ``collect_stats`` (MoE sub-layers only) returns (x, aux, stats) where
    stats are the [2, E] router statistics of :func:`repro.models.moe.moe`
    — the linear quantities PP microbatch accumulation needs for an exact
    aux term.

    tp_axis/tp_attn/tp_ffn — Megatron-style manual tensor parallelism for
    callers inside a shard_map (``repro.dist.pipeline``): the caller's
    in_specs slice ``heads``/``kv_heads`` (tp_attn) and the FFN ``mlp``
    dim (tp_ffn) over ``tp_axis``, so each shard computes a head/f-slice
    and the output contractions are *partial* sums — psummed here, after
    the mixer and after the FFN.  Everything between the two psums is
    elementwise per slice, so numerics match the unsharded layer exactly
    (the GELU output bias, added after the f-contraction, is pre-scaled by
    1/tp so the psum reconstructs it once)."""
    h = apply_norm(lp["norm1"], x, cfg)
    if mixer == "attn":
        h = att.attention(lp["attn"], h, cfg, causal=causal,
                          segment_ids=segment_ids, impl=impl)
        if tp_attn and tp_axis is not None:
            h = jax.lax.psum(h, tp_axis)
    else:
        h = mb.mamba(lp["mamba"], h, cfg, impl=impl)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    stats = None
    if ffn is not None:
        h = apply_norm(lp["norm2"], x, cfg)
        if ffn == "mlp":
            fp = lp[ffn]
            if tp_ffn and tp_axis is not None and cfg.mlp_act == "gelu":
                tp = jax.lax.psum(1, tp_axis)   # static axis size
                fp = dict(fp, b_out=fp["b_out"] / tp)
            h = mlpm.mlp(fp, h, cfg)
        elif collect_stats:
            h, aux, stats = moem.moe(lp[ffn], h, cfg, return_stats=True)
        else:
            h, aux = moem.moe(lp[ffn], h, cfg)
        if tp_ffn and tp_axis is not None:
            # dense MLP f-slice / per-expert f-slice → partial output.
            # MoE router stats/aux come from the replicated router and are
            # already identical on every tp shard — only h is partial.
            h = jax.lax.psum(h, tp_axis)
        x = x + h
    if collect_stats:
        assert ffn == "moe", "collect_stats only applies to MoE sub-layers"
        return x, aux, stats
    return x, aux


def vision_scatter(p, cfg: ArchConfig, x: jnp.ndarray,
                   batch: dict) -> jnp.ndarray:
    """Scatter projected patch embeddings into the token stream (VLM archs).
    Separated from the vocab lookup so vocab-parallel callers can run it
    once on the combined (post-psum) embedding."""
    if not (cfg.vision_dim and "image_embeds" in batch):
        return x
    vh = jnp.einsum("bkv,vd->bkd",
                    batch["image_embeds"].astype(x.dtype),
                    p["vision_proj"])
    valid = batch["image_valid"].astype(x.dtype)[..., None]   # [B,K,1]
    B = x.shape[0]
    b_ix = jnp.broadcast_to(jnp.arange(B)[:, None],
                            batch["image_pos"].shape)
    upd = vh * valid
    # replace token embedding at image positions (invalid slots add 0 at
    # position 0 after being zeroed and re-added — use where-style update)
    cur = x[b_ix, batch["image_pos"]]
    x = x.at[b_ix, batch["image_pos"]].add(upd - cur * valid)
    return x


def embed_tokens(p, cfg: ArchConfig, batch: dict) -> jnp.ndarray:
    x = jnp.take(p["embed"], batch["tokens"], axis=0)
    return vision_scatter(p, cfg, x, batch)


def lm_forward(p, cfg: ArchConfig, batch: dict, *, causal: bool = True,
               impl: str = "auto", remat: bool = True,
               logits_out: bool = True):
    """Full-sequence forward. Returns (logits_or_hidden, aux_loss)."""
    pk, reps = group_layout(cfg)
    x = cm.shard_act(embed_tokens(p, cfg, batch), "hidden")
    segment_ids = batch.get("segment_ids")

    def period_body(x, period_params):
        aux_tot = jnp.zeros((), jnp.float32)
        for j, (mixer, ffn) in enumerate(pk):
            fn = functools.partial(_sublayer_fwd, cfg=cfg, mixer=mixer,
                                   ffn=ffn, causal=causal,
                                   segment_ids=segment_ids, impl=impl)
            if remat:
                fn = jax.checkpoint(fn)
            x, aux = fn(period_params[f"sub{j}"], x)
            x = cm.shard_act(x, "hidden")
            aux_tot = aux_tot + aux
        return x, aux_tot

    x, auxs = jax.lax.scan(period_body, x, p["layers"])
    x = apply_norm(p["final_norm"], x, cfg)
    aux = jnp.sum(auxs)
    if not logits_out:
        return x, aux
    logits = unembed(p, cfg, x)
    return logits, aux


def unembed(p, cfg: ArchConfig, x):
    x = cm.grad_dtype_barrier(x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"])
    if cfg.vocab_pad:
        # mask padded vocab slots: exact lse/softmax of the unpadded model
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, -1e30)
    return cm.shard_act(logits, "logits")


def lm_loss(p, cfg: ArchConfig, batch: dict, *, impl: str = "auto",
            remat: bool = True, aux_weight: float = 0.01):
    logits, aux = lm_forward(p, cfg, batch, impl=impl, remat=remat)
    loss = cm.cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# --------------------------------------------------------------------------- #
# Prefill / decode (serving)
# --------------------------------------------------------------------------- #
def kv_cache_len(cfg: ArchConfig, total_len: int) -> int:
    if cfg.sliding_window > 0:
        return min(cfg.sliding_window, total_len)
    return total_len


def _sublayer_prefill(lp, x, cfg, mixer, ffn, *, cache_len, impl):
    h = apply_norm(lp["norm1"], x, cfg)
    if mixer == "attn":
        h, cache = att.attention_prefill(lp["attn"], h, cfg,
                                         cache_len=cache_len, impl=impl)
    else:
        h, cache = mb.mamba(lp["mamba"], h, cfg, return_cache=True,
                            impl=impl)
    x = x + h
    if ffn is not None:
        h = apply_norm(lp["norm2"], x, cfg)
        if ffn == "mlp":
            h = mlpm.mlp(lp[ffn], h, cfg)
        else:
            h, _ = moem.moe(lp[ffn], h, cfg)
        x = x + h
    return x, cache


def lm_prefill(p, cfg: ArchConfig, batch: dict, *, impl: str = "auto",
               remat: bool = True, extra_cache: int = 0):
    """Prompt processing. Returns (last-token logits [B,V], cache).

    extra_cache: additional cache capacity beyond the prompt (for decoding
    further tokens)."""
    pk, reps = group_layout(cfg)
    S = batch["tokens"].shape[1]
    clen = kv_cache_len(cfg, S + extra_cache)
    x = embed_tokens(p, cfg, batch)

    def period_body(x, period_params):
        caches = {}
        for j, (mixer, ffn) in enumerate(pk):
            fn = functools.partial(_sublayer_prefill, cfg=cfg, mixer=mixer,
                                   ffn=ffn, cache_len=clen, impl=impl)
            if remat:
                fn = jax.checkpoint(fn)
            x, cache = fn(period_params[f"sub{j}"], x)
            caches[f"sub{j}"] = cache
        return x, caches

    x, caches = jax.lax.scan(period_body, x, p["layers"])
    x = apply_norm(p["final_norm"], x, cfg)
    logits = unembed(p, cfg, x[:, -1:])[:, 0]
    return logits, caches


def _sublayer_decode(lp, x, cache, cfg, mixer, ffn, *, pos):
    h = apply_norm(lp["norm1"], x, cfg)
    if mixer == "attn":
        h, new_cache = att.attention_decode(lp["attn"], h, cache, cfg,
                                            pos=pos)
    else:
        h, new_cache = mb.mamba_decode(lp["mamba"], h, cache, cfg)
    x = x + h
    if ffn is not None:
        h = apply_norm(lp["norm2"], x, cfg)
        if ffn == "mlp":
            h = mlpm.mlp(lp[ffn], h, cfg)
        else:
            h, _ = moem.moe(lp[ffn], h, cfg)
        x = x + h
    return x, new_cache


def lm_decode(p, cfg: ArchConfig, cache, token, pos):
    """One decode step. token [B,1] int32; pos scalar int32 (absolute).
    Returns (logits [B,V], new_cache)."""
    pk, reps = group_layout(cfg)
    x = jnp.take(p["embed"], token, axis=0)

    def period_body(x, inp):
        period_params, period_cache = inp
        new_caches = {}
        for j, (mixer, ffn) in enumerate(pk):
            x, nc = _sublayer_decode(period_params[f"sub{j}"], x,
                                     period_cache[f"sub{j}"], cfg, *pk[j],
                                     pos=pos)
            new_caches[f"sub{j}"] = nc
        return x, new_caches

    x, new_cache = jax.lax.scan(period_body, x, (p["layers"], cache))
    x = apply_norm(p["final_norm"], x, cfg)
    logits = unembed(p, cfg, x)[:, 0]
    return logits, new_cache
