"""ViT encoder section for compound VLM workloads (paper §2.1/§4.1).

The assigned ``pixtral-12b`` arch stubs its frontend (the backbone consumes
precomputed patch embeddings — see ``transformer.embed_tokens``).  This
module is the *compound-workload* ViT: a real bidirectional transformer over
patch embeddings that forms its own Maestro section with a CP-heavy
parallelism config, followed by the 4:1 sequence downsampling the paper
describes (Qwen3-VL style) and a projection into the LM's embedding space.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.models import attention as att
from repro.models import common as cm
from repro.models import mlp as mlpm
from repro.models.common import ParamSpec
from repro.models.transformer import apply_norm, norm_specs


def vit_config(*, num_layers=24, d_model=1024, num_heads=16, d_ff=4096,
               patch_dim=768, downsample=4, out_dim=4096,
               name="vit-encoder") -> ArchConfig:
    return ArchConfig(
        name=name, family="vit", num_layers=num_layers, d_model=d_model,
        num_heads=num_heads, num_kv_heads=num_heads, d_ff=d_ff,
        vocab_size=0, head_dim=d_model // num_heads,
        frontend_dim=patch_dim, vision_dim=out_dim,
        # reuse fields: frontend_dim = raw patch dim; vision_dim = LM d_model
        moe_offset=downsample,   # stash the downsample factor
    )


def downsample_factor(cfg: ArchConfig) -> int:
    return cfg.moe_offset or 4


def vit_specs(cfg: ArchConfig) -> dict:
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    layer = {"norm1": norm_specs(cfg), "attn": att.attn_specs(cfg),
             "norm2": norm_specs(cfg), "mlp": mlpm.mlp_specs(cfg)}
    ds = downsample_factor(cfg)
    return {
        "patch_proj": ParamSpec((cfg.frontend_dim, cfg.d_model),
                                ("frames_dim", "embed"), "normal", dt, (0,)),
        "layers": cm.stack_specs(layer, cfg.num_layers),
        "final_norm": norm_specs(cfg),
        "merge_proj": ParamSpec((cfg.d_model * ds, cfg.vision_dim),
                                ("embed", "vision"), "normal", dt, (0,)),
    }


def vit_encode(p, cfg: ArchConfig, patches: jnp.ndarray, *,
               impl: str = "auto", remat: bool = True) -> jnp.ndarray:
    """patches [B, P, patch_dim] -> visual embeddings [B, P/ds, out_dim].

    The ViT attends over the full (long) patch sequence — this is the
    component the paper gives context parallelism — then merges ``ds``
    consecutive tokens (pixel-unshuffle style) into one LM-space embedding.
    """
    B, P, _ = patches.shape
    x = jnp.einsum("bpe,ed->bpd", patches.astype(p["patch_proj"].dtype),
                   p["patch_proj"])
    x = cm.shard_act(x, "hidden")

    def body(x, lp):
        def fn(lp, x):
            h = apply_norm(lp["norm1"], x, cfg)
            h = att.attention(lp["attn"], h, cfg, causal=False, impl=impl)
            x = x + h
            h = apply_norm(lp["norm2"], x, cfg)
            return x + mlpm.mlp(lp["mlp"], h, cfg)
        if remat:
            fn = jax.checkpoint(fn)
        return cm.shard_act(fn(lp, x), "hidden"), None

    x, _ = jax.lax.scan(body, x, p["layers"])
    x = apply_norm(p["final_norm"], x, cfg)
    ds = downsample_factor(cfg)
    x = x.reshape(B, P // ds, ds * cfg.d_model)
    return jnp.einsum("bkm,mv->bkv", x, p["merge_proj"])
