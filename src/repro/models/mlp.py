"""Feed-forward blocks: SwiGLU (llama family) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ArchConfig
from repro.models.common import ParamSpec


def mlp_specs(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.mlp_act == "gelu":
        return {
            "w_in": ParamSpec((d, f), ("embed", "mlp"), "normal", dt, (0,)),
            "b_in": ParamSpec((f,), ("mlp",), "zeros", dt),
            "w_out": ParamSpec((f, d), ("mlp", "embed"), "normal", dt, (0,)),
            "b_out": ParamSpec((d,), ("embed_nosplit",), "zeros", dt),
        }
    return {
        "w_gate": ParamSpec((d, f), ("embed", "mlp"), "normal", dt, (0,)),
        "w_up": ParamSpec((d, f), ("embed", "mlp"), "normal", dt, (0,)),
        "w_down": ParamSpec((f, d), ("mlp", "embed"), "normal", dt, (0,)),
    }


def mlp(p, x, cfg: ArchConfig) -> jnp.ndarray:
    from repro.models.common import grad_dtype_barrier as gdb
    if cfg.mlp_act == "gelu":
        h = gdb(jnp.einsum("bsd,df->bsf", x, p["w_in"]) + p["b_in"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        return jnp.einsum("bsf,fd->bsd", h, p["w_out"]) + p["b_out"]
    g = gdb(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
