"""mamba2-130m  [ssm]  24L d_model=768 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality)  [arXiv:2405.21060; unverified]."""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
    tie_embeddings=True,
)
