"""Architecture registry: the 10 assigned archs + compound workloads.

``get_config(name)`` returns the full published config;
``get_reduced(name)`` returns a family-preserving shrunken config for CPU
smoke tests (small layers/width/experts/vocab, same layer layout).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.core.types import ArchConfig

_MODULES = {
    "granite-20b": "granite_20b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "qwen2.5-32b": "qwen2_5_32b",
    "granite-3-8b": "granite_3_8b",
    "mixtral-8x22b": "mixtral_8x22b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mamba2-130m": "mamba2_130m",
    "pixtral-12b": "pixtral_12b",
    "whisper-small": "whisper_small",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_NAMES = list(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        from repro.configs import compound
        if name in compound.COMPOUND:
            raise ValueError(
                f"{name} is a compound workload; use repro.configs.compound")
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


def get_reduced(name: str) -> ArchConfig:
    return reduce_config(get_config(name))


def reduce_config(cfg: ArchConfig) -> ArchConfig:
    """Family-preserving shrink for CPU smoke tests."""
    period = 1
    if cfg.attn_period:
        period = cfg.attn_period
    if cfg.moe_period:
        import math
        period = math.lcm(period, cfg.moe_period)
    layers = max(2, period)
    kv = cfg.num_kv_heads
    heads = cfg.num_heads
    if heads > 0:
        if kv == heads:
            heads, kv = 4, 4
        elif kv == 1:
            heads, kv = 4, 1
        else:
            heads, kv = 4, 2
    kw = dict(
        num_layers=layers,
        d_model=128,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=32 if heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
    )
    if cfg.is_moe:
        kw["num_experts"] = min(cfg.num_experts, 4)
        kw["experts_per_token"] = min(cfg.experts_per_token, 2)
    if cfg.ssm_state:
        kw["ssm_state"] = min(cfg.ssm_state, 16)
        kw["ssm_headdim"] = 32
    if cfg.sliding_window:
        kw["sliding_window"] = 32
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["frontend_frames"] = 16
        kw["frontend_dim"] = 32
    if cfg.vision_dim:
        kw["vision_dim"] = 32
        kw["max_image_tokens"] = 8
    return cfg.replace(**kw)
