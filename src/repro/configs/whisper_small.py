"""whisper-small  [audio]  12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865
— enc-dec, conv frontend (stub)  [arXiv:2212.04356; unverified].

The conv/audio frontend is stubbed: ``input_specs()`` provides precomputed
frame embeddings [B, 1500, 768].  Backbone: 12 bidirectional encoder layers
+ 12 decoder layers (self + cross attention), GELU MLP, LayerNorm.
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    encoder_layers=12,
    frontend_frames=1500,
    frontend_dim=768,
    mlp_act="gelu",
    norm_type="ln",
)
