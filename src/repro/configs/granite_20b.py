"""granite-20b  [dense]  52L d_model=6144 48H (GQA kv=1 / MQA) d_ff=24576
vocab=49152 — code model  [arXiv:2405.04324; hf].

d_ff = 4×d_model with a GELU MLP (GPT-BigCode heritage — a SwiGLU at this
d_ff would be a 28B model, not 20B); decoder layout otherwise llama-style
(pre-RMSNorm + RoPE) per the assignment note.
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    mlp_act="gelu",
)
