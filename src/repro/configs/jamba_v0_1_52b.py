"""jamba-v0.1-52b  [hybrid]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf].

Layer layout: period-8 blocks with attention at offset 4 (1 attn : 7 mamba),
MoE on every second layer (offset 1).  SSM layers use the Mamba substrate
(d_state=16, expand=2, conv=4 as in Jamba).
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    num_experts=16,
    experts_per_token=2,
    attn_period=8,
    attn_offset=4,
    moe_period=2,
    moe_offset=1,
    ssm_state=16,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_conv=4,
)
