"""pixtral-12b  [vlm]  40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified].

Per the assignment the modality frontend is a STUB: ``input_specs()``
delivers precomputed 1024-dim patch embeddings which the backbone projects
and scatters into the token stream.
"""
from repro.core.types import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    vision_dim=1024,
    max_image_tokens=1024,
)
