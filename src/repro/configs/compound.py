"""Compound-workload configurations (the paper's own workload shapes),
pairing assigned archs into Maestro section graphs.

These are *workloads*, not single archs: each entry builds a SectionGraph
via the §3.1 construction rules.  Used by the examples, the planner
benchmarks, and the compound dry-run extras.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.graph import (SectionGraph, build_distill_graph,
                              build_vlm_graph)
from repro.models.vlm import vit_config


def vlm_compound(lm_name: str = "qwen2.5-32b") -> SectionGraph:
    """ViT encoder section (CP-heavy) → LM backbone (critical)."""
    lm = get_config(lm_name)
    vit = vit_config(out_dim=lm.d_model)
    g = build_vlm_graph(vit, lm)
    g.sections["vit"] = g.sections["vit"].replace(seq_scale=0.5)
    return g


def distill_compound(teacher_name: str = "mixtral-8x22b",
                     student_name: str = "moonshot-v1-16b-a3b",
                     fanout: int = 1) -> SectionGraph:
    """Frozen teacher → trainable student with output-layer colocation."""
    return build_distill_graph(get_config(teacher_name),
                               get_config(student_name), fanout=fanout)


def self_distill_compound(name: str = "granite-3-8b") -> SectionGraph:
    cfg = get_config(name)
    return build_distill_graph(cfg, cfg)


COMPOUND = {
    "vlm_compound": vlm_compound,
    "distill_compound": distill_compound,
    "self_distill_compound": self_distill_compound,
}
