"""Declarative section-centric workload API (paper §3 end to end).

Maestro's core claim is that a compound workload *is* its section graph:
each section carries its own parallelism ``C^s``, execution mode and
data-dependent activation, and everything else — carved meshes, per-section
jitted steps, jitted AdamW with a shared joint grad-norm, wavefront-ordered
dispatch, the realized timeline — is generic machinery.  This module makes
that the API:

* :class:`SectionSpec` — one section: arch + params + a plain
  ``fn(params, inputs) -> {port: array}`` (producer) or ``-> loss``
  (the critical loss section), a :class:`ParallelConfig`, mode
  (``fwd_bwd`` / ``fwd_only``), an optional per-sample activation
  predicate, and typed emit/consume ports.
* :class:`WorkloadSpec` — the sections plus the cross-section edges
  (implied by ``consumes``); validated at spec-compile time (port-type
  mismatches, cycles, cotangent routing) before any device work.
* :class:`CompoundRuntime` — ONE generic runtime that compiles any
  ``WorkloadSpec`` into the disaggregated execution the bespoke
  ``DistillRuntime`` / ``MLLMRuntime`` classes used to hand-write; both
  are now thin declarations on this API (see ``repro.distill.workload``
  and ``repro.mllm.workload``), as is multi-teacher distillation
  (``repro.distill.multi_teacher``).

Execution model (exactly the structure the MLLM runtime is proven
bit-for-bit equivalent to its colocated oracle with):

* per microbatch, producer sections run ``fwd`` tasks on their carved
  meshes (emitted ports pushed through the MessageQueue), the critical
  section computes loss + grads w.r.t. its params and any consumed ports
  from trainable producers (cotangents pushed back), trainable producers
  run ``bwd`` (vjp) tasks;
* gradients accumulate into f32 zero-seeded trees in microbatch dispatch
  order, are normalized by ``n_mb`` once, and per-section *jitted* AdamW
  updates share one joint grad-norm across all trainable sections (the
  colocated clipping semantics — ``adamw.update(gnorm=)``);
* a section with ``ParallelConfig.grad_compress`` ∈ {"bf16", "int8"}
  defers its DP gradient all-reduce to the ``upd`` dispatch and runs it
  compressed (``repro.optim.compression``): its grad/bwd jits move into
  a shard_map over the data axis and emit stacked per-shard partial
  grads ``[dp, ...]`` (the local loss carries a 1/dp scale so partials
  sum to the DP mean and port cotangents keep colocated scale), and the
  int8 error-feedback residual threads across iterations per section
  (zero-init at first ``install()``, preserved after);
* each trainable section's grad-finalize + AdamW update runs as an
  ``upd`` Dispatch on *that section's own worker* (not the main thread):
  the joint grad-norm is a small cross-worker rendezvous of per-leaf
  sum-of-squares vectors through the MessageQueue, and per-section
  worker FIFO serializes ``upd(i)`` before that section's ``fwd(i+1)``
  with no global barrier between iterations;
* iterations stream: ``install()`` adopts params/opts as runtime state,
  ``submit_iteration()`` enqueues one global batch onto the section
  streams (traffic scoped under a monotonic ``s<i>/`` namespace, evicted
  at retirement), ``retire()`` drains the oldest; the ``lookahead`` knob
  bounds how many iterations may be in flight (0 ⇒ fully serialized,
  today's semantics); ``train_iteration()`` is the serialized
  compatibility wrapper;
* a section with an activation predicate simply emits no Dispatch for a
  microbatch none of whose samples activate it, and its consumers
  substitute the port's exact-zero fill;
* every jit is traced + compiled from the main thread (the act-hook /
  attention-impl globals are not thread-safe at trace time), and every
  task blocks its section-mesh arrays before returning (XLA CPU deadlocks
  when two host threads interleave collective launches on one device set
  — moving the updates onto the section workers means every
  collective-bearing program a mesh runs is launched by its one worker).
"""
from __future__ import annotations

import collections
import contextlib
import functools
import logging
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import cost_model as cmdl
from repro.core.executor import Dispatch, mark_start, order_samples
from repro.core.graph import SectionGraph
from repro.core.runtime import MaestroRuntime
from repro.core.scheduler import ScheduleResult
from repro.core.types import ArchConfig, ParallelConfig, SectionConfig
from repro.dist import sharding as shd
from repro.models import attention as att
from repro.models import common as cm
from repro.optim import adamw, schedules
from repro.optim import compression as gcomp

#: symbolic sequence-length dim in Field / Port shapes, resolved to the
#: workload's seq_len at build time (static dims stay ints)
SEQ = "S"

_log = logging.getLogger("repro.workload")


def _spec_has_axis(spec, axis: str) -> bool:
    """Whether a PartitionSpec mentions ``axis`` in any dim entry."""
    for entry in spec:
        if entry is None:
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        if axis in entries:
            return True
    return False


def _np_dtype(dt):
    if isinstance(dt, str):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                "float16": jnp.float16, "int32": jnp.int32}[dt]
    return dt


def _resolve_shape(shape: Tuple, seq_len: Optional[int]) -> Tuple[int, ...]:
    out = []
    for d in shape:
        if d == SEQ:
            assert seq_len is not None, \
                "symbolic 'S' dim used but no seq_len bound yet"
            out.append(int(seq_len))
        else:
            out.append(int(d))
    return tuple(out)


@dataclass(frozen=True)
class Field:
    """One per-sample batch input of a section: shape WITHOUT the batch
    dim (entries int or the symbol :data:`SEQ`).  ``fill`` seeds warmup
    arrays (loss masks warm up as ones so means stay finite)."""
    shape: Tuple
    dtype: Any = "float32"
    fill: float = 0.0


@dataclass(frozen=True)
class Port:
    """A typed cross-section tensor: per-sample shape + dtype.  The same
    ``Port`` object (or an equal one) must appear in the producer's
    ``emits`` and the consumer's ``consumes`` — a mismatch raises at
    spec-compile time, not at trace time."""
    name: str
    shape: Tuple
    dtype: Any = "float32"


@dataclass(frozen=True)
class Consume:
    """Consumer-side declaration of a cross-section edge: the producing
    section plus the *expected* :class:`Port` type."""
    section: str
    port: Port

    @property
    def key(self) -> str:
        return f"{self.section}.{self.port.name}"


@dataclass(frozen=True)
class SectionSpec:
    """One section of a compound workload.

    ``fn(params, inputs) -> {port_name: array}`` for producers, or a
    scalar loss (``loss=True``; ``(loss, aux_scalars)`` with
    ``loss_aux=True``) for the critical section.  ``inputs`` holds, per
    microbatch of capacity ``mbs``:

    * every declared batch :class:`Field` ``[mbs, ...]`` — gathered to
      the activated samples (zero-padded capacity) when the section has
      an ``activation`` predicate, sliced contiguously otherwise;
    * ``"act_valid"`` ``[mbs]`` f32 when the section has a predicate;
    * each consumed port under ``"<section>.<port>"``, plus
      ``"<section>.act_idx"`` / ``"<section>.act_valid"`` when that
      producer has a predicate (for scattering capacity rows back to
      sample slots);
    * every declared const under its name.
    """
    name: str
    arch: ArchConfig
    parallel: ParallelConfig
    fn: Callable[..., Any]
    params: Any                               # tree of ParamSpec
    inputs: Mapping[str, Field] = field(default_factory=dict)
    emits: Tuple[Port, ...] = ()
    consumes: Tuple[Consume, ...] = ()
    mode: str = "fwd_bwd"                     # "fwd_bwd" | "fwd_only"
    loss: bool = False
    loss_aux: bool = False
    activation: Optional[Callable[[Dict[str, np.ndarray]], np.ndarray]] = None
    critical: bool = False
    seq_len: Optional[int] = None             # section sequence length
    consts: Mapping[str, Field] = field(default_factory=dict)

    @property
    def trainable(self) -> bool:
        return self.mode == "fwd_bwd"


@dataclass(frozen=True)
class WorkloadSpec:
    """A compound workload: sections + the edges implied by their
    ``consumes``.  ``global_batch`` / ``seq_len`` / ``mbs`` may be left
    ``None`` for shape-polymorphic workloads — the runtime then binds
    them from the first batch (``mbs=None`` ⇒ one microbatch per
    iteration)."""
    name: str
    sections: Tuple[SectionSpec, ...]
    seq_len: Optional[int] = None
    global_batch: Optional[int] = None
    mbs: Optional[int] = None

    def section(self, name: str) -> SectionSpec:
        for s in self.sections:
            if s.name == name:
                return s
        raise KeyError(name)

    @property
    def critical(self) -> SectionSpec:
        crits = [s for s in self.sections if s.critical]
        assert len(crits) == 1
        return crits[0]

    # ------------------------------------------------------------------ #
    def consumers_of(self, section: str, port: str) -> List[str]:
        return [s.name for s in self.sections
                if any(c.section == section and c.port.name == port
                       for c in s.consumes)]

    def topo_order(self) -> List[str]:
        """Section names, producers before consumers (Kahn)."""
        indeg = {s.name: 0 for s in self.sections}
        for s in self.sections:
            indeg[s.name] = len(s.consumes)
        order, ready = [], [n for n, d in sorted(indeg.items())
                            if d == 0]
        while ready:
            n = ready.pop(0)
            order.append(n)
            for s in self.sections:
                if any(c.section == n for c in s.consumes):
                    indeg[s.name] -= sum(
                        1 for c in s.consumes if c.section == n)
                    if indeg[s.name] == 0:
                        ready.append(s.name)
        if len(order) != len(self.sections):
            raise ValueError(
                f"workload {self.name!r}: section graph has a cycle "
                f"(resolved {order} of {[s.name for s in self.sections]})")
        return order

    # ------------------------------------------------------------------ #
    def validate(self) -> None:
        """Spec-compile-time checks: raise here, before any mesh is
        carved or jit traced."""
        names = [s.name for s in self.sections]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate section names: {names}")
        crits = [s for s in self.sections if s.critical]
        if len(crits) != 1:
            raise ValueError(
                f"workload {self.name!r}: exactly one critical section "
                f"required, got {[s.name for s in crits]}")
        crit = crits[0]
        if not crit.loss or crit.mode != "fwd_bwd":
            raise ValueError(
                f"critical section {crit.name!r} must be a fwd_bwd loss "
                "section (loss=True)")
        if crit.activation is not None:
            raise ValueError(
                f"critical section {crit.name!r} cannot carry an "
                "activation predicate: the loss runs on every microbatch")
        by_name = {s.name: s for s in self.sections}
        for s in self.sections:
            if s.mode not in ("fwd_bwd", "fwd_only"):
                raise ValueError(
                    f"section {s.name!r}: unknown mode {s.mode!r}")
            if s.loss and s.mode == "fwd_only":
                raise ValueError(
                    f"section {s.name!r}: a loss section cannot be "
                    "fwd_only")
            if s.loss and not s.critical:
                raise ValueError(
                    f"section {s.name!r}: loss sections must be the "
                    "critical section")
            if not s.loss and not s.emits:
                raise ValueError(
                    f"section {s.name!r}: a non-loss section must emit "
                    "at least one port")
            pnames = [p.name for p in s.emits]
            if len(set(pnames)) != len(pnames):
                raise ValueError(
                    f"section {s.name!r}: duplicate emitted port names "
                    f"{pnames}")
            for c in s.consumes:
                if c.section == s.name:
                    raise ValueError(
                        f"section {s.name!r} consumes its own port "
                        f"{c.port.name!r}")
                src = by_name.get(c.section)
                if src is None:
                    raise ValueError(
                        f"section {s.name!r} consumes from unknown "
                        f"section {c.section!r}")
                emitted = {p.name: p for p in src.emits}
                if c.port.name not in emitted:
                    raise ValueError(
                        f"section {s.name!r} consumes port "
                        f"{c.port.name!r} which {c.section!r} does not "
                        f"emit (emits {sorted(emitted)})")
                got = emitted[c.port.name]
                if (tuple(got.shape) != tuple(c.port.shape)
                        or _np_dtype(got.dtype) != _np_dtype(c.port.dtype)):
                    raise ValueError(
                        f"port type mismatch on edge {c.section!r} -> "
                        f"{s.name!r}: producer emits "
                        f"{c.port.name!r}{tuple(got.shape)}:{got.dtype} "
                        f"but consumer expects "
                        f"{tuple(c.port.shape)}:{c.port.dtype}")
        # cotangent routing: a trainable producer's port must have exactly
        # one consumer (the bwd task pulls ONE cotangent per port), and
        # that consumer must itself be fwd_bwd — a fwd_only consumer
        # never pushes a cotangent back, so the producer's bwd task would
        # deadlock waiting on it
        for s in self.sections:
            if not s.trainable or s.critical:
                continue
            for p in s.emits:
                cons = self.consumers_of(s.name, p.name)
                if len(cons) != 1:
                    raise ValueError(
                        f"trainable section {s.name!r} port {p.name!r} "
                        f"must have exactly one consumer (cotangent "
                        f"routing), got {cons}")
                if by_name[cons[0]].mode != "fwd_bwd":
                    raise ValueError(
                        f"trainable section {s.name!r} port {p.name!r} "
                        f"is consumed by fwd_only section {cons[0]!r}, "
                        "which can never return a cotangent — the "
                        "producer's bwd task would deadlock; make the "
                        "consumer fwd_bwd or freeze the producer")
        if (self.global_batch is not None and self.mbs is not None
                and self.global_batch % self.mbs):
            raise ValueError(
                f"global_batch={self.global_batch} is not a multiple of "
                f"mbs={self.mbs}")
        self.topo_order()
        # dispatch-graph deadlock proof (repro.analysis.deadlock): the
        # blocking-pull order submit_iteration will emit — incl. the
        # grad-norm rendezvous and lookahead cross-iteration FIFO
        # coupling — must be acyclic with every pull satisfiable.
        # Reject the spec here instead of hanging in drain().
        from repro.analysis import deadlock as _deadlock
        _deadlock.check_spec(self, n_mb=2, lookahead=1).raise_on_error(
            ValueError,
            f"workload {self.name!r}: dispatch-graph deadlock analysis "
            "failed")

    # ------------------------------------------------------------------ #
    def to_graph(self) -> SectionGraph:
        """The cost-model / carving view of this workload (the axis-naming
        and seq_scale contract the scheduler 6-tuples are built from)."""
        g = SectionGraph()
        base_seq = self.seq_len
        for s in self.sections:
            scale = 1.0
            if s.seq_len is not None and base_seq:
                scale = s.seq_len / max(base_seq, 1)
            g.add(SectionConfig(s.name, s.arch, s.parallel,
                                trainable=s.trainable, critical=s.critical,
                                seq_scale=scale))
        for s in self.sections:
            for c in s.consumes:
                port = c.port
                width = int(port.shape[-1]) if port.shape and \
                    port.shape[-1] != SEQ else 1
                bpt = width * jnp.dtype(_np_dtype(port.dtype)).itemsize
                src_dp = self.section(c.section).parallel.dp
                fanout = (s.parallel.dp // src_dp
                          if src_dp and s.parallel.dp % src_dp == 0 else 1)
                g.connect(c.section, s.name, bytes_per_token=bpt,
                          fanout=fanout)
        g.validate()
        return g


# --------------------------------------------------------------------------- #
# Consolidated per-section parallelism validation (replaces the scattered
# _reject_pp / _reject_pp_cp helpers the bespoke runtimes carried)
# --------------------------------------------------------------------------- #
def validate_section_parallel(name: str, arch: ArchConfig,
                              parallel: ParallelConfig, mesh) -> str:
    """ONE validation path for a section's ``C^s`` against its carved
    mesh: routes through ``repro.train.step.parallel_regime`` (the same
    dispatch ``build_train_step`` uses), checks arch-family CP/PP
    support, and rejects PP for declarative workload sections — every
    error names the section and the offending mesh axis."""
    from repro.train.step import _check_pp_cp_support, parallel_regime
    try:
        regime = parallel_regime(mesh, parallel)
    except (ValueError, NotImplementedError) as e:
        raise type(e)(f"section {name!r}: {e}") from None
    if regime == "pp":
        raise NotImplementedError(
            f"section {name!r}: pipeline parallelism (mesh axis "
            f"{shd.AXIS_PIPE!r}={parallel.pp}) is not supported for "
            "declarative workload sections — a section fn cannot be "
            "stage-partitioned by build_pp_loss; use dp/tp/cp for this "
            "section (ROADMAP open item)")
    try:
        _check_pp_cp_support(arch, regime)
    except NotImplementedError as e:
        raise NotImplementedError(f"section {name!r}: {e}") from None
    if regime == "cp":
        cp = dict(mesh.shape).get(shd.AXIS_SEQ, 1)
        # the section's own sequence must divide its seq axis; checked
        # again at build time once seq_len is bound
        if parallel.cp != cp:          # pragma: no cover (regime checked)
            raise ValueError(name)
    return regime


# --------------------------------------------------------------------------- #
# Iteration plan: wavefront order + per-section data-dependent activation
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class SectionActivation:
    """Which microbatches activate a section, and per-microbatch local
    indices/validity of the activating samples (capacity layout)."""
    active_mbs: Tuple[int, ...]
    idx: np.ndarray                   # [n_mb, mbs] int32 local indices
    valid: np.ndarray                 # [n_mb, mbs] f32, 1.0 = real sample


def build_activation(order: Sequence[int], flags: np.ndarray,
                     mbs: int) -> SectionActivation:
    """Per-microbatch activation layout of one section given the sample
    dispatch ``order`` and per-sample ``flags`` (original indexing)."""
    n = len(order)
    assert n % mbs == 0, (n, mbs)
    n_mb = n // mbs
    ordered = np.asarray(flags).astype(bool)[list(order)]
    idx = np.zeros((n_mb, mbs), np.int32)
    valid = np.zeros((n_mb, mbs), np.float32)
    active: List[int] = []
    for i in range(n_mb):
        loc = np.where(ordered[i * mbs:(i + 1) * mbs])[0]
        idx[i, :len(loc)] = loc
        valid[i, :len(loc)] = 1.0
        if len(loc):
            active.append(i)
    return SectionActivation(tuple(active), idx, valid)


@dataclass(frozen=True)
class IterationPlan:
    """Host-side dispatch plan for one global batch."""
    order: Tuple[int, ...]
    mbs: int
    n_mb: int
    activation: Dict[str, SectionActivation]
    schedule: Optional[ScheduleResult] = None

    def section(self, name: str) -> Optional[SectionActivation]:
        return self.activation.get(name)


# --------------------------------------------------------------------------- #
# The one generic compound runtime
# --------------------------------------------------------------------------- #
class _Inflight:
    """Host-side record of one submitted-not-yet-retired iteration."""

    __slots__ = ("seq", "scope", "step_idx", "plan", "return_grads",
                 "ctx", "acc", "crit_acc")

    def __init__(self, seq: int, scope: str, step_idx, plan: IterationPlan,
                 return_grads: bool, trainable: Sequence[str]):
        self.seq = seq
        self.scope = scope
        self.step_idx = step_idx
        self.plan = plan
        self.return_grads = return_grads
        self.ctx: Dict[Tuple[str, int], Any] = {}
        self.acc = {n: {"g": None} for n in trainable}
        self.crit_acc = {"loss": jnp.float32(0.0), "aux": None}


class CompoundRuntime:
    """Compile a :class:`WorkloadSpec` into disaggregated execution on the
    compound executor.  See the module docstring for the execution model;
    ``DistillRuntime`` / ``MLLMRuntime`` / multi-teacher distillation are
    all thin declarations over this class."""

    def __init__(self, spec: WorkloadSpec, *, devices=None,
                 impl: str = "ref", lr_schedule=None,
                 opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                 lookahead: int = 0):
        spec.validate()
        self.spec = spec
        self.impl = impl
        self.opt_cfg = opt_cfg
        self.lr_fn = lr_schedule or functools.partial(schedules.constant,
                                                      peak_lr=1e-3)
        self.graph = spec.to_graph()
        self.rt = MaestroRuntime(self.graph, devices)
        # mesh-thread affinity (repro.analysis.affinity): disjoint
        # section meshes, one live worker each — the wiring invariant
        # the XLA-CPU collective-launch contract rests on
        from repro.analysis import affinity as _affinity
        _affinity.check_wiring(self.rt).raise_on_error(
            RuntimeError,
            f"workload {spec.name!r}: mesh-thread affinity check failed")
        self.executor = self.rt.executor()
        self.last_execution = None
        #: cross-iteration pipelining depth: how many iterations beyond
        #: the oldest may be in flight at once.  0 ⇒ submit_iteration
        #: retires the previous iteration before dispatching the next
        #: (exactly the old barrier semantics); 1 ⇒ iteration i+1's fwd
        #: tasks stream in behind each section's own upd(i).
        self.lookahead = int(lookahead)
        self._session = self.executor.session()
        self._it_seq = 0
        self._inflight: "collections.deque[_Inflight]" = collections.deque()
        self._retired: "collections.deque[dict]" = collections.deque()
        self._params: Dict[str, Any] = {}
        self._opts: Dict[str, Any] = {}
        #: per-compressed-section stacked [dp, ...] error-feedback
        #: residual — zero-init at first install(), then threaded across
        #: iterations by the section's own ``upd`` dispatch
        self._ef: Dict[str, Any] = {}
        self._installed = False
        self._topo = spec.topo_order()
        self._crit = spec.critical.name
        self._trainable = [s.name for s in spec.sections if s.trainable]
        self._has_activation = any(s.activation is not None
                                   for s in spec.sections)
        # consolidated C^s validation against the carved meshes (this is
        # what lifts the old blanket pp/cp rejections: cp sections route
        # through the same parallel_regime dispatch as build_train_step)
        self._regime: Dict[str, str] = {}
        for s in spec.sections:
            self._regime[s.name] = validate_section_parallel(
                s.name, s.arch, self.rt.parallel(s.name),
                self.rt.mesh(s.name))
        # per-section DP grad-compression knob (ParallelConfig.grad_compress
        # → repro.optim.compression): validated here, realized as stacked
        # per-shard partial grads in the section's grad/bwd jits plus ONE
        # compressed all-reduce in its worker-side ``upd`` dispatch
        self._compress: Dict[str, str] = {}
        self._comp_dp: Dict[str, int] = {}
        for s in spec.sections:
            method = self.rt.parallel(s.name).grad_compress or "none"
            if method == "none":
                continue
            if method not in gcomp.METHODS:
                raise ValueError(
                    f"section {s.name!r}: grad_compress={method!r} — "
                    f"expected one of {gcomp.METHODS}")
            if not s.trainable:
                raise ValueError(
                    f"section {s.name!r}: grad_compress on a fwd_only "
                    "section — frozen sections produce no gradients")
            mesh = self.rt.mesh(s.name)
            sizes = dict(mesh.shape)
            if self._regime[s.name] != "plain" or any(
                    sizes.get(a, 1) > 1
                    for a in (shd.AXIS_PIPE, shd.AXIS_SEQ, shd.AXIS_MODEL)):
                raise NotImplementedError(
                    f"section {s.name!r}: grad_compress requires the plain "
                    "regime on a dp-only mesh — the compressed all-reduce "
                    "runs in a shard_map over the data axis and cannot "
                    "nest inside cp schedules or compose with tp "
                    "activation sharding")
            das = shd.dp_axes(mesh)
            if len(das) != 1:
                raise NotImplementedError(
                    f"section {s.name!r}: grad_compress needs exactly one "
                    f"data axis on the section mesh (got {das!r})")
            by = {x.name: x for x in spec.sections}
            for c in s.consumes:
                if by[c.section].activation is not None:
                    raise NotImplementedError(
                        f"section {s.name!r}: grad_compress on a consumer "
                        f"of activation-predicated section {c.section!r} "
                        "— the capacity-row → sample-slot scatter crosses "
                        "the batch dim the compressed shard_map shards")
            self._compress[s.name] = method
            self._comp_dp[s.name] = sizes[das[0]]
        # shape-independent state: param/opt shardings, update/ssq jits
        self._p_shard: Dict[str, Any] = {}
        self._o_shard: Dict[str, Any] = {}
        self._update: Dict[str, Any] = {}
        self._ssq: Dict[str, Any] = {}
        self._compress_step: Dict[str, Any] = {}
        for s in spec.sections:
            mesh = self.rt.mesh(s.name)
            rules = shd.rules_for(s.arch, mesh, teacher=not s.trainable)
            self._p_shard[s.name] = shd.param_shardings(s.params, mesh,
                                                        rules)
            if not s.trainable:
                continue
            self._o_shard[s.name] = shd.opt_state_shardings(s.params, mesh,
                                                            rules)
            rep = shd.replicated(mesh)
            p_sh, o_sh = self._p_shard[s.name], self._o_shard[s.name]
            # jitted per-section AdamW: the same fused elementwise program
            # a colocated step runs (eager op-by-op updates round
            # differently — no FMA fusion).  gnorm= is only legal with
            # clipping enabled (adamw raises otherwise): without a clip
            # threshold the joint norm is metrics-only.
            if opt_cfg.clip_norm > 0:
                upd = functools.partial(adamw.update, cfg=opt_cfg)

                def upd_fn(g, st, lr, gn, _u=upd):
                    return _u(g, st, lr, gnorm=gn)
                self._update[s.name] = jax.jit(
                    upd_fn, in_shardings=(p_sh, o_sh, rep, rep),
                    out_shardings=(p_sh, o_sh, rep),
                    donate_argnums=(1,))
            else:
                def upd_fn(g, st, lr, _cfg=opt_cfg):
                    return adamw.update(g, st, lr, _cfg)
                self._update[s.name] = jax.jit(
                    upd_fn, in_shardings=(p_sh, o_sh, rep),
                    out_shardings=(p_sh, o_sh, rep),
                    donate_argnums=(1,))

            def ssq_vec(g):
                return jnp.stack([jnp.sum(jnp.square(x.astype(jnp.float32)))
                                  for x in jax.tree_util.tree_leaves(g)])
            # jitted per-leaf sums of squares: the same compiled
            # square+sum subgraph an in-jit global_norm runs
            self._ssq[s.name] = jax.jit(ssq_vec, in_shardings=(p_sh,),
                                        out_shardings=rep)
            if s.name in self._compress:
                self._compress_step[s.name] = self._make_compress_step(
                    s.name, self._compress[s.name], p_sh)
        self._built: Optional[Tuple[int, int, int]] = None
        if spec.global_batch is not None and spec.seq_len is not None:
            self._build(spec.global_batch, spec.seq_len,
                        spec.mbs or spec.global_batch)

    # ------------------------------------------------------------------ #
    # params / optimizer state
    # ------------------------------------------------------------------ #
    def init(self, rng):
        """Init + place every section's params (spec order rng split) and
        matching optimizer states for the trainable sections."""
        rngs = jax.random.split(rng, len(self.spec.sections))
        host = {s.name: cm.init_params(s.params, r)
                for s, r in zip(self.spec.sections, rngs)}
        return self.place(host)

    def place(self, params: Dict[str, Any]):
        """Place per-section param trees onto the carved meshes and build
        matching optimizer states."""
        placed = {n: jax.device_put(params[n], self._p_shard[n])
                  for n in params}
        opts = {n: jax.device_put(adamw.init(placed[n]), self._o_shard[n])
                for n in self._trainable}
        return placed, opts

    # ------------------------------------------------------------------ #
    # shape binding: jits, input/port shardings, warmup
    # ------------------------------------------------------------------ #
    def _ensure_built(self, host: Dict[str, np.ndarray]) -> None:
        B = None
        for s in self.spec.sections:
            for k in s.inputs:
                B = len(host[k])
                break
            if B is not None:
                break
        assert B is not None, "no section declares batch inputs"
        S = self.spec.seq_len
        if S is None:
            for s in self.spec.sections:
                for k, f in s.inputs.items():
                    if SEQ in tuple(f.shape):
                        S = int(host[k].shape[1 + tuple(f.shape).index(SEQ)])
                        break
                if S is not None:
                    break
        mbs = self.spec.mbs or B
        # normalize seq to the stored key (seq-free specs bind S=None but
        # _built records 0) so a None-seq workload doesn't re-jit per step
        if self._built != (B, S or 0, mbs):
            self._build(B, S, mbs)

    def _build(self, global_batch: int, seq_len: Optional[int],
               mbs: int) -> None:
        assert global_batch % mbs == 0, (global_batch, mbs)
        if getattr(self, "_inflight", None):
            raise RuntimeError(
                "cannot rebind workload shapes with iterations in "
                "flight — drain() first")
        self.B, self.S, self.mbs = global_batch, seq_len, mbs
        self.n_mb = global_batch // mbs
        spec = self.spec
        self._in_shard: Dict[str, Dict[str, Any]] = {}
        self._in_spec: Dict[str, Dict[str, Tuple[Tuple[int, ...], Any,
                                                 float]]] = {}
        self._pull_shard: Dict[str, Dict[str, Any]] = {}
        self._ct_pull_shard: Dict[str, Dict[str, Any]] = {}
        self._port_zero: Dict[str, Dict[str, Tuple[Tuple[int, ...],
                                                   Any]]] = {}
        self._fwd: Dict[str, Any] = {}
        self._bwd: Dict[str, Any] = {}
        self._ctx: Dict[str, Any] = {}
        self._grad = None
        self._grad_has_ct = False
        by_name = {s.name: s for s in spec.sections}

        for name in self._topo:
            s = by_name[name]
            mesh = self.rt.mesh(name)
            sec_seq = s.seq_len if s.seq_len is not None else seq_len
            cp = dict(mesh.shape).get(shd.AXIS_SEQ, 1)
            if cp > 1 and (sec_seq is None or sec_seq % cp):
                raise ValueError(
                    f"section {name!r}: sequence length {sec_seq} does "
                    f"not divide the mesh {shd.AXIS_SEQ!r} axis ({cp})")
            if name in self._compress and mbs % self._comp_dp[name]:
                raise NotImplementedError(
                    f"section {name!r}: grad_compress needs the "
                    f"microbatch size ({mbs}) to divide the data axis "
                    f"({self._comp_dp[name]}) so every shard owns a real "
                    "slice of the batch")
            from repro.train.step import _act_hook_for
            hook = _act_hook_for(mesh, mbs, sec_seq or 1)
            if self._regime[name] == "cp":
                from repro.dist import context as cpx
                cp_impl = cpx.cp_attention_impl(
                    mesh, batch_axes=shd.dp_axes(mesh) or None,
                    mode=s.parallel.cp_mode, impl=s.parallel.cp_impl,
                    overlap_chunks=s.parallel.cp_overlap_chunks,
                    section=name)
                ctx = functools.partial(att.attention_impl, cp_impl)
            else:
                ctx = contextlib.nullcontext
            self._ctx[name] = (hook, ctx)

            # ---- input layout: every key the section fn will see ------ #
            in_shard: Dict[str, Any] = {}
            in_spec: Dict[str, Tuple[Tuple[int, ...], Any, float]] = {}
            rep = shd.replicated(mesh)
            for k, f in s.inputs.items():
                shp = (mbs,) + _resolve_shape(tuple(f.shape), sec_seq)
                in_shard[k] = shd.dp_sharding(mesh, len(shp))
                in_spec[k] = (shp, _np_dtype(f.dtype), f.fill)
            if s.activation is not None:
                in_shard["act_valid"] = shd.dp_sharding(mesh, 1)
                in_spec["act_valid"] = ((mbs,), jnp.float32, 0.0)
            for cn, f in s.consts.items():
                shp = _resolve_shape(tuple(f.shape), sec_seq)
                in_shard[cn] = rep
                in_spec[cn] = (shp, _np_dtype(f.dtype), f.fill)
            pull_shard: Dict[str, Any] = {}
            for c in s.consumes:
                shp = (mbs,) + _resolve_shape(tuple(c.port.shape), seq_len)
                pull_shard[c.key] = shd.dp_sharding(mesh, len(shp))
                if by_name[c.section].activation is not None:
                    in_shard[f"{c.section}.act_idx"] = rep
                    in_spec[f"{c.section}.act_idx"] = ((mbs,), jnp.int32,
                                                       0.0)
                    in_shard[f"{c.section}.act_valid"] = rep
                    in_spec[f"{c.section}.act_valid"] = ((mbs,),
                                                         jnp.float32, 0.0)
            self._in_shard[name] = in_shard
            self._in_spec[name] = in_spec
            self._pull_shard[name] = pull_shard
            self._port_zero[name] = {
                c.key: ((mbs,) + _resolve_shape(tuple(c.port.shape),
                                                seq_len),
                        _np_dtype(c.port.dtype))
                for c in s.consumes}
            # cotangent pulls for this section's OWN emitted ports
            # (producer-mesh dp layout)
            self._ct_pull_shard[name] = {
                p.name: shd.dp_sharding(
                    mesh, 1 + len(_resolve_shape(tuple(p.shape), seq_len)))
                for p in s.emits}

        self._build_jits(by_name)
        self._warmup(by_name)
        self._built = (global_batch, seq_len or 0, mbs)

    # consumed keys whose cotangents matter (src is a trainable producer)
    def _ct_keys(self, s: SectionSpec) -> List[str]:
        by_name = {x.name: x for x in self.spec.sections}
        return [c.key for c in s.consumes
                if by_name[c.section].trainable]

    # ------------------------------------------------------------------ #
    # DP grad compression (ParallelConfig.grad_compress): the section's
    # grad/bwd jits move into a shard_map over the data axis and emit
    # STACKED per-shard partial grads [dp, ...] instead of XLA's
    # implicitly all-reduced full grads; the reduce is deferred to the
    # section's ``upd`` dispatch where it runs compressed.
    # ------------------------------------------------------------------ #
    def _make_compress_step(self, name: str, method: str, p_sh):
        """Jitted compressed grad-finalize for one section: stacked
        per-shard f32 partial grads ``[dp, ...]`` plus the stacked
        error-feedback residual → ONE compressed all-reduce over the data
        axis (``repro.optim.compression``) → (param-dtype reduced grads,
        new stacked residual).  Replaces the eager ``(g / n_mb).astype``
        finalize in ``upd_task``; 1/n_mb folds in via ``inv_n``."""
        mesh = self.rt.mesh(name)
        da = shd.dp_axes(mesh)[0]
        rep = shd.replicated(mesh)
        ef_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P(da)), p_sh)
        shapes = cm.param_shapes(self.spec.section(name).params)

        def finalize(g_stacked, ef_stacked, inv_n):
            g = jax.tree_util.tree_map(lambda x: x[0] * inv_n, g_stacked)
            ef = gcomp.ErrorFeedback(jax.tree_util.tree_map(
                lambda x: x[0], ef_stacked))
            # mean=False: the partial grads already carry the 1/dp the
            # loss was scaled by, so the compressed SUM is the DP mean
            red, new_ef = gcomp.ef_compress_tree(g, ef, da, method,
                                                 mean=False)
            red = jax.tree_util.tree_map(
                lambda r, sp: r.astype(sp.dtype), red, shapes)
            return red, jax.tree_util.tree_map(lambda x: x[None],
                                               new_ef.residual)

        run = shd.shard_map(finalize, mesh, (P(da), P(da), P()),
                            (P(), P(da)))
        # donate only the residual: the reduced grads are param-shaped,
        # so the stacked-grad buffer has no donatable consumer (warning)
        return jax.jit(run, in_shardings=(ef_sh, ef_sh, rep),
                       out_shardings=(p_sh, ef_sh),
                       donate_argnums=(1,))

    def _ef_init(self, name: str, params) -> Any:
        """Zero-initialized stacked [dp, ...] error-feedback residual for
        one compressed section, placed on its data axis."""
        mesh = self.rt.mesh(name)
        da = shd.dp_axes(mesh)[0]
        dpn = self._comp_dp[name]
        z = jax.tree_util.tree_map(
            lambda x: jnp.zeros((dpn,) + x.shape, jnp.float32), params)
        return jax.device_put(
            z, jax.tree_util.tree_map(
                lambda _: NamedSharding(mesh, P(da)), z))

    def _sharded_grad_jit(self, s: SectionSpec, p_sh, ct_sh, rest_sh,
                          rep):
        """Compressed critical section: loss + grads run in a shard_map
        over the data axis, each shard seeing only its local batch slice.
        The local loss is scaled 1/dp inside so (a) the stacked partial
        grads sum to the DP-mean gradient and (b) pushed port cotangents
        keep the colocated per-element scale.  The reported loss/aux are
        the psum of the scaled locals — the mean over shards, which for
        sample-decomposable losses matches the colocated global mean
        within fp tolerance (masked means deviate only when the mask is
        unbalanced across shards; documented in docs/perf.md)."""
        name = s.name
        mesh = self.rt.mesh(name)
        da = shd.dp_axes(mesh)[0]
        dp = self._comp_dp[name]
        _fn, _aux = s.fn, s.loss_aux
        p_specs = jax.tree_util.tree_map(lambda sh: sh.spec, p_sh)
        rest_specs = {k: sh.spec for k, sh in rest_sh.items()}
        g_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P(da)), p_sh)

        def scaled(p, inputs):
            # act-hook sharding constraints are illegal inside shard_map
            with cm.act_hook(None):
                val = _fn(p, inputs)
            if _aux:
                return val[0] / dp, val[1]
            return val / dp

        def reduce_val(val):
            if _aux:
                return (jax.lax.psum(val[0], da),
                        jax.tree_util.tree_map(
                            lambda a: jax.lax.psum(a / dp, da), val[1]))
            return jax.lax.psum(val, da)

        def stack32(g):
            return jax.tree_util.tree_map(
                lambda x: x.astype(jnp.float32)[None], g)

        if ct_sh is not None:
            ct_specs = {k: sh.spec for k, sh in ct_sh.items()}

            def grad_fn(params, cts, rest):
                def f(p, c):
                    return scaled(p, {**rest, **c})
                val, (g_p, g_c) = jax.value_and_grad(
                    f, argnums=(0, 1), has_aux=_aux)(params, cts)
                # a replicated ct input sees identical local grads that
                # are each a PARTIAL derivative → reduce; batch-sharded
                # cts assemble shard-local slices as-is
                g_c = {k: (v if _spec_has_axis(ct_specs[k], da)
                           else jax.lax.psum(v, da))
                       for k, v in g_c.items()}
                return reduce_val(val), stack32(g_p), g_c

            run = shd.shard_map(grad_fn, mesh,
                                (p_specs, ct_specs, rest_specs),
                                (P(), P(da), ct_specs))
            return jax.jit(run, in_shardings=(p_sh, ct_sh, rest_sh),
                           out_shardings=(rep, g_sh, ct_sh))

        def grad_fn(params, rest):
            def f(p):
                return scaled(p, rest)
            val, g_p = jax.value_and_grad(f, has_aux=_aux)(params)
            return reduce_val(val), stack32(g_p)

        run = shd.shard_map(grad_fn, mesh, (p_specs, rest_specs),
                            (P(), P(da)))
        return jax.jit(run, in_shardings=(p_sh, rest_sh),
                       out_shardings=(rep, g_sh))

    def _sharded_bwd_jit(self, s: SectionSpec, p_sh, ct_sh, rest_sh,
                         all_in_sh, ct_out_sh):
        """Compressed trainable producer: the vjp runs in a shard_map over
        the data axis against the shard-local slice of the pulled
        cotangents (which already carry the colocated global scale), so
        the stacked per-shard partial grads sum to the full gradient."""
        name = s.name
        mesh = self.rt.mesh(name)
        da = shd.dp_axes(mesh)[0]
        _fn = s.fn
        p_specs = jax.tree_util.tree_map(lambda sh: sh.spec, p_sh)
        ct_out_specs = {k: sh.spec for k, sh in ct_out_sh.items()}
        g_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P(da)), p_sh)

        def call(p, inputs):
            with cm.act_hook(None):
                return _fn(p, inputs)

        def stack32(g):
            return jax.tree_util.tree_map(
                lambda x: x.astype(jnp.float32)[None], g)

        if ct_sh is not None:
            ct_specs = {k: sh.spec for k, sh in ct_sh.items()}
            rest_specs = {k: sh.spec for k, sh in rest_sh.items()}

            def bwd_fn(params, cts_in, rest, cts):
                def f(p, c):
                    return call(p, {**rest, **c})
                _, vjp = jax.vjp(f, params, cts_in)
                g_p, g_c = vjp(cts)
                g_c = {k: (v if _spec_has_axis(ct_specs[k], da)
                           else jax.lax.psum(v, da))
                       for k, v in g_c.items()}
                return stack32(g_p), g_c

            run = shd.shard_map(
                bwd_fn, mesh,
                (p_specs, ct_specs, rest_specs, ct_out_specs),
                (P(da), ct_specs))
            return jax.jit(
                run, in_shardings=(p_sh, ct_sh, rest_sh, ct_out_sh),
                out_shardings=(g_sh, ct_sh))

        all_in_specs = {k: sh.spec for k, sh in all_in_sh.items()}

        def bwd_fn(params, inputs, cts):
            def f(p):
                return call(p, inputs)
            _, vjp = jax.vjp(f, params)
            return stack32(vjp(cts)[0])

        run = shd.shard_map(bwd_fn, mesh,
                            (p_specs, all_in_specs, ct_out_specs), P(da))
        return jax.jit(run, in_shardings=(p_sh, all_in_sh, ct_out_sh),
                       out_shardings=g_sh)

    def _build_jits(self, by_name: Dict[str, SectionSpec]) -> None:
        for name in self._topo:
            s = by_name[name]
            hook, ctx = self._ctx[name]
            p_sh = self._p_shard[name]
            in_sh = self._in_shard[name]
            pull_sh = self._pull_shard[name]
            ct_keys = self._ct_keys(s)

            def call(fn, params, inputs, _hook=hook, _ctx=ctx):
                with cm.act_hook(_hook), _ctx():
                    return fn(params, inputs)

            if s.critical:
                rep = shd.replicated(self.rt.mesh(name))
                rest_sh = {**in_sh, **{k: v for k, v in pull_sh.items()
                                       if k not in ct_keys}}
                self._grad_has_ct = bool(ct_keys)
                if name in self._compress:
                    ct_sh = ({k: pull_sh[k] for k in ct_keys}
                             if ct_keys else None)
                    self._grad = self._sharded_grad_jit(s, p_sh, ct_sh,
                                                        rest_sh, rep)
                    continue
                if ct_keys:
                    ct_sh = {k: pull_sh[k] for k in ct_keys}

                    def grad_fn(params, cts, rest, _fn=s.fn,
                                _call=call, _aux=s.loss_aux):
                        def f(p, c):
                            return _call(_fn, p, {**rest, **c})
                        val, (g_p, g_c) = jax.value_and_grad(
                            f, argnums=(0, 1), has_aux=_aux)(params, cts)
                        return val, g_p, g_c
                    self._grad = jax.jit(
                        grad_fn,
                        in_shardings=(p_sh, ct_sh, rest_sh),
                        out_shardings=(rep, p_sh, ct_sh))
                else:
                    def grad_fn(params, rest, _fn=s.fn, _call=call,
                                _aux=s.loss_aux):
                        def f(p):
                            return _call(_fn, p, rest)
                        val, g_p = jax.value_and_grad(
                            f, has_aux=_aux)(params)
                        return val, g_p
                    self._grad = jax.jit(
                        grad_fn, in_shardings=(p_sh, rest_sh),
                        out_shardings=(rep, p_sh))
                continue

            # ---- producer fwd ---------------------------------------- #
            all_in_sh = {**in_sh, **pull_sh}

            def fwd_fn(params, inputs, _fn=s.fn, _call=call):
                return _call(_fn, params, inputs)
            self._fwd[name] = jax.jit(fwd_fn,
                                      in_shardings=(p_sh, all_in_sh))

            # ---- producer bwd (vjp; recompute like the bespoke
            # runtimes did — remat is the section fn's business) -------- #
            if not s.trainable:
                continue
            ct_out_sh = self._ct_pull_shard[name]
            if name in self._compress:
                if ct_keys:
                    ct_sh = {k: pull_sh[k] for k in ct_keys}
                    rest_keys_sh = {**in_sh,
                                    **{k: v for k, v in pull_sh.items()
                                       if k not in ct_keys}}
                    self._bwd[name] = self._sharded_bwd_jit(
                        s, p_sh, ct_sh, rest_keys_sh, None, ct_out_sh)
                else:
                    self._bwd[name] = self._sharded_bwd_jit(
                        s, p_sh, None, None, all_in_sh, ct_out_sh)
                continue
            if ct_keys:
                ct_sh = {k: pull_sh[k] for k in ct_keys}
                rest_keys_sh = {**in_sh,
                                **{k: v for k, v in pull_sh.items()
                                   if k not in ct_keys}}

                def bwd_fn(params, cts_in, rest, cts, _fn=s.fn,
                           _call=call):
                    def f(p, c):
                        return _call(_fn, p, {**rest, **c})
                    _, vjp = jax.vjp(f, params, cts_in)
                    g_p, g_c = vjp(cts)
                    return g_p, g_c
                self._bwd[name] = jax.jit(
                    bwd_fn,
                    in_shardings=(p_sh, ct_sh, rest_keys_sh, ct_out_sh),
                    out_shardings=(p_sh, ct_sh))
            else:
                def bwd_fn(params, inputs, cts, _fn=s.fn, _call=call):
                    def f(p):
                        return _call(_fn, p, inputs)
                    _, vjp = jax.vjp(f, params)
                    return vjp(cts)[0]
                self._bwd[name] = jax.jit(
                    bwd_fn, in_shardings=(p_sh, all_in_sh, ct_out_sh),
                    out_shardings=p_sh)

    # ------------------------------------------------------------------ #
    def _zero_inputs(self, name: str) -> Dict[str, Any]:
        out = {}
        for k, (shp, dt, fill) in self._in_spec[name].items():
            if k.endswith(".act_idx"):
                out[k] = jnp.arange(shp[0], dtype=jnp.int32)
            elif fill:
                out[k] = jnp.full(shp, fill, dt)
            else:
                out[k] = jnp.zeros(shp, dt)
        return out

    def _warmup(self, by_name: Dict[str, SectionSpec]) -> None:
        """Trace + compile every worker-thread jit from the main thread:
        the act-hook / attention-impl globals are process-wide, so
        concurrent first-call tracing from two section workers races."""
        params = {}
        for i, s in enumerate(self.spec.sections):
            params[s.name] = jax.device_put(
                cm.init_params(s.params, jax.random.PRNGKey(i)),
                self._p_shard[s.name])
        outs = []
        for name in self._topo:
            s = by_name[name]
            inputs = self._zero_inputs(name)
            for c in s.consumes:
                shp, dt = self._port_zero[name][c.key]
                inputs[c.key] = jax.device_put(
                    jnp.zeros(shp, dt), self._pull_shard[name][c.key])
            if s.critical:
                ct_keys = self._ct_keys(s)
                rest = {k: v for k, v in inputs.items()
                        if k not in ct_keys}
                if ct_keys:
                    cts = {k: inputs[k] for k in ct_keys}
                    outs.append(self._grad(params[name], cts, rest))
                else:
                    outs.append(self._grad(params[name], rest))
                continue
            out = self._fwd[name](params[name], inputs)
            outs.append(out)
            if s.trainable:
                # fresh zeros in the queue-pull layout: the fwd OUTPUT may
                # carry a CP/seq-sharded layout the bwd jit does not take
                cts = {p.name: jax.device_put(
                    jnp.zeros(out[p.name].shape, out[p.name].dtype),
                    self._ct_pull_shard[name][p.name])
                    for p in s.emits}
                ct_keys = self._ct_keys(s)
                if ct_keys:
                    rest = {k: v for k, v in inputs.items()
                            if k not in ct_keys}
                    outs.append(self._bwd[name](
                        params[name], {k: inputs[k] for k in ct_keys},
                        rest, cts))
                else:
                    outs.append(self._bwd[name](params[name], inputs,
                                                cts))
        # the optimizer path runs on worker threads too (the per-section
        # ``upd`` dispatch): trace + compile the ssq and AdamW-update jits
        # here with dummy (donated) state so no worker ever traces
        for name in self._trainable:
            gs = jax.device_put(
                jax.tree_util.tree_map(
                    lambda x: jnp.zeros(x.shape, x.dtype), params[name]),
                self._p_shard[name])
            st = jax.device_put(adamw.init(params[name]),
                                self._o_shard[name])
            if name in self._compress:
                g_stacked = self._ef_init(name, params[name])
                outs.append(self._compress_step[name](
                    g_stacked, self._ef_init(name, params[name]),
                    jnp.float32(1.0)))
            outs.append(self._ssq[name](gs))
            lr = self.lr_fn(jnp.int32(0))
            if self.opt_cfg.clip_norm > 0:
                outs.append(self._update[name](gs, st, lr,
                                               jnp.float32(1.0)))
            else:
                outs.append(self._update[name](gs, st, lr))
        jax.block_until_ready(outs)

    # ------------------------------------------------------------------ #
    # planning
    # ------------------------------------------------------------------ #
    def plan_iteration(self, host: Dict[str, np.ndarray], *,
                       reorder: bool = True) -> IterationPlan:
        """Activation predicates → cost-model 6-tuples → wavefront (or
        FIFO) sample order → per-section capacity layouts."""
        assert self._built is not None, "runtime not shape-bound yet"
        flags: Dict[str, np.ndarray] = {}
        n = None
        for s in self.spec.sections:
            if s.activation is not None:
                f = np.asarray(s.activation(host)).astype(bool)
                flags[s.name] = f
                n = len(f)
        if n is None:
            n = self.B
        assert n == self.B, (n, self.B)
        if not flags:
            # homogeneous batch: every order ties — skip the scheduler
            reorder = False
        samples = cmdl.sample_tuples(self.graph, flags, self.S or 1, n=n)
        order, sched = order_samples(samples, reorder=reorder)
        activation = {name: build_activation(order, f, self.mbs)
                      for name, f in flags.items()}
        return IterationPlan(tuple(order), self.mbs, self.n_mb,
                             activation, sched)

    def _dispatched(self, plan: IterationPlan) -> Dict[str, set]:
        """Effective per-microbatch dispatch sets: a producer runs on mb
        ``i`` iff its predicate activates AND some consumer of it is
        dispatched on ``i`` (work nobody pulls is never submitted)."""
        disp: Dict[str, set] = {self._crit: set(range(plan.n_mb))}
        for name in reversed(self._topo):
            if name == self._crit:
                continue
            s = self.spec.section(name)
            avail: set = set()
            for p in s.emits:
                for c in self.spec.consumers_of(name, p.name):
                    avail |= disp.get(c, set())
            act = plan.activation.get(name)
            mine = (set(act.active_mbs) if act is not None
                    else set(range(plan.n_mb)))
            disp[name] = mine & avail
        return disp

    # ------------------------------------------------------------------ #
    # streaming state: install / state
    # ------------------------------------------------------------------ #
    def install(self, params: Dict[str, Any],
                opts: Dict[str, Any]) -> None:
        """Adopt per-section params (every section) and optimizer states
        (at least every trainable section) as the runtime's streaming
        state.  Worker-side ``upd`` tasks advance this state in place;
        read it back with :meth:`state`.  Requires a quiescent runtime
        (nothing in flight)."""
        if self._inflight:
            raise RuntimeError(
                "install() requires a quiescent runtime — retire()/"
                "drain() the in-flight iterations first")
        missing = {s.name for s in self.spec.sections} - set(params)
        if missing:
            raise ValueError(f"install: missing params for sections "
                             f"{sorted(missing)}")
        missing_o = set(self._trainable) - set(opts)
        if missing_o:
            raise ValueError(f"install: missing optimizer state for "
                             f"trainable sections {sorted(missing_o)}")
        # donation lint (repro.analysis.donation): the worker-side update
        # jits DONATE the installed optimizer state, and jax.device_put
        # is a no-copy identity when the sharding already matches — so
        # re-installing a tree a previous stream consumed, or installing
        # trees that alias each other, would crash deep inside a worker
        # jit.  Catch every such hazard here with a named error instead.
        from repro.analysis import donation as _donation
        _donation.lint_state(params, opts, runtime=self,
                             ef=self._ef).raise_on_error(
            adamw.DonatedStateError, "install: donation lint failed")
        self._params = dict(params)
        self._opts = dict(opts)
        # error-feedback residuals for compressed sections: zero-init on
        # FIRST install only, preserved across installs — the serialized
        # train_iteration wrapper installs every step, and resetting EF
        # there would silently disable the int8 residual carry
        for name in self._compress:
            if name not in self._ef:
                self._ef[name] = self._ef_init(name, self._params[name])
        self._installed = True

    def state(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Snapshot of the current (params, opts) streaming state.  Only
        consistent across sections when nothing is in flight."""
        return dict(self._params), dict(self._opts)

    @property
    def in_flight(self) -> int:
        return len(self._inflight)

    # ------------------------------------------------------------------ #
    # one training iteration on the executor (streaming)
    # ------------------------------------------------------------------ #
    def submit_iteration(self, batch, step_idx, *,
                         reorder: bool = True,
                         plan: Optional[IterationPlan] = None,
                         consts: Optional[Dict[str, Dict[str, Any]]] = None,
                         return_grads: bool = False,
                         timeout: float = 300.0) -> int:
        """Enqueue one global-batch iteration onto the section streams
        and return its sequence number.  All tasks — including each
        trainable section's grad-finalize + AdamW ``upd`` — run on the
        section workers; nothing blocks here beyond the ``lookahead``
        gate (when more than ``lookahead`` iterations are already in
        flight, the oldest is retired first and its metrics buffered for
        the next :meth:`retire`)."""
        assert self._installed, \
            "call install(params, opts) before submit_iteration()"
        while len(self._inflight) > self.lookahead:
            self._retired.append(self._retire_oldest(timeout=timeout))
        host = {k: np.asarray(v) for k, v in batch.items()}
        self._ensure_built(host)
        if plan is None:
            plan = self.plan_iteration(host, reorder=reorder)
        assert plan.mbs == self.mbs and plan.n_mb == self.n_mb, \
            (plan.mbs, plan.n_mb, self.mbs, self.n_mb)
        idx = list(plan.order)
        keys = {k for s in self.spec.sections for k in s.inputs}
        ordered = {k: host[k][idx] for k in keys}
        placed_consts: Dict[str, Dict[str, Any]] = {}
        for s in self.spec.sections:
            if s.consts:
                given = (consts or {}).get(s.name, {})
                missing = set(s.consts) - set(given)
                if missing:
                    raise ValueError(
                        f"section {s.name!r}: missing consts "
                        f"{sorted(missing)}")
                rep = shd.replicated(self.rt.mesh(s.name))
                placed_consts[s.name] = {
                    k: jax.device_put(given[k], rep) for k in s.consts}
        disp = self._dispatched(plan)
        by_name = {s.name: s for s in self.spec.sections}
        m = plan.mbs
        q = self.rt.queue
        rec = _Inflight(self._it_seq, f"s{self._it_seq}", step_idx, plan,
                        return_grads, self._trainable)
        self._it_seq += 1
        it = rec.scope     # iteration-scoped tag namespace (evicted on
        #                    retirement: cross-iteration prefetch cannot
        #                    alias message keys across iterations)

        def mb_inputs(s: SectionSpec, i: int) -> Dict[str, Any]:
            rows = slice(i * m, (i + 1) * m)
            act = plan.activation.get(s.name)
            out = {}
            for k in s.inputs:
                v = ordered[k][rows]
                if act is not None:
                    v = v[act.idx[i]]
                out[k] = jax.device_put(jnp.asarray(v),
                                        self._in_shard[s.name][k])
            if act is not None:
                out["act_valid"] = jax.device_put(
                    jnp.asarray(act.valid[i]),
                    self._in_shard[s.name]["act_valid"])
            for c in s.consumes:
                sa = plan.activation.get(c.section)
                if sa is not None:
                    out[f"{c.section}.act_idx"] = jnp.asarray(sa.idx[i])
                    out[f"{c.section}.act_valid"] = jnp.asarray(
                        sa.valid[i])
            for k, v in placed_consts.get(s.name, {}).items():
                out[k] = v
            return out

        def pull_consumed(s: SectionSpec, i: int) -> Dict[str, Any]:
            pulled, stalled = {}, False
            for c in s.consumes:
                if i in disp.get(c.section, ()):
                    pulled[c.key] = q.pull(
                        c.section, s.name, f"{it}/{c.key}.{i}",
                        sharding=self._pull_shard[s.name][c.key],
                        timeout=timeout)
                    stalled = True
                else:
                    # inactive producer: the port's contribution is the
                    # exact zero a colocated step computes
                    shp, dt = self._port_zero[s.name][c.key]
                    pulled[c.key] = jax.device_put(
                        jnp.zeros(shp, dt),
                        self._pull_shard[s.name][c.key])
            if stalled:
                mark_start()      # dependency wait is idle, not busy
            return pulled

        def fwd_task(s: SectionSpec, i: int):
            def fn():
                pulled = pull_consumed(s, i)
                inputs = {**mb_inputs(s, i), **pulled}
                out = self._fwd[s.name](self._params[s.name], inputs)
                if s.trainable:
                    rec.ctx[(s.name, i)] = inputs
                for p in s.emits:
                    for cname in self.spec.consumers_of(s.name, p.name):
                        if i in disp.get(cname, ()):
                            q.push(s.name, cname,
                                   f"{it}/{s.name}.{p.name}.{i}",
                                   out[p.name])
                return out
            return fn

        def crit_task(i: int):
            s = by_name[self._crit]
            ct_keys = self._ct_keys(s)

            def fn():
                pulled = pull_consumed(s, i)
                rest = {**mb_inputs(s, i),
                        **{k: v for k, v in pulled.items()
                           if k not in ct_keys}}
                if self._grad_has_ct:
                    cts = {k: pulled[k] for k in ct_keys}
                    val, g_p, g_c = self._grad(self._params[s.name], cts,
                                               rest)
                else:
                    g_c = {}
                    val, g_p = self._grad(self._params[s.name], rest)
                loss, aux = (val if s.loss_aux else (val, None))
                for c in s.consumes:
                    if c.key in g_c and i in disp.get(c.section, ()):
                        q.push(s.name, c.section,
                               f"{it}/ct.{c.key}.{i}", g_c[c.key])
                rec.crit_acc["loss"] = rec.crit_acc["loss"] + loss
                if aux is not None:
                    a0 = rec.crit_acc["aux"]
                    rec.crit_acc["aux"] = aux if a0 is None else \
                        jax.tree_util.tree_map(lambda x, y: x + y, a0, aux)
                g0 = rec.acc[s.name]["g"]
                if g0 is None:
                    # f32 zero seed, like a colocated scan carry — seeding
                    # with the raw param-dtype grad would double-round
                    # (g_p shapes, not params: compressed sections emit
                    # stacked [dp, ...] per-shard partial grads)
                    g0 = jax.tree_util.tree_map(
                        lambda x: jnp.zeros(x.shape, jnp.float32), g_p)
                rec.acc[s.name]["g"] = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g0, g_p)
                # block before finishing: the section mesh must be quiet
                # when another thread launches its next collective-bearing
                # program (XLA CPU rendezvous contract)
                jax.block_until_ready((rec.acc[s.name]["g"],
                                       rec.crit_acc["loss"]))
                return loss
            return fn

        def bwd_task(s: SectionSpec, i: int):
            ct_keys = self._ct_keys(s)

            def fn():
                cts = {}
                for p in s.emits:
                    consumer = self.spec.consumers_of(s.name, p.name)[0]
                    cts[p.name] = q.pull(
                        consumer, s.name, f"{it}/ct.{s.name}.{p.name}.{i}",
                        sharding=self._ct_pull_shard[s.name][p.name],
                        timeout=timeout)
                mark_start()
                inputs = rec.ctx.pop((s.name, i))
                if ct_keys:
                    rest = {k: v for k, v in inputs.items()
                            if k not in ct_keys}
                    g_p, g_c = self._bwd[s.name](
                        self._params[s.name],
                        {k: inputs[k] for k in ct_keys}, rest, cts)
                    for c in s.consumes:
                        if c.key in g_c and i in disp.get(c.section, ()):
                            q.push(s.name, c.section,
                                   f"{it}/ct.{c.key}.{i}", g_c[c.key])
                else:
                    g_p = self._bwd[s.name](self._params[s.name], inputs,
                                            cts)
                g0 = rec.acc[s.name]["g"]
                if g0 is None:
                    g0 = jax.tree_util.tree_map(
                        lambda x: jnp.zeros(x.shape, jnp.float32), g_p)
                rec.acc[s.name]["g"] = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g0, g_p)
                jax.block_until_ready(rec.acc[s.name]["g"])
                return True
            return fn

        n_mb = plan.n_mb
        trainable = list(self._trainable)

        def upd_task(name: str):
            peers = [n for n in trainable if n != name]
            comp = self._compress.get(name)

            def fn():
                g = rec.acc[name]["g"]
                if g is None:      # section never dispatched: exact zero
                    lead = (self._comp_dp[name],) if comp else ()
                    g = jax.tree_util.tree_map(
                        lambda x: jnp.zeros(lead + x.shape, jnp.float32),
                        self._params[name])
                if comp:
                    # stacked per-shard partial grads → ONE compressed
                    # all-reduce over the data axis; the error-feedback
                    # residual threads to the next iteration
                    gs, self._ef[name] = self._compress_step[name](
                        g, self._ef[name], jnp.float32(1.0 / n_mb))
                else:
                    gs = jax.tree_util.tree_map(
                        lambda g_, p: (g_ / n_mb).astype(p.dtype), g,
                        self._params[name])
                # joint grad-norm rendezvous: every trainable section
                # pushes its per-leaf sum-of-squares vector to every peer
                # BEFORE pulling any (pushes never block → no wait cycle),
                # then all of them assemble the identical host reduction in
                # sorted-section order — bitwise the one global clip
                # threshold the colocated / main-thread finalize computed
                vec = self._ssq[name](gs)
                for p in peers:
                    q.push(name, p, f"{it}/gnorm.{name}", vec)
                vecs = {name: np.asarray(jax.device_get(vec))}
                for p in peers:
                    vecs[p] = np.asarray(jax.device_get(
                        q.pull(p, name, f"{it}/gnorm.{p}",
                               timeout=timeout)))
                if peers:
                    mark_start()   # rendezvous wait is idle, not busy
                gnorm = jnp.sqrt(jnp.sum(jnp.asarray(
                    np.concatenate([vecs[n] for n in sorted(vecs)]))))
                lr = self.lr_fn(jnp.int32(rec.step_idx))
                if self.opt_cfg.clip_norm > 0:
                    p2, o2, _ = self._update[name](gs, self._opts[name],
                                                   lr, gnorm)
                else:
                    p2, o2, _ = self._update[name](gs, self._opts[name],
                                                   lr)
                # synchronize the update program before installing: this
                # worker's next task (fwd of iteration i+1) launches the
                # next collective-bearing program on the same section mesh
                # (XLA CPU rendezvous contract)
                jax.block_until_ready((p2, o2))
                self._params[name], self._opts[name] = p2, o2
                out = {"grad_norm": gnorm, "lr": lr}
                if rec.return_grads:
                    out["grads"] = gs
                if name == self._crit:
                    out["loss"] = (rec.crit_acc["loss"]
                                   / n_mb).astype(jnp.float32)
                    if rec.crit_acc["aux"] is not None:
                        out["aux"] = jax.tree_util.tree_map(
                            lambda v: (v / n_mb).astype(jnp.float32),
                            rec.crit_acc["aux"])
                return out
            return fn

        dispatches: List[Dispatch] = []
        for name in self._topo:
            if name == self._crit:
                continue
            s = by_name[name]
            for i in sorted(disp[name]):
                dispatches.append(Dispatch(name, f"fwd{i}",
                                           fwd_task(s, i)))
        for i in range(plan.n_mb):
            dispatches.append(Dispatch(self._crit, f"mb{i}",
                                       crit_task(i)))
        for name in reversed(self._topo):
            s = by_name[name]
            if name == self._crit or not s.trainable:
                continue
            for i in sorted(disp[name]):
                dispatches.append(Dispatch(name, f"bwd{i}",
                                           bwd_task(s, i)))
        # grad-finalize + AdamW run on each trainable section's OWN worker:
        # the per-section FIFO serializes update(i) before that section's
        # fwd(i+1) while other sections stream ahead independently
        for name in self._topo:
            if by_name[name].trainable:
                dispatches.append(Dispatch(name, "upd", upd_task(name)))
        self._session.submit(rec.seq, dispatches)
        self._inflight.append(rec)
        return rec.seq

    # ------------------------------------------------------------------ #
    # retirement: collect one iteration's metrics
    # ------------------------------------------------------------------ #
    def _retire_oldest(self, *, timeout: float = 300.0) -> dict:
        rec = self._inflight.popleft()
        try:
            execution = self._session.retire(rec.seq, timeout=timeout)
        finally:
            leftovers = self.rt.queue.evict_scope(rec.scope)
            if leftovers:
                _log.warning(
                    "iteration %s retired with undrained messages "
                    "(producer pushed, no consumer pulled): %s",
                    rec.step_idx, leftovers)
        self.last_execution = execution
        upd = {n: execution.results[(n, "upd")] for n in self._trainable}
        crit = upd[self._crit]
        metrics = {"loss": crit["loss"], "grad_norm": crit["grad_norm"],
                   "lr": crit["lr"], "execution": execution,
                   "plan": rec.plan, "n_tasks": execution.task_counts}
        for k, v in crit.get("aux", {}).items():
            metrics[k] = v
        if rec.return_grads:
            metrics["grads"] = {n: upd[n]["grads"]
                                for n in self._trainable}
        return metrics

    def retire(self, *, timeout: float = 300.0) -> dict:
        """Block until the oldest outstanding iteration completes and
        return its metrics dict (loss / joint grad_norm / lr / aux
        scalars / realized ``execution`` timeline / ``plan`` /
        per-section ``n_tasks``).  Iterations auto-retired by the
        lookahead gate are returned first, in order."""
        if self._retired:
            return self._retired.popleft()
        if not self._inflight:
            raise RuntimeError("retire(): no iteration in flight")
        return self._retire_oldest(timeout=timeout)

    def drain(self, *, timeout: float = 300.0) -> List[dict]:
        """Retire every outstanding iteration (oldest first); returns
        their metrics in submission order.  Leaves the runtime quiescent
        — required before ``install()`` or shape rebinding."""
        out = []
        while self._retired:
            out.append(self._retired.popleft())
        while self._inflight:
            out.append(self._retire_oldest(timeout=timeout))
        return out

    # ------------------------------------------------------------------ #
    # serialized compatibility wrapper
    # ------------------------------------------------------------------ #
    def train_iteration(self, params, opts, batch, step_idx, *,
                        reorder: bool = True,
                        plan: Optional[IterationPlan] = None,
                        consts: Optional[Dict[str, Dict[str, Any]]] = None,
                        return_grads: bool = False,
                        timeout: float = 300.0):
        """One serialized global-batch iteration: ``install`` the given
        state, ``submit_iteration``, ``retire``, and return ``(params,
        opts, metrics)``.  Exactly the streaming path at lookahead
        depth 0 — there is no second execution mode."""
        if self._inflight or self._retired:
            raise RuntimeError(
                "train_iteration() is the serialized wrapper; it cannot "
                "interleave with in-flight submit_iteration()/retire() "
                "streams — drain() first")
        self.install(params, opts)
        self.submit_iteration(batch, step_idx, reorder=reorder, plan=plan,
                              consts=consts, return_grads=return_grads,
                              timeout=timeout)
        metrics = self.retire(timeout=timeout)
        new_params, new_opts = self.state()
        return new_params, new_opts, metrics

    # ------------------------------------------------------------------ #
    def shutdown(self):
        self.rt.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False
