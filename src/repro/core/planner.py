"""Two-stage section hyper-parameter optimization (paper §3.2).

The joint search over {C^s} = {DP, TP, PP, CP, mbs, fanout} per section is
combinatorial; Maestro's hierarchy makes it tractable:

* **Stage 1 (critical-first)**: fix the critical section's GPU budget
  (= the baseline allocation, as in the paper's evaluation) and pick the
  C^crit maximizing per-sample throughput subject to the per-GPU memory
  constraint.
* **Stage 2 (auxiliary-adaptive)**: for each auxiliary section, choose the
  *minimal* GPU count (and a fanout consistent with
  DP^aux × fanout = DP^crit for producers) such that its per-iteration time
  fully overlaps the critical section — no stalls, no backpressure.

Constraints enforced (paper eq. 2): Σ N^s ≤ N_GPUs; max memory ≤ HBM;
DP^fr × fanout = DP^sr on every edge.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core import cost_model as cmdl
from repro.core.graph import SectionGraph
from repro.core.types import (ArchConfig, HardwareSpec, ParallelConfig,
                              SectionConfig, V5E)


def _divisors_leq(n: int, cap: int) -> List[int]:
    return [d for d in range(1, min(n, cap) + 1) if n % d == 0]


def candidate_parallelisms(cfg: ArchConfig, n_gpus: int, *,
                           max_tp: int = 16, max_pp: int = 8,
                           max_cp: int = 16,
                           mbs_options=(1, 2, 4, 8, 16)
                           ) -> List[ParallelConfig]:
    """Hardware-valid C^s candidates on exactly n_gpus devices.

    TP must divide attention heads (or the SSM inner dim for attn-free
    archs); PP must divide the layer stack; CP divides the sequence (checked
    at use); DP = n_gpus / (tp·pp·cp)."""
    if cfg.num_heads:
        # TP divides the Q heads; KV heads are replicated when tp > kv
        tps = _divisors_leq(cfg.num_heads, max_tp)
    else:
        tps = _divisors_leq(cfg.ssm_expand * cfg.d_model // cfg.ssm_headdim,
                            max_tp)
    pps = _divisors_leq(cfg.num_layers, max_pp)
    out = []
    for tp, pp in itertools.product(tps, pps):
        for cp in _divisors_leq(max_cp, max_cp):
            denom = tp * pp * cp
            if n_gpus % denom:
                continue
            dp = n_gpus // denom
            for mbs in mbs_options:
                out.append(ParallelConfig(dp=dp, tp=tp, pp=pp, cp=cp,
                                          mbs=mbs))
    return out


@dataclass
class SectionPlan:
    name: str
    parallel: ParallelConfig
    n_gpus: int
    t_iter: float                 # seconds per iteration on this section
    mem_per_gpu: float
    fanout: int = 1
    stalls_critical: bool = False  # True when overlap was not achievable


@dataclass
class Plan:
    sections: Dict[str, SectionPlan]
    total_gpus: int
    iter_time: float              # critical-path iteration time

    def summary(self) -> str:
        rows = [f"{n}: gpus={p.n_gpus} dp={p.parallel.dp} "
                f"tp={p.parallel.tp} pp={p.parallel.pp} cp={p.parallel.cp} "
                f"mbs={p.parallel.mbs} fanout={p.fanout} "
                f"t_iter={p.t_iter*1e3:.1f}ms mem={p.mem_per_gpu/2**30:.1f}GiB"
                + (" [STALLS CRITICAL]" if p.stalls_critical else "")
                for n, p in self.sections.items()]
        return "\n".join(rows)


def _iter_time(cfg: ArchConfig, parallel: ParallelConfig, seq_len: int,
               samples_per_iter: int, *, trainable: bool,
               hw: HardwareSpec) -> float:
    """Per-iteration wall time of a section processing samples_per_iter
    samples with dp-way data parallelism and grad-accum microbatching."""
    per_dp = max(samples_per_iter // max(parallel.dp, 1), 1)
    n_micro = max(per_dp // max(parallel.mbs, 1), 1)
    t_mb = cmdl.microbatch_time(cfg, parallel, seq_len,
                                forward_only=not trainable,
                                num_microbatches=n_micro, hw=hw)
    return n_micro * t_mb


def plan_critical(section: SectionConfig, n_gpus: int, seq_len: int,
                  global_batch: int, *, hw: HardwareSpec = V5E
                  ) -> SectionPlan:
    """Stage 1: best C^crit on a fixed GPU budget."""
    best: Optional[SectionPlan] = None
    for cand in candidate_parallelisms(section.arch, n_gpus):
        if global_batch % cand.dp:
            continue
        if not cmdl.fits(section.arch, cand, seq_len,
                         trainable=section.trainable, hw=hw):
            continue
        t = _iter_time(section.arch, cand, seq_len, global_batch,
                       trainable=section.trainable, hw=hw)
        if best is None or t < best.t_iter:
            best = SectionPlan(section.name, cand, n_gpus, t,
                               cmdl.memory_per_gpu(
                                   section.arch, cand, seq_len,
                                   trainable=section.trainable))
    if best is None:
        raise ValueError(
            f"no feasible config for critical section {section.name} on "
            f"{n_gpus} GPUs (memory?)")
    return best


def plan_auxiliary(section: SectionConfig, crit_plan: SectionPlan,
                   seq_len: int, samples_per_iter: int, *,
                   producer_edge_fanouts=(1, 2, 4, 8),
                   is_producer: bool, activation_rate: float = 1.0,
                   gpu_cap: Optional[int] = None,
                   hw: HardwareSpec = V5E) -> SectionPlan:
    """Stage 2: minimal GPUs such that t_aux ≤ t_crit (full overlap).

    activation_rate: fraction of samples activating this section
    (data-dependent sparsity shrinks its effective work).  If no budget up
    to ``gpu_cap`` (default 2×critical) achieves overlap, returns the
    least-stalling plan at the cap with ``stalls_critical=True``."""
    eff_samples = max(int(samples_per_iter * activation_rate), 1)
    budget = crit_plan.t_iter
    cap = gpu_cap or 2 * crit_plan.n_gpus
    ns = sorted({max(crit_plan.n_gpus // f, 1)
                 for f in (256, 128, 64, 32, 16, 8, 4, 2, 1)}
                | {crit_plan.n_gpus * m // 4 for m in (5, 6, 8)})
    ns = [n for n in ns if n <= cap]
    fallback = None
    for n in ns:
        best = None
        for cand in candidate_parallelisms(section.arch, n):
            if is_producer:
                fo = [f for f in producer_edge_fanouts
                      if cand.dp * f == crit_plan.parallel.dp]
                if not fo:
                    continue
                fanout = fo[0]
            else:
                fanout = 1
            if eff_samples % cand.dp:
                continue
            if not cmdl.fits(section.arch, cand, seq_len,
                             trainable=section.trainable, hw=hw):
                continue
            t = _iter_time(section.arch, cand, seq_len, eff_samples,
                           trainable=section.trainable, hw=hw)
            sp = SectionPlan(section.name, cand, n, t,
                             cmdl.memory_per_gpu(
                                 section.arch, cand, seq_len,
                                 trainable=section.trainable),
                             fanout=fanout,
                             stalls_critical=t > budget)
            if t <= budget and (best is None or t < best.t_iter):
                best = sp
            if fallback is None or t < fallback.t_iter:
                fallback = sp
        if best is not None:
            return best
    if fallback is not None:
        return fallback
    raise ValueError(f"no feasible config at all for auxiliary section "
                     f"{section.name} (memory?)")


def plan(graph: SectionGraph, *, critical_gpus: int, seq_len: int,
         global_batch: int, activation_rates: Optional[Dict[str, float]]
         = None, hw: HardwareSpec = V5E) -> Plan:
    """End-to-end two-stage planning for a section graph."""
    activation_rates = activation_rates or {}
    crit = graph.critical
    crit_plan = plan_critical(crit, critical_gpus,
                              int(seq_len * crit.seq_scale), global_batch,
                              hw=hw)
    plans = {crit.name: crit_plan}
    for name, sec in graph.sections.items():
        if name == crit.name:
            continue
        producer = any(e.dst == crit.name for e in graph.consumers_of(name))
        p = plan_auxiliary(sec, crit_plan, int(seq_len * sec.seq_scale),
                           global_batch, is_producer=producer,
                           activation_rate=activation_rates.get(name, 1.0),
                           hw=hw)
        plans[name] = p
    total = sum(p.n_gpus for p in plans.values())
    return Plan(plans, total, crit_plan.t_iter)
