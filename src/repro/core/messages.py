"""Asynchronous, asymmetric M-to-N message queue for cross-section tensors
(paper §3.3), JAX-native.

The RDMA design maps onto JAX as:

* CPU subchannel (metadata)  → an in-process, thread-safe queue of
  :class:`Meta` records (tensor name, global shape/dtype, shard index,
  sender's position in its TP/CP group).
* GPU subchannel (one-sided data) → ``jax.Array`` references.  JAX arrays
  are immutable and dispatch is async, so handing the array over IS the
  one-sided push: the sender never blocks on the receiver, and the device
  buffer moves only when the receiver materializes it on its own mesh
  (``jax.device_put`` / ``make_array_from_single_device_arrays`` → ICI DMA
  on a real pod).

``push`` transmits a (possibly sharded) tensor to a destination section;
``pull`` dequeues the earliest message, automatically gathering fragments
pushed by multiple senders (the M-to-N pattern) and resharding onto the
receiver's mesh/spec.

Iteration scopes: a key of the form ``"<scope>/<rest>"`` belongs to tag
namespace ``<scope>`` (the streaming runtime scopes every iteration's
traffic under a monotonic ``s<i>/`` prefix).  ``evict_scope`` retires a
namespace when its iteration retires: leftover messages are dropped
(and reported) and later push/pull against the retired scope raise —
cross-iteration prefetch can neither alias a stale tensor nor leak
buffers.  Keys without a ``/`` are unscoped and never evicted.
"""
from __future__ import annotations

import logging
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_log = logging.getLogger("repro.messages")


class StaleScopeError(RuntimeError):
    """A push or pull targeted an iteration scope that was already
    retired by ``evict_scope`` — cross-iteration traffic may not alias a
    sealed namespace.  Subclasses RuntimeError so pre-existing handlers
    keep working."""


class PullTimeout(TimeoutError):
    """A blocking ``pull`` exhausted its timeout.  The message names the
    producing section, the iteration scope being waited on, and the keys
    that ARE buffered on the edge (a stale scope or a typo'd microbatch
    index is usually the answer).  Subclasses TimeoutError so
    pre-existing handlers keep working."""


@dataclass(frozen=True)
class Meta:
    key: str                      # logical tensor name (+ microbatch tag)
    src_section: str
    global_shape: Tuple[int, ...]
    dtype: Any
    frag_index: Tuple[slice, ...]   # where this fragment sits globally
    frag_rank: int                  # sender's position in its group
    frag_count: int                 # senders contributing to this tensor
    seq: int = 0                    # FIFO sequence number


class _Channel:
    """One (src_section → dst_section) point-to-point channel.

    Metadata is indexed *per key* (``metas[key][frag_rank]``) so a ``pull``
    wakeup inspects exactly its own key instead of rescanning every
    buffered message — O(frag_count) per wakeup however deep the channel
    backlog is."""

    def __init__(self):
        self.metas: Dict[str, Dict[int, Meta]] = {}
        self.data: Dict[Tuple[str, int], jax.Array] = {}
        self.lock = threading.Lock()
        self.cv = threading.Condition(self.lock)


class MessageQueue:
    """M-to-N cross-section transfer with automatic resharding."""

    def __init__(self):
        self._channels: Dict[Tuple[str, str], _Channel] = {}
        self._lock = threading.Lock()
        self._seq = 0
        self._retired_scopes: set = set()
        self.bytes_pushed = 0
        self.pushes = 0

    def _channel(self, src: str, dst: str) -> _Channel:
        with self._lock:
            if (src, dst) not in self._channels:
                self._channels[(src, dst)] = _Channel()
            return self._channels[(src, dst)]

    @staticmethod
    def _scope(key: str) -> Optional[str]:
        return key.split("/", 1)[0] if "/" in key else None

    def _check_scope(self, op: str, src: str, dst: str, key: str) -> None:
        sc = self._scope(key)
        if sc is not None:
            with self._lock:
                retired = sc in self._retired_scopes
            if retired:
                raise StaleScopeError(
                    f"{op}({src}->{dst}, {key}): iteration scope {sc!r} "
                    "is already retired — cross-iteration traffic may "
                    "not alias a retired namespace")

    # ------------------------------------------------------------------ #
    def push(self, src: str, dst: str, key: str, value: jax.Array, *,
             frag_index: Optional[Tuple[slice, ...]] = None,
             frag_rank: int = 0, frag_count: int = 1,
             global_shape: Optional[Tuple[int, ...]] = None) -> None:
        """One-sided send: enqueue metadata, hand over the device buffer.

        For M-to-N, each of the ``frag_count`` senders pushes its fragment
        with its ``frag_index`` into the global tensor."""
        self._check_scope("push", src, dst, key)
        ch = self._channel(src, dst)
        gshape = tuple(global_shape or value.shape)
        fidx = frag_index or tuple(slice(0, d) for d in gshape)
        with self._lock:
            seq = self._seq
            self._seq += 1
        meta = Meta(key, src, gshape, value.dtype, fidx, frag_rank,
                    frag_count, seq)
        with ch.cv:
            ch.data[(key, frag_rank)] = value
            ch.metas.setdefault(key, {})[frag_rank] = meta
            self.bytes_pushed += value.size * value.dtype.itemsize
            self.pushes += 1
            ch.cv.notify_all()

    # ------------------------------------------------------------------ #
    def pull(self, src: str, dst: str, key: str, *,
             sharding: Optional[NamedSharding] = None,
             timeout: Optional[float] = 30.0) -> jax.Array:
        """Dequeue ``key``; gather all fragments; reshard to ``sharding``.

        Fragments that tile the global tensor contiguously along axis 0
        (the common TP/DP handoff layout) are assembled *device-side* with
        ``jnp.concatenate`` — no host ``np.zeros`` round-trip; arbitrary
        fragment layouts keep the host-assembly fallback."""
        self._check_scope("pull", src, dst, key)
        ch = self._channel(src, dst)
        # absolute deadline: wakeups for OTHER keys on the channel must
        # not restart the clock (steady unrelated traffic would defer
        # the timeout forever)
        deadline = None if timeout is None or timeout < 0 else (
            time.monotonic() + timeout)
        with ch.cv:
            while True:
                metas = ch.metas.get(key, {})
                need = (next(iter(metas.values())).frag_count if metas
                        else 1)
                if len(metas) >= need:
                    metas = dict(metas)
                    frags = {r: ch.data.pop((key, r)) for r in metas}
                    del ch.metas[key]
                    break
                remaining = None if deadline is None else (
                    deadline - time.monotonic())
                if remaining is not None and remaining <= 0 or \
                        not ch.cv.wait(timeout=remaining):
                    # the pending-key set makes cross-iteration stalls
                    # diagnosable: the key that IS buffered (a stale scope,
                    # a typo'd microbatch index) is usually the answer
                    pending = sorted(ch.metas)
                    sc = self._scope(key)
                    scope_note = ("" if sc is None else
                                  f" into iteration scope {sc!r}")
                    raise PullTimeout(
                        f"pull({src}->{dst}, {key}): "
                        f"{len(metas)}/{need} fragments after {timeout}s "
                        f"— producer section {src!r} never pushed "
                        f"{key!r}{scope_note}; pending keys on this "
                        f"edge: {pending}")
        out = _assemble(frags, metas)
        if sharding is not None:
            out = jax.device_put(out, sharding)
        return out

    # ------------------------------------------------------------------ #
    def evict_scope(self, scope: str) -> Dict[str, List[str]]:
        """Retire an iteration's tag namespace: drop every leftover
        message whose key lives under ``scope + "/"`` and refuse future
        push/pull against it.  Returns ``{"src->dst": [evicted keys]}``
        (normally empty — leftovers mean a producer pushed something no
        consumer ever pulled)."""
        with self._lock:
            self._retired_scopes.add(scope)
            channels = list(self._channels.items())
        evicted: Dict[str, List[str]] = {}
        for (src, dst), ch in channels:
            with ch.cv:
                keys = [k for k in ch.metas if self._scope(k) == scope]
                for k in keys:
                    for r in list(ch.metas[k]):
                        ch.data.pop((k, r), None)
                    del ch.metas[k]
                if keys:
                    evicted[f"{src}->{dst}"] = sorted(keys)
                    ch.cv.notify_all()
        for edge, keys in sorted(evicted.items()):
            _log.warning(
                "evict_scope(%r): dropped %d leftover message(s) on %s: "
                "%s — a producer pushed something no consumer ever "
                "pulled", scope, len(keys), edge, keys)
        return evicted

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        """Totals plus a per-edge view: buffered-key depth, the pending
        key set, and the approximate buffered bytes on each
        ``src->dst`` channel."""
        with self._lock:
            channels = list(self._channels.items())
        edges = {}
        for (src, dst), ch in channels:
            with ch.cv:
                pending = sorted(ch.metas)
                nbytes = sum(int(v.size) * v.dtype.itemsize
                             for v in ch.data.values())
            edges[f"{src}->{dst}"] = {"depth": len(pending),
                                      "pending": pending,
                                      "bytes": int(nbytes)}
        return {"pushes": self.pushes, "bytes_pushed": self.bytes_pushed,
                "channels": len(self._channels), "edges": edges}


def _axis0_contiguous(metas: Dict[int, "Meta"]) -> Optional[List[int]]:
    """Rank order in which the fragments tile the global tensor
    contiguously along axis 0 (full slices elsewhere), or None."""
    gshape = next(iter(metas.values())).global_shape
    if not gshape:
        return None
    by_start = []
    for r, m in metas.items():
        idx = m.frag_index
        if len(idx) != len(gshape):
            return None
        for d, sl in enumerate(idx[1:], start=1):
            if (sl.start or 0) != 0 or sl.stop != gshape[d] \
                    or sl.step not in (None, 1):
                return None
        sl0 = idx[0]
        if sl0.step not in (None, 1):
            return None
        by_start.append((sl0.start or 0, sl0.stop, r))
    by_start.sort()
    pos = 0
    order = []
    for start, stop, r in by_start:
        if start != pos:
            return None
        pos = stop
        order.append(r)
    return order if pos == gshape[0] else None


def _assemble(frags: Dict[int, jax.Array], metas: Dict[int, "Meta"]):
    m0 = next(iter(metas.values()))
    if len(frags) == 1:
        (r0, only), = frags.items()
        if tuple(only.shape) == tuple(metas[r0].global_shape):
            return only
    order = _axis0_contiguous(metas)
    if order is not None:
        # device-side assembly: fragments stay jax.Arrays end to end
        return jnp.concatenate([frags[r] for r in order], axis=0)
    # fallback: arbitrary fragment layout assembled on host
    buf = np.zeros(m0.global_shape,
                   jax.dtypes.canonicalize_dtype(m0.dtype))
    for r, arr in frags.items():
        buf[metas[r].frag_index] = np.asarray(arr)
    return jnp.asarray(buf)


def reshard(value: jax.Array, mesh: Mesh, spec: PartitionSpec) -> jax.Array:
    """Direct resharding helper across parallelism domains (TPx → TPy,
    CPx → CPy): on a real pod this lowers to ICI DMA; here it is the same
    ``device_put`` path the queue uses."""
    return jax.device_put(value, NamedSharding(mesh, spec))
