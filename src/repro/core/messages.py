"""Asynchronous, asymmetric M-to-N message queue for cross-section tensors
(paper §3.3), JAX-native.

The RDMA design maps onto JAX as:

* CPU subchannel (metadata)  → an in-process, thread-safe queue of
  :class:`Meta` records (tensor name, global shape/dtype, shard index,
  sender's position in its TP/CP group).
* GPU subchannel (one-sided data) → ``jax.Array`` references.  JAX arrays
  are immutable and dispatch is async, so handing the array over IS the
  one-sided push: the sender never blocks on the receiver, and the device
  buffer moves only when the receiver materializes it on its own mesh
  (``jax.device_put`` / ``make_array_from_single_device_arrays`` → ICI DMA
  on a real pod).

``push`` transmits a (possibly sharded) tensor to a destination section;
``pull`` dequeues the earliest message, automatically gathering fragments
pushed by multiple senders (the M-to-N pattern) and resharding onto the
receiver's mesh/spec.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclass(frozen=True)
class Meta:
    key: str                      # logical tensor name (+ microbatch tag)
    src_section: str
    global_shape: Tuple[int, ...]
    dtype: Any
    frag_index: Tuple[slice, ...]   # where this fragment sits globally
    frag_rank: int                  # sender's position in its group
    frag_count: int                 # senders contributing to this tensor
    seq: int = 0                    # FIFO sequence number


class _Channel:
    """One (src_section → dst_section) point-to-point channel."""

    def __init__(self):
        self.meta_q: "queue.Queue[Meta]" = queue.Queue()
        self.data: Dict[Tuple[str, int], jax.Array] = {}
        self.lock = threading.Lock()
        self.cv = threading.Condition(self.lock)


class MessageQueue:
    """M-to-N cross-section transfer with automatic resharding."""

    def __init__(self):
        self._channels: Dict[Tuple[str, str], _Channel] = {}
        self._lock = threading.Lock()
        self._seq = 0
        self.bytes_pushed = 0
        self.pushes = 0

    def _channel(self, src: str, dst: str) -> _Channel:
        with self._lock:
            if (src, dst) not in self._channels:
                self._channels[(src, dst)] = _Channel()
            return self._channels[(src, dst)]

    # ------------------------------------------------------------------ #
    def push(self, src: str, dst: str, key: str, value: jax.Array, *,
             frag_index: Optional[Tuple[slice, ...]] = None,
             frag_rank: int = 0, frag_count: int = 1,
             global_shape: Optional[Tuple[int, ...]] = None) -> None:
        """One-sided send: enqueue metadata, hand over the device buffer.

        For M-to-N, each of the ``frag_count`` senders pushes its fragment
        with its ``frag_index`` into the global tensor."""
        ch = self._channel(src, dst)
        gshape = tuple(global_shape or value.shape)
        fidx = frag_index or tuple(slice(0, d) for d in gshape)
        with self._lock:
            seq = self._seq
            self._seq += 1
        meta = Meta(key, src, gshape, value.dtype, fidx, frag_rank,
                    frag_count, seq)
        with ch.cv:
            ch.data[(key, frag_rank)] = value
            ch.meta_q.put(meta)
            self.bytes_pushed += value.size * value.dtype.itemsize
            self.pushes += 1
            ch.cv.notify_all()

    # ------------------------------------------------------------------ #
    def pull(self, src: str, dst: str, key: str, *,
             sharding: Optional[NamedSharding] = None,
             timeout: Optional[float] = 30.0) -> jax.Array:
        """Dequeue ``key``; gather all fragments; reshard to ``sharding``."""
        ch = self._channel(src, dst)
        frags: Dict[int, jax.Array] = {}
        metas: Dict[int, Meta] = {}
        need = 1
        deadline = None if timeout is None else (
            threading.TIMEOUT_MAX if timeout < 0 else timeout)
        with ch.cv:
            while True:
                for (k, r), v in list(ch.data.items()):
                    if k == key and r not in frags:
                        frags[r] = v
                metas = {m.frag_rank: m for m in list(ch.meta_q.queue)
                         if m.key == key}
                if metas:
                    need = next(iter(metas.values())).frag_count
                if len(frags) >= need and len(metas) >= need:
                    for r in list(frags):
                        del ch.data[(key, r)]
                    # drop consumed metadata
                    kept = [m for m in ch.meta_q.queue if m.key != key]
                    ch.meta_q.queue.clear()
                    ch.meta_q.queue.extend(kept)
                    break
                if not ch.cv.wait(timeout=deadline):
                    raise TimeoutError(
                        f"pull({src}->{dst}, {key}): "
                        f"{len(frags)}/{need} fragments after {timeout}s")
        if need == 1 and frags[0].shape == metas[0].global_shape:
            out = frags[0]
        else:
            # assemble the global tensor from fragments on host
            m0 = metas[min(metas)]
            buf = np.zeros(m0.global_shape,
                           jax.dtypes.canonicalize_dtype(m0.dtype))
            for r, arr in frags.items():
                buf[metas[r].frag_index] = np.asarray(arr)
            out = jnp.asarray(buf)
        if sharding is not None:
            out = jax.device_put(out, sharding)
        return out

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        return {"pushes": self.pushes, "bytes_pushed": self.bytes_pushed,
                "channels": len(self._channels)}


def reshard(value: jax.Array, mesh: Mesh, spec: PartitionSpec) -> jax.Array:
    """Direct resharding helper across parallelism domains (TPx → TPy,
    CPx → CPy): on a real pod this lowers to ICI DMA; here it is the same
    ``device_put`` path the queue uses."""
    return jax.device_put(value, NamedSharding(mesh, spec))
