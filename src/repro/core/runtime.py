"""Disaggregated Maestro runtime: sections on disjoint device groups.

This is the paper-faithful execution mode: each section owns a device
subset shaped by its C^s (``carve_meshes``), runs its own compiled step
functions from a worker thread, and exchanges tensors through the
:class:`MessageQueue` (§3.3) in the order produced by the wavefront
scheduler (§3.4).

On this CPU container the "devices" are virtual, but the dataflow,
resharding, fanout and scheduling logic are exactly what a multi-controller
deployment executes per pod slice — tests verify numerical equivalence with
monolithic training.
"""
from __future__ import annotations

import queue
import threading
import traceback
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence

import jax
from jax.sharding import Mesh

from repro.core.graph import SectionGraph
from repro.core.messages import MessageQueue


def carve_sections(graph: SectionGraph, devices: Optional[Sequence] = None,
                   *, gpu_counts: Optional[Dict[str, int]] = None):
    """Partition the device list into per-section meshes.

    Every section mesh follows the ``repro.dist.sharding`` axis-naming
    contract — ``ParallelConfig(dp, tp, pp, cp)`` maps 1:1 onto
    ``(data, pipe, seq, model)`` axes — so the sharding rules, the CP
    attention and the PP loss all address section meshes identically.

    gpu_counts overrides section.parallel.devices (e.g. from the planner);
    the extra/fewer devices widen/narrow the TP axis.

    Returns ``(meshes, parallels)``: the *effective* ParallelConfig per
    section (TP widened/narrowed by gpu_counts) rides along so step
    builders can validate pp/cp against the mesh they were carved with
    (``repro.train.step.parallel_regime``) instead of re-deriving it."""
    from repro.dist.sharding import section_mesh

    devices = list(devices if devices is not None else jax.devices())
    meshes: Dict[str, Mesh] = {}
    parallels: Dict[str, Any] = {}
    off = 0
    for name, sec in graph.sections.items():
        par = sec.parallel
        n = (gpu_counts or {}).get(name, par.devices)
        if off + n > len(devices):
            raise ValueError(
                f"section {name!r}: needs devices [{off}, {off + n}) but "
                f"only {len(devices)} are available — shrink a section's "
                "ParallelConfig or provide more devices")
        base = par.dp * par.pp * par.cp
        if n % base:
            raise ValueError(
                f"section {name!r}: {n} devices do not factor into "
                f"dp×pp×cp={par.dp}×{par.pp}×{par.cp} (tp must be "
                f"integral)")
        if n != par.devices:
            par = par.replace(tp=n // base)
        meshes[name] = section_mesh(devices[off:off + n], par, name)
        parallels[name] = par
        off += n
    return meshes, parallels


def carve_meshes(graph: SectionGraph, devices: Optional[Sequence] = None,
                 *, gpu_counts: Optional[Dict[str, int]] = None
                 ) -> Dict[str, Mesh]:
    """Mesh-only view of :func:`carve_sections` (kept for callers that
    don't need the effective ParallelConfigs)."""
    return carve_sections(graph, devices, gpu_counts=gpu_counts)[0]


@dataclass
class Task:
    tag: str
    fn: Callable
    args: tuple
    #: optional result route: when set, ``sink((tag, out_or_TaskError))``
    #: is called on the worker thread instead of ``results.put`` — the
    #: streaming executor uses this to route every result to the
    #: iteration that submitted it, so results can never satisfy (or
    #: poison) another iteration's drain
    sink: Optional[Callable] = None


@dataclass(frozen=True)
class TaskError:
    """Failure of ONE task: rides the result queue in that task's slot so a
    single bad task no longer poisons every later ``drain`` with a stale
    traceback."""
    tag: str
    traceback: str


class SectionWorker:
    """One worker thread per section; executes tasks FIFO.

    A failing task produces a :class:`TaskError` *result* (attached to the
    failing tag); subsequent tasks keep executing and draining normally."""

    def __init__(self, name: str):
        self.name = name
        self.inbox: "queue.Queue[Optional[Task]]" = queue.Queue()
        self.results: "queue.Queue" = queue.Queue()
        self.error: Optional[str] = None        # last failure (diagnostics)
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"section-{name}")
        self._thread.start()

    def _run(self):
        # mark this thread as the section's one launching thread, so the
        # affinity analysis pass can attribute dispatch execution
        # precisely (repro.analysis.affinity.check_trace)
        from repro.analysis.affinity import worker_section
        worker_section.name = self.name
        while True:
            task = self.inbox.get()
            if task is None:
                return
            deliver = task.sink or self.results.put
            try:
                out = task.fn(*task.args)
                deliver((task.tag, out))
            except Exception:
                tb = traceback.format_exc()
                self.error = tb
                deliver((task.tag, TaskError(task.tag, tb)))

    def submit(self, tag: str, fn: Callable, *args,
               sink: Optional[Callable] = None) -> None:
        self.inbox.put(Task(tag, fn, args, sink))

    def drain(self, n: int, timeout: float = 120.0,
              expect=None) -> Dict[str, Any]:
        """Collect ``n`` results.  With ``expect`` (a set of tags),
        results outside it are discarded instead of counted — stale
        leftovers from an earlier batch whose drain raised mid-way must
        not satisfy a later batch's count."""
        exp = None if expect is None else set(expect)
        out = {}
        while len(out) < n:
            try:
                tag, val = self.results.get(timeout=timeout)
            except queue.Empty:
                raise TimeoutError(
                    f"section {self.name}: {n - len(out)}/{n} tasks still "
                    f"outstanding after {timeout}s (got {sorted(out)})")
            if exp is not None and tag not in exp:
                continue                     # stale result; drop it
            if isinstance(val, TaskError):
                raise RuntimeError(
                    f"section {self.name} task {val.tag!r} failed:\n"
                    f"{val.traceback}")
            out[tag] = val
        return out

    def stop(self):
        self.inbox.put(None)
        self._thread.join(timeout=10)


class MaestroRuntime:
    """Wires sections, meshes, workers and the message queue together."""

    def __init__(self, graph: SectionGraph,
                 devices: Optional[Sequence] = None,
                 gpu_counts: Optional[Dict[str, int]] = None):
        graph.validate()
        self.graph = graph
        self.meshes, self.parallels = carve_sections(
            graph, devices, gpu_counts=gpu_counts)
        self.queue = MessageQueue()
        self.workers = {name: SectionWorker(name) for name in graph.sections}

    def mesh(self, section: str) -> Mesh:
        return self.meshes[section]

    def parallel(self, section: str):
        """Effective ParallelConfig of the carved section (TP widened by
        gpu_counts when the planner handed it extra devices)."""
        return self.parallels[section]

    def build_train_step(self, section: str, model, shape, **kw):
        """Train-step builder bound to this section's carved mesh and
        effective C^s — the runtime executes exactly the step the dry-run
        lowers, pp/cp dispatch included."""
        from repro.train import step as step_mod
        return step_mod.build_train_step(model, self.meshes[section],
                                         self.parallels[section], shape,
                                         **kw)

    def executor(self):
        """A :class:`repro.core.executor.CompoundExecutor` over this
        runtime's workers and message queue (lazy import: executor builds
        on runtime, not the other way around)."""
        from repro.core.executor import CompoundExecutor
        return CompoundExecutor(graph=self.graph, runtime=self)

    def shutdown(self):
        for w in self.workers.values():
            w.stop()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False
