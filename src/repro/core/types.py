"""Core configuration types shared across the framework.

``ArchConfig`` describes a model architecture (one of the 10 assigned archs or a
compound-workload component).  ``ShapeConfig`` describes an (input-shape) cell.
``ParallelConfig`` is the per-section training configuration C^s from the paper:
{DP, TP, PP, CP, mbs, fanout}.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | vlm | audio | hybrid | vit
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 = full attention
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # --- hybrid (jamba): attention every `attn_period` layers at `attn_offset`,
    #     MoE every `moe_period` layers at `moe_offset` ---
    attn_period: int = 0
    attn_offset: int = 0
    moe_period: int = 0
    moe_offset: int = 1
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    frontend_frames: int = 0         # stubbed modality frontend: #frames
    frontend_dim: int = 0            # stubbed modality frontend: embed dim
    # --- VLM (pixtral-style; frontend stubbed per assignment) ---
    vision_dim: int = 0              # patch-embedding dim delivered by the stub
    max_image_tokens: int = 0        # static per-batch image-token capacity
    # --- numerics / layer flavor ---
    dtype: str = "bfloat16"
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    mlp_act: str = "swiglu"          # swiglu | gelu
    norm_type: str = "rms"           # rms | ln
    # --- physical layout (numerics-neutral) ---
    # activation-level Q-head padding: zero heads appended per KV group so
    # (num_heads + head_pad) divides the TP axis; padded heads are sliced
    # off before the output projection — exact same math, sharded compute.
    head_pad: int = 0
    # physical vocab padding: embed/unembed rows appended so the vocab dim
    # divides the TP axis; padded logits are masked to −inf before any
    # softmax/lse, so loss and grads are exactly those of the unpadded
    # model (padded embed rows receive zero gradient).
    vocab_pad: int = 0

    @property
    def padded_vocab(self) -> int:
        return self.vocab_size + self.vocab_pad

    # ------------------------------------------------------------------ #
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch supports 500K-token decode without a full KV cache."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_period:
            return i % self.attn_period == self.attn_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        if not self.is_moe:
            return False
        if self.moe_period:
            return i % self.moe_period == self.moe_offset
        return True

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # --- analytic parameter counts (used by cost model / roofline) ------- #
    def attn_params(self) -> int:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.hd
        p = d * h * hd + 2 * d * kv * hd + h * hd * d
        if self.qkv_bias:
            p += (h + 2 * kv) * hd
        return p

    def mlp_params(self) -> int:
        if self.mlp_act == "gelu":
            return 2 * self.d_model * self.d_ff
        return 3 * self.d_model * self.d_ff          # SwiGLU

    def moe_params(self) -> int:
        return self.num_experts * 3 * self.d_model * self.d_ff \
            + self.d_model * self.num_experts

    def mamba_params(self) -> int:
        d_in = self.ssm_expand * self.d_model
        nheads = d_in // self.ssm_headdim
        proj_in = self.d_model * (2 * d_in + 2 * self.ssm_state + nheads)
        conv = (d_in + 2 * self.ssm_state) * self.ssm_conv
        return proj_in + conv + 3 * nheads + d_in * self.d_model

    def layer_params(self, i: int) -> int:
        p = 2 * self.d_model                          # norms
        if self.family == "ssm":
            return p + self.mamba_params()
        if self.is_attn_layer(i):
            p += self.attn_params()
        else:
            p += self.mamba_params()
        if self.is_moe_layer(i):
            p += self.moe_params()
        elif self.d_ff > 0:
            p += self.mlp_params()
        return p

    def total_params(self) -> int:
        body = sum(self.layer_params(i) for i in range(self.num_layers))
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        enc = 0
        if self.encoder_layers:
            enc = self.encoder_layers * (2 * self.d_model + self.attn_params()
                                         + self.mlp_params())
            enc += self.frontend_dim * self.d_model
        vlm = self.vision_dim * self.d_model if self.vision_dim else 0
        return body + emb + head + enc + vlm + self.d_model

    def active_params(self) -> int:
        """Active (per-token) params for MoE archs — used for MODEL_FLOPS."""
        if not self.is_moe:
            return self.total_params()
        dense = self.total_params() - sum(
            self.moe_params() for i in range(self.num_layers)
            if self.is_moe_layer(i))
        active_moe = sum(
            self.experts_per_token * 3 * self.d_model * self.d_ff
            + self.d_model * self.num_experts
            for i in range(self.num_layers) if self.is_moe_layer(i))
        return dense + active_moe


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str              # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclass(frozen=True)
class ParallelConfig:
    """Per-section training configuration C^s (paper §3.2)."""
    dp: int = 1
    tp: int = 1
    pp: int = 1
    cp: int = 1
    mbs: int = 1            # micro-batch size per DP shard
    fanout: int = 1         # DP^producer * fanout = DP^consumer  (paper eq. 1)
    remat: bool = True
    zero_opt: bool = True   # shard optimizer state over the data axis
    sequence_parallel: bool = False
    grad_compress: str = "none"   # DP grad all-reduce wire format:
    #   "none" | "bf16" | "int8" (int8 adds error feedback) — see
    #   repro.optim.compression; consumed by the plain-regime train step
    #   and by CompoundRuntime's per-section update dispatch
    # --- CP attention (repro.dist.context; active when cp > 1) ---
    cp_impl: str = "auto"         # kernel tier inside the CP shard:
    #   "auto" | "pallas" | "pallas_interpret" | "ref"
    cp_mode: str = "auto"         # "auto" | "ulysses" | "ulysses_mqa" |
    #   "allgather" — auto picks ulysses when heads divide, else the
    #   comm-model-cheaper of ulysses_mqa / allgather
    cp_overlap_chunks: int = 1    # >1: issue per-chunk K/V a2as under
    #   ulysses and merge partial flash outputs (exact); must divide S/cp

    @property
    def devices(self) -> int:
        return self.dp * self.tp * self.pp * self.cp

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class SectionConfig:
    """A section: a logically independent component with its own C^s."""
    name: str
    arch: ArchConfig
    parallel: ParallelConfig
    trainable: bool = True           # False => forward-only (frozen teacher)
    critical: bool = False           # the critical section (paper §3.2)
    seq_scale: float = 1.0           # e.g. ViT sees 4× the visual tokens
    #                                  the LM consumes (pre-downsampling)

    def replace(self, **kw) -> "SectionConfig":
        return dataclasses.replace(self, **kw)


# TPU v5e hardware constants used throughout roofline/cost analysis.
@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12       # FLOP/s per chip
    hbm_bandwidth: float = 819e9          # bytes/s per chip
    ici_bandwidth: float = 50e9           # bytes/s per link
    hbm_bytes: int = 16 * 2**30           # 16 GiB per chip
    vmem_bytes: int = 128 * 2**20


V5E = HardwareSpec()
