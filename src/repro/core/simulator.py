"""Event-driven timeline simulator for section execution (paper §3.4).

Each sample is the paper's 6-tuple
``(t_f_bc, t_f_c, t_f_ac, t_b_bc, t_b_c, t_b_ac)`` — execution time
before/within/after the *critical section*, forward and backward.

Resource mapping (VLM example: BC = ViT, C = LLM):

* ``bc`` resource executes  f_bc  (e.g. ViT fwd)  and  b_ac (ViT bwd)
* ``c``  resource executes  f_c  and  b_c          (critical section)
* ``ac`` resource executes  f_ac and  b_bc         (post-critical modules)

Per-sample dependency chain: f_bc → f_c → f_ac → b_bc → b_c → b_ac.
Execution policy: when a resource frees up it picks the *ready* task whose
(schedule position, phase) is smallest — greedy ready-first list scheduling,
which is what lets the critical section skip past samples whose upstream
work hasn't finished (the paper's no-stall property).

Zero-duration phases complete instantly and occupy no resource.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple


class Sample(NamedTuple):
    idx: int
    t_f_bc: float
    t_f_c: float
    t_f_ac: float
    t_b_bc: float
    t_b_c: float
    t_b_ac: float

    @property
    def tuple6(self):
        return (self.t_f_bc, self.t_f_c, self.t_f_ac,
                self.t_b_bc, self.t_b_c, self.t_b_ac)


PHASES = ("f_bc", "f_c", "f_ac", "b_bc", "b_c", "b_ac")
PHASE_RESOURCE = ("bc", "c", "ac", "ac", "c", "bc")


@dataclass
class SimResult:
    makespan: float
    critical_busy: float
    critical_idle: float          # idle inside the critical section's span
    resource_busy: Dict[str, float]
    timeline: Optional[List[Tuple[str, int, str, float, float]]] = None
    # timeline entries: (resource, sample_idx, phase, start, end)

    @property
    def critical_utilization(self) -> float:
        span = self.critical_busy + self.critical_idle
        return self.critical_busy / span if span > 0 else 1.0


def simulate(samples: Sequence[Sample], *, collect_timeline: bool = False,
             bc_concurrency: int = 1) -> SimResult:
    """Simulate one DP rank's schedule (sample order = schedule order).

    bc_concurrency: number of parallel executors for the bc resource
    (used when a producer section serves this rank exclusively)."""
    n = len(samples)
    durations = [s.tuple6 for s in samples]
    done_t = [[None] * 6 for _ in range(n)]          # completion times
    next_phase = [0] * n
    res_free = {"bc": [0.0] * bc_concurrency, "c": [0.0], "ac": [0.0]}
    busy = {"bc": 0.0, "c": 0.0, "ac": 0.0}
    c_start, c_end = math.inf, 0.0
    timeline: List[Tuple[str, int, str, float, float]] = []

    # ready time of sample i's phase p = completion of phase p-1 (or 0)
    def ready_time(i: int, p: int) -> float:
        return 0.0 if p == 0 else done_t[i][p - 1]

    # fast path: resolve all leading zero-duration phases
    def resolve_zeros(i: int):
        p = next_phase[i]
        while p < 6 and durations[i][p] == 0.0:
            done_t[i][p] = ready_time(i, p)
            p += 1
        next_phase[i] = p

    for i in range(n):
        resolve_zeros(i)

    remaining = sum(1 for i in range(n) if next_phase[i] < 6)
    while remaining:
        progressed = False
        # find, per resource, the smallest-(pos, phase) ready task
        for rname, frees in res_free.items():
            slot = min(range(len(frees)), key=lambda k: frees[k])
            t_free = frees[slot]
            best = None
            for i in range(n):
                p = next_phase[i]
                if p >= 6 or PHASE_RESOURCE[p] != rname:
                    continue
                rt = ready_time(i, p)
                key = (max(rt, t_free), i, p)
                if best is None or key < best[0:1] + best[1:3]:
                    best = (key[0], i, p, rt)
            if best is None:
                continue
            start, i, p, rt = best
            dur = durations[i][p]
            end = start + dur
            frees[slot] = end
            busy[rname] += dur
            done_t[i][p] = end
            next_phase[i] = p + 1
            resolve_zeros(i)
            if next_phase[i] >= 6:
                remaining -= 1
            if rname == "c":
                c_start = min(c_start, start)
                c_end = max(c_end, end)
            if collect_timeline:
                timeline.append((rname, samples[i].idx, PHASES[p], start,
                                 end))
            progressed = True
        if not progressed:      # pragma: no cover — deadlock guard
            raise RuntimeError("simulator made no progress")

    makespan = max((done_t[i][5] for i in range(n)), default=0.0)
    c_span_idle = (c_end - c_start - busy["c"]) if c_end > c_start else 0.0
    return SimResult(makespan, busy["c"], max(c_span_idle, 0.0), busy,
                     timeline if collect_timeline else None)


def makespan_of(samples: Sequence[Sample]) -> float:
    return simulate(samples).makespan


# --------------------------------------------------------------------------- #
# System-level: one producer (bc) section shared by `fanout` consumer ranks
# --------------------------------------------------------------------------- #
def simulate_fanout(per_rank: Sequence[Sequence[Sample]], *,
                    collect_timeline: bool = False) -> SimResult:
    """Simulate `fanout` consumer DP ranks sharing ONE bc producer rank.

    The bc resource serves all ranks' f_bc / b_ac tasks (round-robin merged
    by schedule position); each consumer rank has its own c and ac
    resources.  Returns the aggregate (max-makespan) result with critical
    stats summed over consumer ranks.
    """
    fanout = len(per_rank)
    tagged: List[Tuple[int, int, Sample]] = []   # (rank, pos, sample)
    for r, sched in enumerate(per_rank):
        for pos, s in enumerate(sched):
            tagged.append((r, pos, s))

    durations = {(r, p): per_rank[r][p].tuple6
                 for r, p, _ in tagged}
    done_t = {(r, p): [None] * 6 for r, p, _ in tagged}
    next_phase = {(r, p): 0 for r, p, _ in tagged}
    res_free: Dict[str, float] = {"bc": 0.0}
    for r in range(fanout):
        res_free[f"c{r}"] = 0.0
        res_free[f"ac{r}"] = 0.0
    busy = {k: 0.0 for k in res_free}
    c_bounds = {r: [math.inf, 0.0] for r in range(fanout)}
    timeline = []

    def resource_of(rank: int, phase: int) -> str:
        base = PHASE_RESOURCE[phase]
        return "bc" if base == "bc" else f"{base}{rank}"

    def ready_time(key, p):
        return 0.0 if p == 0 else done_t[key][p - 1]

    def resolve_zeros(key):
        p = next_phase[key]
        while p < 6 and durations[key][p] == 0.0:
            done_t[key][p] = ready_time(key, p)
            p += 1
        next_phase[key] = p

    for key in list(next_phase):
        resolve_zeros(key)
    remaining = sum(1 for k in next_phase if next_phase[k] < 6)

    while remaining:
        progressed = False
        for rname in res_free:
            t_free = res_free[rname]
            best = None
            for (r, pos), _ in ((k, None) for k in next_phase):
                p = next_phase[(r, pos)]
                if p >= 6 or resource_of(r, p) != rname:
                    continue
                rt = ready_time((r, pos), p)
                # merged round-robin priority for the shared bc resource
                key = (max(rt, t_free), pos, r, p)
                if best is None or key < best[0]:
                    best = (key, (r, pos), p)
            if best is None:
                continue
            (start, _, _, _), key, p = best
            dur = durations[key][p]
            end = start + dur
            res_free[rname] = end
            busy[rname] += dur
            done_t[key][p] = end
            next_phase[key] = p + 1
            resolve_zeros(key)
            if next_phase[key] >= 6:
                remaining -= 1
            if rname.startswith("c"):
                r = int(rname[1:])
                c_bounds[r][0] = min(c_bounds[r][0], start)
                c_bounds[r][1] = max(c_bounds[r][1], end)
            if collect_timeline:
                timeline.append((rname, per_rank[key[0]][key[1]].idx,
                                 PHASES[p], start, end))
            progressed = True
        if not progressed:      # pragma: no cover
            raise RuntimeError("simulator made no progress")

    makespan = max(done_t[k][5] for k in done_t)
    c_busy = sum(busy[f"c{r}"] for r in range(fanout))
    c_idle = sum(max(c_bounds[r][1] - c_bounds[r][0] - busy[f"c{r}"], 0.0)
                 for r in range(fanout) if c_bounds[r][1] > c_bounds[r][0])
    return SimResult(makespan, c_busy, c_idle, busy,
                     timeline if collect_timeline else None)
