"""Compound executor: runs a :class:`SectionGraph` end to end (§3.3–§3.4).

This is the layer that turns the wavefront scheduler from a planning
artifact into the thing that *executes* training:

* each section runs its compiled steps on its own carved mesh from its
  :class:`~repro.core.runtime.SectionWorker` thread;
* cross-section tensors flow through the :class:`MessageQueue` — a
  blocking ``pull`` *is* the cross-section data dependency, so dispatching
  every task up front in schedule order is deadlock-free whenever the
  per-section orders are mutually consistent (which the wavefront merge
  guarantees: it only permutes samples, never inverts an edge);
* the *dispatch order* per section comes from
  :func:`repro.core.scheduler.schedule_global_batch` (cost-model
  durations) — or FIFO when reordering is disabled — so reordering
  actually happens at runtime, not just in the simulator;
* every task's realized ``(start, end)`` wall time is recorded
  (``jax.block_until_ready`` on the result before stamping ``end``), so
  benches report *executed* makespan / section utilization rather than
  simulated ones.

Data-dependent activation is expressed by simply not emitting a dispatch:
a sample (or microbatch) that does not activate a section produces no task
for that section's worker — the dynamic path of MLLM training where
text-only samples bypass the vision section entirely.
"""
from __future__ import annotations

import queue as queue_mod
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.graph import SectionGraph
from repro.core.messages import MessageQueue
from repro.core.runtime import SectionWorker, TaskError
from repro.core.scheduler import (ScheduleResult, merge_fanout_schedules,
                                  partition_global_batch,
                                  wavefront_schedule)
from repro.core.simulator import Sample


@dataclass(frozen=True)
class Dispatch:
    """One unit of section work: ``fn`` runs on ``section``'s worker
    thread; cross-section inputs/outputs move through the MessageQueue
    inside ``fn`` (a blocking pull realizes the dependency edge)."""
    section: str
    tag: str
    fn: Callable[[], Any]


@dataclass(frozen=True)
class TimelineEvent:
    section: str
    tag: str
    start: float          # seconds since ExecutionResult.t0
    end: float


@dataclass
class ExecutionResult:
    """Realized execution of one dispatch list."""
    results: Dict[Tuple[str, str], Any]
    timeline: List[TimelineEvent]
    t0: float
    dispatch_order: Dict[str, List[str]]    # per-section submitted order

    @property
    def makespan(self) -> float:
        if not self.timeline:
            return 0.0
        return max(e.end for e in self.timeline) - min(
            e.start for e in self.timeline)

    @property
    def task_counts(self) -> Dict[str, int]:
        """Submitted tasks per section (dispatch-list view — includes
        tasks whose realized events are still being merged)."""
        return {name: len(tags)
                for name, tags in self.dispatch_order.items()}

    @property
    def completion_order(self) -> List[Tuple[str, str]]:
        return [(e.section, e.tag)
                for e in sorted(self.timeline, key=lambda e: e.end)]

    def section_events(self, section: str) -> List[TimelineEvent]:
        return [e for e in self.timeline if e.section == section]

    def busy(self, section: str) -> float:
        return sum(e.end - e.start for e in self.section_events(section))

    def utilization(self, section: str) -> float:
        """Busy fraction of the section's realized span (first start →
        last end) — the executed analogue of SimResult.critical_utilization
        (idle inside the span = stalls the scheduler failed to hide)."""
        ev = self.section_events(section)
        if not ev:
            return 1.0
        span = max(e.end for e in ev) - min(e.start for e in ev)
        return self.busy(section) / span if span > 0 else 1.0


def _block(value):
    """Force async dispatch to finish so task end-times are realized.
    A failure surfacing here (async XLA error materializing at block
    time) must propagate — the worker attaches it to THIS task instead
    of silently returning a poisoned result."""
    try:
        import jax
    except ImportError:                     # pragma: no cover
        return value
    jax.block_until_ready(value)
    return value


_task_local = threading.local()


def mark_start():
    """Re-stamp the current task's realized start time.

    Call right after a blocking dependency wait (a MessageQueue pull) so
    the stall is recorded as section *idle* rather than busy — without
    this, a consumer that waits inside its task window reads ~100%
    utilization no matter how badly the schedule stalls it."""
    slot = getattr(_task_local, "slot", None)
    if slot is not None:
        slot["start"] = time.perf_counter()


class CompoundExecutor:
    """Generic section-graph executor over workers + message queue.

    Construct from a :class:`~repro.core.runtime.MaestroRuntime` (shares
    its workers/queue/meshes) or standalone from section names (tests /
    host-side orchestration without carved meshes)."""

    def __init__(self, graph: Optional[SectionGraph] = None, *,
                 runtime=None, sections: Optional[Sequence[str]] = None,
                 queue: Optional[MessageQueue] = None):
        self.graph = graph if graph is not None else (
            runtime.graph if runtime is not None else None)
        if runtime is not None:
            self.workers = runtime.workers
            self.queue = runtime.queue
            self._owns_workers = False
        else:
            names = list(sections if sections is not None
                         else self.graph.sections)
            self.workers = {n: SectionWorker(n) for n in names}
            self.queue = queue if queue is not None else MessageQueue()
            self._owns_workers = True
        self._run_seq = 0

    # ------------------------------------------------------------------ #
    def run(self, dispatches: Sequence[Dispatch], *,
            timeout: float = 300.0) -> ExecutionResult:
        """Execute the dispatch list: per-section FIFO in list order,
        sections concurrent, dependencies resolved by blocking queue
        pulls inside the dispatch fns.  Returns the realized execution.
        """
        per_section: Dict[str, List[Dispatch]] = {}
        for d in dispatches:
            assert d.section in self.workers, d.section
            per_section.setdefault(d.section, []).append(d)
        for name, lst in per_section.items():
            tags = [d.tag for d in lst]
            assert len(set(tags)) == len(tags), \
                f"duplicate dispatch tags for section {name}: {tags}"
        timeline: List[TimelineEvent] = []
        tl_lock = threading.Lock()
        t0 = time.perf_counter()
        # run-scoped tag namespace: if a previous run's drain raised
        # mid-batch, its leftover results must not be mistaken for this
        # run's (drain discards tags outside `expect`)
        self._run_seq += 1
        pre = f"r{self._run_seq}:"

        def wrap(d: Dispatch):
            def timed():
                slot = {"start": time.perf_counter()}
                _task_local.slot = slot
                try:
                    out = _block(d.fn())
                finally:
                    _task_local.slot = None
                end = time.perf_counter() - t0
                with tl_lock:
                    timeline.append(TimelineEvent(
                        d.section, d.tag, slot["start"] - t0, end))
                return out
            return timed

        for name, lst in per_section.items():
            for d in lst:
                self.workers[name].submit(pre + d.tag, wrap(d))
        # drain ALL sections concurrently (round-robin poll): a failure
        # in any section must surface as that task's traceback, not as a
        # timeout of some other section blocked on the dead dependency
        expected = {name: {pre + d.tag for d in lst}
                    for name, lst in per_section.items()}
        outstanding = {name: set(tags) for name, tags in expected.items()}
        results: Dict[Tuple[str, str], Any] = {}
        end_time = time.monotonic() + timeout
        while any(outstanding.values()):
            progressed = False
            for name, exp in outstanding.items():
                w = self.workers[name]
                while True:
                    try:
                        tag, val = w.results.get_nowait()
                    except queue_mod.Empty:
                        break
                    if tag not in expected[name]:
                        continue              # stale result; drop it
                    if isinstance(val, TaskError):
                        raise RuntimeError(
                            f"section {name} task "
                            f"{val.tag[len(pre):]!r} failed:\n"
                            f"{val.traceback}")
                    results[(name, tag[len(pre):])] = val
                    exp.discard(tag)
                    progressed = True
            if not any(outstanding.values()):
                break
            if time.monotonic() > end_time:
                left = {n: sorted(t[len(pre):] for t in e)
                        for n, e in outstanding.items() if e}
                raise TimeoutError(
                    f"executor: tasks still outstanding after "
                    f"{timeout}s: {left}")
            if not progressed:
                time.sleep(0.002)
        timeline.sort(key=lambda e: (e.start, e.end))
        return ExecutionResult(
            results, timeline, t0,
            {n: [d.tag for d in lst] for n, lst in per_section.items()})

    def shutdown(self):
        if self._owns_workers:
            for w in self.workers.values():
                w.stop()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False


# --------------------------------------------------------------------------- #
# Schedule-driven dispatch order (Algorithm 1 at execution time)
# --------------------------------------------------------------------------- #
def order_samples(samples: Sequence[Sample], *, reorder: bool = True
                  ) -> Tuple[List[int], Optional[ScheduleResult]]:
    """The per-iteration sample dispatch order: wavefront (Algorithm 1 on
    cost-model 6-tuples) when ``reorder``, else FIFO.  Returns the
    permutation (original sample indices in dispatch order) and the
    ScheduleResult (None for FIFO)."""
    if not reorder:
        return list(range(len(samples))), None
    res = wavefront_schedule(samples)
    return [s.idx for s in res.order], res


def order_global_batch(samples: Sequence[Sample], dp: int, *,
                       reorder: bool = True
                       ) -> Tuple[List[List[int]], List[Tuple[int, int]]]:
    """DP>1 composition: partition the global batch over ``dp`` consumer
    ranks balancing activated-section load, Algorithm 1 per rank, fanout
    merge for the shared producer.  Returns (per-rank sample orders, the
    producer's merged ``(rank, sample_idx)`` order)."""
    if not reorder:
        n = len(samples)
        assert n % dp == 0, (n, dp)
        per = n // dp
        ranks = [list(range(r * per, (r + 1) * per)) for r in range(dp)]
        merged = merge_fanout_schedules(
            [[samples[i] for i in rank] for rank in ranks])
        return ranks, [(r, s.idx) for r, s in merged]
    parts = partition_global_batch(samples, dp)
    scheduled = [wavefront_schedule(p).order for p in parts]
    merged = merge_fanout_schedules(scheduled)
    return ([[s.idx for s in sched] for sched in scheduled],
            [(r, s.idx) for r, s in merged])


def chunk_microbatches(order: Sequence[int], mbs: int) -> List[List[int]]:
    """Contiguous microbatches of the dispatch order (the executed
    analogue of the shard-major microbatch layout: reordering decides
    *which samples share a microbatch*)."""
    assert len(order) % mbs == 0, (len(order), mbs)
    return [list(order[i:i + mbs]) for i in range(0, len(order), mbs)]
