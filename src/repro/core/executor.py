"""Compound executor: runs a :class:`SectionGraph` end to end (§3.3–§3.4).

This is the layer that turns the wavefront scheduler from a planning
artifact into the thing that *executes* training:

* each section runs its compiled steps on its own carved mesh from its
  :class:`~repro.core.runtime.SectionWorker` thread;
* cross-section tensors flow through the :class:`MessageQueue` — a
  blocking ``pull`` *is* the cross-section data dependency, so dispatching
  every task up front in schedule order is deadlock-free whenever the
  per-section orders are mutually consistent (which the wavefront merge
  guarantees: it only permutes samples, never inverts an edge);
* the *dispatch order* per section comes from
  :func:`repro.core.scheduler.schedule_global_batch` (cost-model
  durations) — or FIFO when reordering is disabled — so reordering
  actually happens at runtime, not just in the simulator;
* every task's realized ``(start, end)`` wall time is recorded
  (``jax.block_until_ready`` on the result before stamping ``end``), so
  benches report *executed* makespan / section utilization rather than
  simulated ones.

Data-dependent activation is expressed by simply not emitting a dispatch:
a sample (or microbatch) that does not activate a section produces no task
for that section's worker — the dynamic path of MLLM training where
text-only samples bypass the vision section entirely.

Cross-iteration streaming (:class:`StreamSession`): dispatches carry an
iteration index, workers consume one continuous per-section FIFO stream
spanning iterations, and results drain asynchronously (event-driven, no
polling) into per-iteration :class:`ExecutionResult`s — iteration ``i+1``'s
tasks for a section may start the moment that section's own ``i`` tasks
finish, without waiting for the other sections' tails.
"""
from __future__ import annotations

import logging
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis.affinity import record as _affinity_record
from repro.core.graph import SectionGraph
from repro.core.messages import MessageQueue
from repro.core.runtime import SectionWorker, TaskError
from repro.core.scheduler import (ScheduleResult, merge_fanout_schedules,
                                  partition_global_batch,
                                  wavefront_schedule)
from repro.core.simulator import Sample

_log = logging.getLogger("repro.executor")


@dataclass(frozen=True)
class Dispatch:
    """One unit of section work: ``fn`` runs on ``section``'s worker
    thread; cross-section inputs/outputs move through the MessageQueue
    inside ``fn`` (a blocking pull realizes the dependency edge)."""
    section: str
    tag: str
    fn: Callable[[], Any]


@dataclass(frozen=True)
class TimelineEvent:
    section: str
    tag: str
    start: float          # seconds since ExecutionResult.t0
    end: float


@dataclass
class ExecutionResult:
    """Realized execution of one dispatch list."""
    results: Dict[Tuple[str, str], Any]
    timeline: List[TimelineEvent]
    t0: float
    dispatch_order: Dict[str, List[str]]    # per-section submitted order

    @property
    def makespan(self) -> float:
        if not self.timeline:
            return 0.0
        return max(e.end for e in self.timeline) - min(
            e.start for e in self.timeline)

    @property
    def task_counts(self) -> Dict[str, int]:
        """Submitted tasks per section (dispatch-list view — includes
        tasks whose realized events are still being merged)."""
        return {name: len(tags)
                for name, tags in self.dispatch_order.items()}

    @property
    def completion_order(self) -> List[Tuple[str, str]]:
        return [(e.section, e.tag)
                for e in sorted(self.timeline, key=lambda e: e.end)]

    def section_events(self, section: str) -> List[TimelineEvent]:
        return [e for e in self.timeline if e.section == section]

    def busy(self, section: str) -> float:
        return sum(e.end - e.start for e in self.section_events(section))

    def utilization(self, section: str) -> float:
        """Busy fraction of the section's realized span (first start →
        last end) — the executed analogue of SimResult.critical_utilization
        (idle inside the span = stalls the scheduler failed to hide)."""
        ev = self.section_events(section)
        if not ev:
            return 1.0
        span = max(e.end for e in ev) - min(e.start for e in ev)
        return self.busy(section) / span if span > 0 else 1.0


def _block(value):
    """Force async dispatch to finish so task end-times are realized.
    A failure surfacing here (async XLA error materializing at block
    time) must propagate — the worker attaches it to THIS task instead
    of silently returning a poisoned result."""
    try:
        import jax
    except ImportError:                     # pragma: no cover
        return value
    jax.block_until_ready(value)
    return value


_task_local = threading.local()


def mark_start():
    """Re-stamp the current task's realized start time.

    Call right after a blocking dependency wait (a MessageQueue pull) so
    the stall is recorded as section *idle* rather than busy — without
    this, a consumer that waits inside its task window reads ~100%
    utilization no matter how badly the schedule stalls it."""
    slot = getattr(_task_local, "slot", None)
    if slot is not None:
        slot["start"] = time.perf_counter()


class _IterationState:
    """In-flight bookkeeping of one submitted iteration."""

    __slots__ = ("seq", "t0", "order", "n_expected", "done", "results",
                 "events", "error", "aborted")

    def __init__(self, seq: int, t0: float, order: Dict[str, List[str]]):
        self.seq = seq
        self.t0 = t0
        self.order = order                  # section -> tags (FIFO order)
        self.n_expected = sum(len(t) for t in order.values())
        self.done: set = set()              # completed (section, tag)
        self.results: Dict[Tuple[str, str], Any] = {}
        self.events: List[TimelineEvent] = []
        self.error: Optional[Tuple[str, str, TaskError]] = None
        self.aborted = False


class StreamSession:
    """Streaming view over an executor's workers: iteration-indexed
    submits feed one continuous per-section FIFO stream, results drain
    event-driven into per-iteration :class:`ExecutionResult`s.

    ``submit(i, dispatches)`` enqueues iteration ``i``'s tasks behind
    whatever is already streaming — per-section worker FIFO serializes a
    section's own iterations while different sections overlap freely.
    ``retire(i)`` blocks (on a condition variable, not a poll) until
    iteration ``i`` completes and returns its realized execution.  Every
    result is routed to its iteration through a per-task sink, so a
    leftover from an aborted iteration can never satisfy — or silently
    poison — another iteration's drain; a straggling :class:`TaskError`
    that lands after its iteration was already aborted is *logged*
    rather than dropped."""

    def __init__(self, executor: "CompoundExecutor"):
        self.ex = executor
        self._cv = threading.Condition()
        self._iters: Dict[int, _IterationState] = {}
        self._pending: List[int] = []       # submitted, not yet retired
        self._last_seq: Optional[int] = None

    # ------------------------------------------------------------------ #
    def submit(self, iteration: int,
               dispatches: Sequence[Dispatch]) -> None:
        """Enqueue one iteration's dispatch list onto the section
        streams (per-section FIFO in list order)."""
        per_section: Dict[str, List[Dispatch]] = {}
        for d in dispatches:
            assert d.section in self.ex.workers, d.section
            per_section.setdefault(d.section, []).append(d)
        for name, lst in per_section.items():
            tags = [d.tag for d in lst]
            assert len(set(tags)) == len(tags), \
                f"duplicate dispatch tags for section {name}: {tags}"
        with self._cv:
            assert self._last_seq is None or iteration > self._last_seq, \
                (f"iteration indices must be strictly increasing: got "
                 f"{iteration} after {self._last_seq}")
            assert iteration not in self._iters, iteration
            self._last_seq = iteration
            st = _IterationState(
                iteration, time.perf_counter(),
                {n: [d.tag for d in lst]
                 for n, lst in per_section.items()})
            self._iters[iteration] = st
            self._pending.append(iteration)
        for name, lst in per_section.items():
            w = self.ex.workers[name]
            for d in lst:
                w.submit(f"i{iteration}:{d.tag}", self._timed(st, d),
                         sink=self._sink(st, d))

    @property
    def in_flight(self) -> int:
        with self._cv:
            return len(self._pending)

    # ------------------------------------------------------------------ #
    @staticmethod
    def _timed(st: _IterationState, d: Dispatch):
        def timed():
            _task_local.slot = {"start": time.perf_counter()}
            _affinity_record(d.section)
            return _block(d.fn())
        return timed

    def _sink(self, st: _IterationState, d: Dispatch):
        def deliver(item):
            _tag, out = item
            end = time.perf_counter()
            slot = getattr(_task_local, "slot", None) or {"start": end}
            _task_local.slot = None
            with self._cv:
                st.events.append(TimelineEvent(
                    d.section, d.tag, slot["start"] - st.t0,
                    end - st.t0))
                st.results[(d.section, d.tag)] = out
                st.done.add((d.section, d.tag))
                if isinstance(out, TaskError):
                    if st.aborted:
                        # satellite fix: a poisoned task completing after
                        # its iteration already aborted used to vanish
                        # without a trace
                        _log.warning(
                            "stale TaskError after iteration %d aborted: "
                            "section %s task %r failed:\n%s", st.seq,
                            d.section, d.tag, out.traceback)
                    elif st.error is None:
                        st.error = (d.section, d.tag, out)
                self._cv.notify_all()
        return deliver

    # ------------------------------------------------------------------ #
    def retire(self, iteration: Optional[int] = None, *,
               timeout: float = 300.0) -> ExecutionResult:
        """Wait (event-driven) for one iteration to complete and return
        its realized execution.  Defaults to the oldest in flight.  A
        failed task raises that task's traceback immediately — without
        waiting for the rest of the iteration."""
        with self._cv:
            if iteration is None:
                if not self._pending:
                    raise RuntimeError(
                        "stream session: no iteration in flight")
                iteration = self._pending[0]
            st = self._iters.get(iteration)
            if st is None:
                raise KeyError(
                    f"iteration {iteration} is not in flight")
            deadline = time.monotonic() + timeout
            while True:
                if st.error is not None:
                    st.aborted = True
                    self._pending.remove(iteration)
                    del self._iters[iteration]
                    name, tag, err = st.error
                    raise RuntimeError(
                        f"section {name} task {tag!r} failed:\n"
                        f"{err.traceback}")
                if len(st.done) == st.n_expected:
                    self._pending.remove(iteration)
                    del self._iters[iteration]
                    break
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not self._cv.wait(timeout=remaining):
                    left: Dict[str, List[str]] = {}
                    for name, tags in st.order.items():
                        open_tags = [t for t in tags
                                     if (name, t) not in st.done]
                        if open_tags:
                            left[name] = open_tags
                    raise TimeoutError(
                        f"executor: tasks still outstanding after "
                        f"{timeout}s: {left}")
        events = sorted(st.events, key=lambda e: (e.start, e.end))
        return ExecutionResult(dict(st.results), events, st.t0,
                               dict(st.order))


class CompoundExecutor:
    """Generic section-graph executor over workers + message queue.

    Construct from a :class:`~repro.core.runtime.MaestroRuntime` (shares
    its workers/queue/meshes) or standalone from section names (tests /
    host-side orchestration without carved meshes)."""

    def __init__(self, graph: Optional[SectionGraph] = None, *,
                 runtime=None, sections: Optional[Sequence[str]] = None,
                 queue: Optional[MessageQueue] = None):
        self.graph = graph if graph is not None else (
            runtime.graph if runtime is not None else None)
        if runtime is not None:
            self.workers = runtime.workers
            self.queue = runtime.queue
            self._owns_workers = False
        else:
            names = list(sections if sections is not None
                         else self.graph.sections)
            self.workers = {n: SectionWorker(n) for n in names}
            self.queue = queue if queue is not None else MessageQueue()
            self._owns_workers = True

    def session(self) -> StreamSession:
        """A new cross-iteration streaming session over this executor's
        workers (see :class:`StreamSession`)."""
        return StreamSession(self)

    # ------------------------------------------------------------------ #
    def run(self, dispatches: Sequence[Dispatch], *,
            timeout: float = 300.0) -> ExecutionResult:
        """Execute the dispatch list: per-section FIFO in list order,
        sections concurrent, dependencies resolved by blocking queue
        pulls inside the dispatch fns.  Returns the realized execution.

        One-shot convenience over :class:`StreamSession` (submit a single
        iteration, retire it) — sink routing guarantees a stale result
        from an earlier aborted run can never satisfy this run's drain."""
        s = StreamSession(self)
        s.submit(0, dispatches)
        return s.retire(0, timeout=timeout)

    def shutdown(self):
        if self._owns_workers:
            for w in self.workers.values():
                w.stop()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False


# --------------------------------------------------------------------------- #
# Schedule-driven dispatch order (Algorithm 1 at execution time)
# --------------------------------------------------------------------------- #
def order_samples(samples: Sequence[Sample], *, reorder: bool = True
                  ) -> Tuple[List[int], Optional[ScheduleResult]]:
    """The per-iteration sample dispatch order: wavefront (Algorithm 1 on
    cost-model 6-tuples) when ``reorder``, else FIFO.  Returns the
    permutation (original sample indices in dispatch order) and the
    ScheduleResult (None for FIFO)."""
    if not reorder:
        return list(range(len(samples))), None
    res = wavefront_schedule(samples)
    return [s.idx for s in res.order], res


def order_global_batch(samples: Sequence[Sample], dp: int, *,
                       reorder: bool = True
                       ) -> Tuple[List[List[int]], List[Tuple[int, int]]]:
    """DP>1 composition: partition the global batch over ``dp`` consumer
    ranks balancing activated-section load, Algorithm 1 per rank, fanout
    merge for the shared producer.  Returns (per-rank sample orders, the
    producer's merged ``(rank, sample_idx)`` order)."""
    if not reorder:
        n = len(samples)
        assert n % dp == 0, (n, dp)
        per = n // dp
        ranks = [list(range(r * per, (r + 1) * per)) for r in range(dp)]
        merged = merge_fanout_schedules(
            [[samples[i] for i in rank] for rank in ranks])
        return ranks, [(r, s.idx) for r, s in merged]
    parts = partition_global_batch(samples, dp)
    scheduled = [wavefront_schedule(p).order for p in parts]
    merged = merge_fanout_schedules(scheduled)
    return ([[s.idx for s in sched] for sched in scheduled],
            [(r, s.idx) for r, s in merged])


def chunk_microbatches(order: Sequence[int], mbs: int) -> List[List[int]]:
    """Contiguous microbatches of the dispatch order (the executed
    analogue of the shard-major microbatch layout: reordering decides
    *which samples share a microbatch*)."""
    assert len(order) % mbs == 0, (len(order), mbs)
    return [list(order[i:i + mbs]) for i in range(0, len(order), mbs)]
