"""Section graph construction (paper §3.1).

A :class:`SectionGraph` is a DAG of :class:`SectionConfig` nodes with
data-flow edges.  Construction rules implemented:

* one section per logically independent component (default);
* **KD output-layer colocation**: the teacher's final output layer is
  colocated with the student section, so only hidden states (d_model) cross
  the boundary instead of logits (vocab ≫ d_model) — realized by the
  ``hidden_handoff`` edge attribute + the chunked-vocab ``distill_kl``
  kernel on the student side;
* **mutually-exclusive encoder colocation**: modality encoders of similar
  size that are (almost) never active on the same sample share a section.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.types import ArchConfig, ParallelConfig, SectionConfig


@dataclass(frozen=True)
class Edge:
    src: str
    dst: str
    hidden_handoff: bool = False     # transfer hidden states, not logits
    bytes_per_token: int = 0         # cross-section traffic estimate
    fanout: int = 1                  # DP^src * fanout = DP^dst


@dataclass
class SectionGraph:
    sections: Dict[str, SectionConfig] = field(default_factory=dict)
    edges: List[Edge] = field(default_factory=list)

    def add(self, section: SectionConfig) -> "SectionGraph":
        assert section.name not in self.sections, section.name
        self.sections[section.name] = section
        return self

    def connect(self, src: str, dst: str, **kw) -> "SectionGraph":
        assert src in self.sections and dst in self.sections
        self.edges.append(Edge(src, dst, **kw))
        return self

    @property
    def critical(self) -> SectionConfig:
        crits = [s for s in self.sections.values() if s.critical]
        assert len(crits) == 1, "exactly one critical section required"
        return crits[0]

    def producers_of(self, name: str) -> List[Edge]:
        return [e for e in self.edges if e.dst == name]

    def consumers_of(self, name: str) -> List[Edge]:
        return [e for e in self.edges if e.src == name]

    def validate(self) -> None:
        names = set(self.sections)
        for e in self.edges:
            assert e.src in names and e.dst in names
        # acyclic check (Kahn)
        indeg = {n: 0 for n in names}
        for e in self.edges:
            indeg[e.dst] += 1
        order, queue = [], [n for n in names if indeg[n] == 0]
        while queue:
            n = queue.pop()
            order.append(n)
            for e in self.consumers_of(n):
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    queue.append(e.dst)
        assert len(order) == len(names), "section graph has a cycle"
        _ = self.critical


# --------------------------------------------------------------------------- #
# Construction rules
# --------------------------------------------------------------------------- #
def build_distill_graph(teacher: ArchConfig, student: ArchConfig, *,
                        fanout: int = 1,
                        teacher_parallel: Optional[ParallelConfig] = None,
                        student_parallel: Optional[ParallelConfig] = None
                        ) -> SectionGraph:
    """KD: frozen teacher (forward-only) → trainable student (critical).

    Per §3.1 the teacher's output layer is colocated with the student:
    the edge carries hidden states (d_model · bytes), not logits
    (vocab · bytes) — a vocab/d_model ≈ 62× traffic reduction for
    Qwen3.5-scale vocabularies."""
    g = SectionGraph()
    g.add(SectionConfig("teacher", teacher,
                        teacher_parallel or ParallelConfig(),
                        trainable=False))
    g.add(SectionConfig("student", student,
                        student_parallel or ParallelConfig(),
                        trainable=True, critical=True))
    g.connect("teacher", "student", hidden_handoff=True,
              bytes_per_token=teacher.d_model * 2, fanout=fanout)
    g.validate()
    return g


def build_vlm_graph(vit: ArchConfig, lm: ArchConfig, *, fanout: int = 1,
                    vit_parallel: Optional[ParallelConfig] = None,
                    lm_parallel: Optional[ParallelConfig] = None
                    ) -> SectionGraph:
    """VLM: ViT encoder section (CP-heavy, long visual-token sequences) →
    LLM backbone (critical)."""
    g = SectionGraph()
    g.add(SectionConfig("vit", vit,
                        vit_parallel or ParallelConfig(cp=2),
                        trainable=True))
    g.add(SectionConfig("llm", lm, lm_parallel or ParallelConfig(),
                        trainable=True, critical=True))
    g.connect("vit", "llm", bytes_per_token=lm.d_model * 2, fanout=fanout)
    g.validate()
    return g


def maybe_colocate_exclusive(g: SectionGraph, a: str, b: str, *,
                             coactivation_rate: float,
                             size_ratio_tol: float = 2.0,
                             rate_tol: float = 0.05) -> SectionGraph:
    """§3.1 omni-modal rule: encoders that are (almost) mutually exclusive
    and of comparable size share one section (resource-fragmentation fix).

    Returns a new graph with `a`+`b` merged when the rule applies."""
    sa, sb = g.sections[a], g.sections[b]
    ratio = max(sa.arch.total_params(), sb.arch.total_params()) / max(
        min(sa.arch.total_params(), sb.arch.total_params()), 1)
    if coactivation_rate > rate_tol or ratio > size_ratio_tol:
        return g
    merged = SectionConfig(f"{a}+{b}", sa.arch, sa.parallel,
                           trainable=sa.trainable or sb.trainable,
                           critical=sa.critical or sb.critical,
                           seq_scale=max(sa.seq_scale, sb.seq_scale))
    out = SectionGraph()
    out.add(merged)
    for name, s in g.sections.items():
        if name not in (a, b):
            out.add(s)
    for e in g.edges:
        src = merged.name if e.src in (a, b) else e.src
        dst = merged.name if e.dst in (a, b) else e.dst
        if src != dst:
            out.connect(src, dst, hidden_handoff=e.hidden_handoff,
                        bytes_per_token=e.bytes_per_token, fanout=e.fanout)
    out.validate()
    return out
