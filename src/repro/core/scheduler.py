"""Wavefront scheduling (paper §3.4, Algorithm 1).

1. Sort samples ascending by ``t_f_bc`` (earliest to reach the critical
   section first); seed the result schedule with the top sample.
2. For each remaining sample, evaluate every insertion position by
   simulating the full multi-section timeline and commit the position
   minimizing makespan (first such position on ties).

Candidate evaluation is the hot path (the naive form re-runs the full
O(N²) simulator for every one of O(N²) candidates — O(N⁴) overall, §3.4
says scheduling must overlap GPU execution).  ``wavefront_schedule``
instead evaluates candidates with :func:`_greedy_makespan`, a
semantics-identical re-implementation of ``core.simulator.simulate`` that

* keeps per-resource *pending sets* instead of rescanning every sample's
  phase per dispatch, and
* **early-aborts** a candidate once a makespan lower bound (max
  completion dispatched so far; critical-resource free time + remaining
  critical work) reaches the best makespan already found for this
  insertion.  Positions are scanned left to right, so an aborted
  candidate can never win the (min makespan, min position) selection.

Most candidates die after a handful of dispatches, bringing the effective
cost to ~O(N²) on paper-like workloads.

**Equivalence contract** (vs :func:`wavefront_schedule_reference`, the
seed O(N⁴) form kept as the oracle): the per-candidate evaluator
:func:`_greedy_makespan` reproduces ``simulate`` dispatch-for-dispatch on
*every* input (fuzz-tested).  The early abort additionally relies on
float comparisons against the incumbent makespan, which on critical-
saturated schedules are exact *ties*; when the tied quantities were
accumulated without rounding (the case for cost-model-scale durations —
all repo workloads, benches and the acceptance fixtures; property-tested
on fixed seeds in ``tests/test_scheduler_fast.py``) the schedule is
identical to the reference.  On adversarial float inputs an ulp of
accumulation drift can flip such a tie and the two algorithms may commit
different — equally scoring at decision time — insertions; the result is
still a valid Algorithm-1 schedule and never worse than FIFO.

Plus the two DP-level mechanisms from the paper:

* ``partition_global_batch`` — split the global batch across DP ranks
  balancing the distribution of activated sections (per-rank counts stay
  exactly equal — SPMD requires it).
* ``merge_fanout_schedules`` — round-robin interleave of ``fanout``
  consumer-rank schedules for the shared producer section.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core.simulator import (PHASE_RESOURCE, Sample, SimResult,
                                  simulate)


@dataclass
class ScheduleResult:
    order: List[Sample]
    makespan: float
    fifo_makespan: float
    sim: SimResult
    elapsed_s: float

    @property
    def improvement(self) -> float:
        return (self.fifo_makespan - self.makespan) / self.fifo_makespan \
            if self.fifo_makespan else 0.0


# phase → resource id (0 = bc, 1 = c, 2 = ac); mirrors simulator semantics
_RES_ID = {"bc": 0, "c": 1, "ac": 2}
_PHASE_RES = tuple(_RES_ID[r] for r in PHASE_RESOURCE)


def _greedy_makespan(durations: Sequence[Tuple[float, ...]],
                     abort_above: float = math.inf) -> Optional[float]:
    """Makespan of ``core.simulator.simulate`` for a 6-tuple list —
    identical dispatch semantics (greedy ready-first per resource in
    bc/c/ac order, ties by schedule position), restructured around
    per-resource pending sets.

    Returns None as soon as a makespan lower bound reaches
    ``abort_above`` (the candidate cannot beat an already-found schedule).
    """
    n = len(durations)
    nxt = [0] * n                     # next phase per sample
    ready = [0.0] * n                 # completion time of previous phase
    pend: List[List[int]] = [[], [], []]
    free = [0.0, 0.0, 0.0]
    maxdone = 0.0
    crit_rem = 0.0
    for d in durations:
        crit_rem += d[1] + d[4]
    remaining = n

    def park(i: int) -> bool:
        """Skip zero-duration phases; en-queue sample on its next resource.
        Returns True when the sample finished."""
        p = nxt[i]
        d = durations[i]
        while p < 6 and d[p] == 0.0:
            p += 1
        nxt[i] = p
        if p >= 6:
            return True
        pend[_PHASE_RES[p]].append(i)
        return False

    for i in range(n):
        if park(i):
            remaining -= 1

    while remaining:
        progressed = False
        for r in (0, 1, 2):
            lst = pend[r]
            if not lst:
                continue
            t_free = free[r]
            best_j = 0
            best_i = lst[0]
            best_start = ready[best_i] if ready[best_i] > t_free else t_free
            for j in range(1, len(lst)):
                i = lst[j]
                st = ready[i] if ready[i] > t_free else t_free
                if st < best_start or (st == best_start and i < best_i):
                    best_start, best_j, best_i = st, j, i
            i = best_i
            p = nxt[i]
            dur = durations[i][p]
            end = best_start + dur
            free[r] = end
            ready[i] = end
            if r == 1:
                crit_rem -= dur
            if end > maxdone:
                maxdone = end
            lst[best_j] = lst[-1]
            lst.pop()
            nxt[i] = p + 1
            if park(i):
                remaining -= 1
            progressed = True
            # maxdone is produced by the exact arithmetic the full run
            # would perform for this dispatch prefix — always a sound
            # abort.  The critical-work bound (free[1] + crit_rem) fires
            # mostly at *exact equality* with the incumbent (critical-
            # saturated schedules); that is sound whenever the critical-
            # side arithmetic is exact, which holds for the per-sample
            # cost model's duration scale — but on arbitrary float soup
            # an ulp of accumulation drift can flip such a tie, so the
            # schedule is only guaranteed identical to the reference on
            # tie-stable inputs (see module docstring).
            bound = free[1] + crit_rem
            if maxdone > bound:
                bound = maxdone
            if bound >= abort_above:
                return None
        if not progressed:      # pragma: no cover — deadlock guard
            raise RuntimeError("scheduler simulation made no progress")
    return maxdone


def wavefront_schedule(samples: Sequence[Sample]) -> ScheduleResult:
    """Algorithm 1. Returns the reordered schedule plus quality metrics.

    Produces the same schedule as :func:`wavefront_schedule_reference`
    (the straightforward O(N⁴) form) at ~O(N²) effective cost on
    tie-stable inputs — see the module docstring for the pruning
    argument and the exact equivalence contract."""
    t0 = time.perf_counter()
    if not samples:
        return ScheduleResult([], 0.0, 0.0, simulate([]), 0.0)
    fifo = _greedy_makespan([s.tuple6 for s in samples])
    initial = sorted(samples, key=lambda s: s.t_f_bc)
    result: List[Sample] = [initial[0]]
    result_t6: List[Tuple[float, ...]] = [initial[0].tuple6]
    for s in initial[1:]:
        t6 = s.tuple6
        best_pos, best_mk = 0, math.inf
        for pos in range(len(result) + 1):
            cand = result_t6[:pos] + [t6] + result_t6[pos:]
            mk = _greedy_makespan(cand, abort_above=best_mk)
            if mk is not None and mk < best_mk:
                best_mk, best_pos = mk, pos
        result.insert(best_pos, s)
        result_t6.insert(best_pos, t6)
    final = simulate(result)
    # Beyond-paper guard (found by property testing): the greedy insertion
    # is a heuristic and can end *worse* than the incoming order on
    # adversarial inputs — keep whichever schedule is better, so the
    # scheduler is never-worse-than-FIFO by construction.
    if final.makespan > fifo:
        result = list(samples)
        final = simulate(result)
    return ScheduleResult(result, final.makespan, fifo, final,
                          time.perf_counter() - t0)


def wavefront_schedule_reference(samples: Sequence[Sample]
                                 ) -> ScheduleResult:
    """The seed O(N⁴) form of Algorithm 1 — one full ``simulate`` per
    insertion candidate.  Kept as the equivalence oracle for
    ``wavefront_schedule`` (tests assert identical schedules on the
    acceptance fixtures; see the module docstring for the contract)."""
    t0 = time.perf_counter()
    fifo = simulate(samples).makespan if samples else 0.0
    if not samples:
        return ScheduleResult([], 0.0, 0.0, simulate([]), 0.0)
    initial = sorted(samples, key=lambda s: s.t_f_bc)
    result: List[Sample] = [initial[0]]
    for s in initial[1:]:
        best_pos, best_mk = 0, float("inf")
        for pos in range(len(result) + 1):
            cand = result[:pos] + [s] + result[pos:]
            mk = simulate(cand).makespan
            if mk < best_mk:
                best_mk, best_pos = mk, pos
        result.insert(best_pos, s)
    final = simulate(result)
    if final.makespan > fifo:
        result = list(samples)
        final = simulate(result)
    return ScheduleResult(result, final.makespan, fifo, final,
                          time.perf_counter() - t0)


def partition_global_batch(samples: Sequence[Sample],
                           dp: int) -> List[List[Sample]]:
    """Balance activated-section load across DP ranks with equal counts.

    Greedy LPT on the non-critical work (t_f_bc + t_b_ac + t_f_ac + t_b_bc)
    subject to the per-rank capacity |batch|/dp."""
    n = len(samples)
    assert n % dp == 0, (n, dp)
    cap = n // dp
    order = sorted(samples,
                   key=lambda s: -(s.t_f_bc + s.t_b_ac + s.t_f_ac + s.t_b_bc))
    loads = [0.0] * dp
    counts = [0] * dp
    ranks: List[List[Sample]] = [[] for _ in range(dp)]
    for s in order:
        cand = [r for r in range(dp) if counts[r] < cap]
        r = min(cand, key=lambda r: (loads[r], counts[r]))
        ranks[r].append(s)
        loads[r] += s.t_f_bc + s.t_b_ac + s.t_f_ac + s.t_b_bc
        counts[r] += 1
    return ranks


def merge_fanout_schedules(per_rank: Sequence[Sequence[Sample]]
                           ) -> List[Tuple[int, Sample]]:
    """Round-robin interleave of consumer-rank schedules → the order in
    which the shared producer section processes samples.  Returns
    (consumer_rank, sample) pairs."""
    out: List[Tuple[int, Sample]] = []
    longest = max((len(r) for r in per_rank), default=0)
    for pos in range(longest):
        for r, sched in enumerate(per_rank):
            if pos < len(sched):
                out.append((r, sched[pos]))
    return out


def schedule_global_batch(samples: Sequence[Sample], dp: int
                          ) -> Tuple[List[List[Sample]],
                                     List[Tuple[int, Sample]]]:
    """Partition → per-rank Algorithm 1 → fanout merge (paper end-to-end)."""
    ranks = partition_global_batch(samples, dp)
    scheduled = [wavefront_schedule(r).order for r in ranks]
    merged = merge_fanout_schedules(scheduled)
    return scheduled, merged
