"""Wavefront scheduling (paper §3.4, Algorithm 1).

1. Sort samples ascending by ``t_f_bc`` (earliest to reach the critical
   section first); seed the result schedule with the top sample.
2. For each remaining sample, evaluate every insertion position by
   simulating the full multi-section timeline (``core.simulator``) and
   commit the position minimizing makespan.

Plus the two DP-level mechanisms from the paper:

* ``partition_global_batch`` — split the global batch across DP ranks
  balancing the distribution of activated sections (per-rank counts stay
  exactly equal — SPMD requires it).
* ``merge_fanout_schedules`` — round-robin interleave of ``fanout``
  consumer-rank schedules for the shared producer section.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.core.simulator import Sample, SimResult, simulate


@dataclass
class ScheduleResult:
    order: List[Sample]
    makespan: float
    fifo_makespan: float
    sim: SimResult
    elapsed_s: float

    @property
    def improvement(self) -> float:
        return (self.fifo_makespan - self.makespan) / self.fifo_makespan \
            if self.fifo_makespan else 0.0


def wavefront_schedule(samples: Sequence[Sample]) -> ScheduleResult:
    """Algorithm 1. Returns the reordered schedule plus quality metrics."""
    t0 = time.perf_counter()
    fifo = simulate(samples).makespan if samples else 0.0
    if not samples:
        return ScheduleResult([], 0.0, 0.0, simulate([]), 0.0)
    initial = sorted(samples, key=lambda s: s.t_f_bc)
    result: List[Sample] = [initial[0]]
    for s in initial[1:]:
        best_pos, best_mk = 0, float("inf")
        for pos in range(len(result) + 1):
            cand = result[:pos] + [s] + result[pos:]
            mk = simulate(cand).makespan
            if mk < best_mk:
                best_mk, best_pos = mk, pos
        result.insert(best_pos, s)
    final = simulate(result)
    # Beyond-paper guard (found by property testing): the greedy insertion
    # is a heuristic and can end *worse* than the incoming order on
    # adversarial inputs — keep whichever schedule is better, so the
    # scheduler is never-worse-than-FIFO by construction.
    if final.makespan > fifo:
        result = list(samples)
        final = simulate(result)
    return ScheduleResult(result, final.makespan, fifo, final,
                          time.perf_counter() - t0)


def partition_global_batch(samples: Sequence[Sample],
                           dp: int) -> List[List[Sample]]:
    """Balance activated-section load across DP ranks with equal counts.

    Greedy LPT on the non-critical work (t_f_bc + t_b_ac + t_f_ac + t_b_bc)
    subject to the per-rank capacity |batch|/dp."""
    n = len(samples)
    assert n % dp == 0, (n, dp)
    cap = n // dp
    order = sorted(samples,
                   key=lambda s: -(s.t_f_bc + s.t_b_ac + s.t_f_ac + s.t_b_bc))
    loads = [0.0] * dp
    counts = [0] * dp
    ranks: List[List[Sample]] = [[] for _ in range(dp)]
    for s in order:
        cand = [r for r in range(dp) if counts[r] < cap]
        r = min(cand, key=lambda r: (loads[r], counts[r]))
        ranks[r].append(s)
        loads[r] += s.t_f_bc + s.t_b_ac + s.t_f_ac + s.t_b_bc
        counts[r] += 1
    return ranks


def merge_fanout_schedules(per_rank: Sequence[Sequence[Sample]]
                           ) -> List[Tuple[int, Sample]]:
    """Round-robin interleave of consumer-rank schedules → the order in
    which the shared producer section processes samples.  Returns
    (consumer_rank, sample) pairs."""
    out: List[Tuple[int, Sample]] = []
    longest = max((len(r) for r in per_rank), default=0)
    for pos in range(longest):
        for r, sched in enumerate(per_rank):
            if pos < len(sched):
                out.append((r, sched[pos]))
    return out


def schedule_global_batch(samples: Sequence[Sample], dp: int
                          ) -> Tuple[List[List[Sample]],
                                     List[Tuple[int, Sample]]]:
    """Partition → per-rank Algorithm 1 → fanout merge (paper end-to-end)."""
    ranks = partition_global_batch(samples, dp)
    scheduled = [wavefront_schedule(r).order for r in ranks]
    merged = merge_fanout_schedules(scheduled)
    return scheduled, merged
