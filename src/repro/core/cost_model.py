"""Analytic per-section cost model.

Used by (a) the two-stage planner (§3.2) to search per-section configs and
(b) the wavefront scheduler (§3.4) to build the per-sample 6-tuples.

Time model per microbatch of a section::

    t = t_overhead(C) + tokens * flops_per_token / (peak * mfu(C))

* ``t_overhead`` captures per-launch/per-microbatch fixed cost; its ratio to
  the marginal term is calibrated so a forward-only teacher gains 2.6×
  throughput from mbs 1→4 (paper Fig. 9).
* ``mfu(C)`` applies TP/CP communication penalties and the PP bubble
  (p−1)/(m+p−1).

Memory model per GPU (bytes)::

    params/(tp·pp[·dp if ZeRO])·bytes_param + opt_states + activations(mbs)

All constants are module-level and documented; tests pin the Fig. 9
calibration.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.core.types import ArchConfig, HardwareSpec, ParallelConfig, V5E

# ---- calibration constants ------------------------------------------------ #
BASE_MFU = 0.55           # well-tuned dense matmul-bound section
TP_PENALTY = 0.04         # multiplicative loss per log2(tp) step
CP_PENALTY = 0.03
FWD_OVERHEAD_RATIO = 4.57  # t_overhead / marginal-cost-per-sample (Fig. 9:
#                            mbs 1→4 ⇒ 2.6× teacher throughput)
BWD_FLOPS_MULT = 2.0      # bwd ≈ 2× fwd
BYTES_PARAM = 2           # bf16
BYTES_OPT = 12            # fp32 master + m + v
BYTES_GRAD = 4            # fp32 accumulation
ACT_BYTES_PER_TOKEN_LAYER = 2.5   # remat: ~1 residual + norm stats, bf16


def flops_per_token_fwd(cfg: ArchConfig, seq_len: int) -> float:
    """Forward FLOPs per token: 2·N_active + attention quadratic term."""
    base = 2.0 * cfg.active_params()
    attn_layers = sum(1 for i in range(cfg.num_layers) if cfg.is_attn_layer(i))
    if attn_layers and cfg.num_heads:
        eff_ctx = seq_len / 2 if not cfg.sliding_window else min(
            cfg.sliding_window, seq_len / 2)
        base += 4.0 * attn_layers * eff_ctx * cfg.num_heads * cfg.hd
    if cfg.family == "ssm" or cfg.attn_period:
        ssm_layers = sum(1 for i in range(cfg.num_layers)
                         if not cfg.is_attn_layer(i))
        d_in = cfg.ssm_expand * cfg.d_model
        base += 2.0 * ssm_layers * d_in * cfg.ssm_state * 2
    return base


SHARD_WIDTH_KNEE = 64     # per-shard hidden width where MXU efficiency halves


def mfu(parallel: ParallelConfig, *, num_microbatches: int = 1,
        forward_only: bool = False, d_model: int = 0) -> float:
    eff = BASE_MFU
    eff *= (1.0 - TP_PENALTY) ** math.log2(max(parallel.tp, 1))
    eff *= (1.0 - CP_PENALTY) ** math.log2(max(parallel.cp, 1))
    if d_model:
        # small-shard penalty: slicing a narrow model across a wide TP axis
        # starves the MXU (the paper's §2.1 uniform-config pathology for
        # the 0.4B ViT at the LLM's TP degree)
        w = d_model / max(parallel.tp, 1)
        eff *= w / (w + SHARD_WIDTH_KNEE)
    if parallel.pp > 1:
        m = max(num_microbatches, 1)
        bubble = (parallel.pp - 1) / (m + parallel.pp - 1)
        eff *= (1.0 - bubble)
    return eff


@dataclass(frozen=True)
class SectionCost:
    """Per-iteration cost of one section under a config."""
    t_fwd_sample: float          # seconds per sample, forward
    t_bwd_sample: float          # seconds per sample, backward (0 if frozen)
    mem_per_gpu: float           # bytes
    flops_fwd_sample: float


def microbatch_time(cfg: ArchConfig, parallel: ParallelConfig,
                    seq_len: int, *, forward_only: bool,
                    num_microbatches: int = 8,
                    hw: HardwareSpec = V5E) -> float:
    """Seconds for one microbatch (mbs samples) on this section's GPUs."""
    chips = parallel.tp * parallel.cp * parallel.pp
    f_tok = flops_per_token_fwd(cfg, seq_len)
    flops = f_tok * seq_len * parallel.mbs
    if not forward_only:
        flops *= (1.0 + BWD_FLOPS_MULT)
    eff = mfu(parallel, num_microbatches=num_microbatches,
              forward_only=forward_only, d_model=cfg.d_model)
    marginal = flops / (hw.peak_flops_bf16 * chips * eff)
    per_sample = marginal / max(parallel.mbs, 1)
    overhead = FWD_OVERHEAD_RATIO * per_sample * (1 if forward_only else 0.35)
    return overhead + marginal


def section_cost(cfg: ArchConfig, parallel: ParallelConfig, seq_len: int, *,
                 trainable: bool = True, num_microbatches: int = 8,
                 hw: HardwareSpec = V5E) -> SectionCost:
    t_mb_f = microbatch_time(cfg, parallel, seq_len, forward_only=True,
                             num_microbatches=num_microbatches, hw=hw)
    t_f = t_mb_f / max(parallel.mbs, 1)
    if trainable:
        t_mb_full = microbatch_time(cfg, parallel, seq_len,
                                    forward_only=False,
                                    num_microbatches=num_microbatches, hw=hw)
        t_full = t_mb_full / max(parallel.mbs, 1)
        t_b = t_full - t_f
    else:
        t_b = 0.0
    mem = memory_per_gpu(cfg, parallel, seq_len, trainable=trainable)
    return SectionCost(t_f, t_b, mem,
                       flops_per_token_fwd(cfg, seq_len) * seq_len)


def memory_per_gpu(cfg: ArchConfig, parallel: ParallelConfig, seq_len: int,
                   *, trainable: bool) -> float:
    n = cfg.total_params()
    shard = parallel.tp * parallel.pp
    zshard = shard * (parallel.dp if parallel.zero_opt else 1)
    # trainable sections use FSDP param sharding (embed dims → data axis,
    # matching dist/sharding.py) + ZeRO opt state + reduce-scattered grads;
    # frozen teachers keep params TP-sharded only (TEACHER_RULES)
    p_bytes = n * BYTES_PARAM / (zshard if trainable else shard)
    opt = 0.0
    if trainable:
        opt = n * (BYTES_OPT / zshard + BYTES_GRAD / zshard)
    act_layers = cfg.num_layers / parallel.pp
    act = (parallel.mbs * seq_len * cfg.d_model * act_layers
           * ACT_BYTES_PER_TOKEN_LAYER / (parallel.tp * parallel.cp))
    if trainable:
        act *= 2.0                   # fwd residuals + bwd workspace
    # logits workspace (fp32) for the loss
    logits = (parallel.mbs * seq_len * cfg.vocab_size * 4
              / (parallel.tp * parallel.cp)) if trainable else 0.0
    return p_bytes + opt + act + min(logits, 4e9)


def fits(cfg: ArchConfig, parallel: ParallelConfig, seq_len: int, *,
         trainable: bool, hw: HardwareSpec = V5E,
         reserve: float = 0.9) -> bool:
    return memory_per_gpu(cfg, parallel, seq_len, trainable=trainable) \
        <= hw.hbm_bytes * reserve


# --------------------------------------------------------------------------- #
# Scheduler 6-tuples (§3.4): cost-model durations per sample
# --------------------------------------------------------------------------- #
def sample_tuples(graph, activation: dict, seq_len: int, *,
                  n: Optional[int] = None, num_microbatches: int = 8,
                  hw: HardwareSpec = V5E):
    """Per-sample ``Sample`` 6-tuples for a section graph, durations from
    the analytic cost model — the executor feeds these to
    ``schedule_global_batch`` to decide the *realized* dispatch order.

    ``activation[name][i]`` — whether sample ``i`` activates section
    ``name`` (data-dependent activation; omitted sections are always
    active).  Sections upstream of the critical section contribute to the
    ``bc`` phases (fwd before / bwd after the critical section), strict
    downstream sections to ``ac``; a section's sequence length is
    ``seq_len * seq_scale``."""
    from repro.core.simulator import Sample

    if n is None:
        n = max((len(v) for v in activation.values()), default=0)
    crit = graph.critical.name
    # transitive closure: everything with a path INTO the critical
    # section runs before it (a depth-2 producer still occupies the bc
    # resource), everything else is strict-downstream
    upstream = set()
    frontier = [crit]
    while frontier:
        node = frontier.pop()
        for e in graph.producers_of(node):
            if e.src not in upstream:
                upstream.add(e.src)
                frontier.append(e.src)
    costs = {}
    for name, sec in graph.sections.items():
        costs[name] = section_cost(
            sec.arch, sec.parallel, max(int(seq_len * sec.seq_scale), 1),
            trainable=sec.trainable, num_microbatches=num_microbatches,
            hw=hw)

    def active(name: str, i: int) -> bool:
        acts = activation.get(name)
        return True if acts is None else bool(acts[i])

    out = []
    for i in range(n):
        f_bc = b_ac = f_ac = b_bc = 0.0
        for name, sec in graph.sections.items():
            if name == crit or not active(name, i):
                continue
            c = costs[name]
            if name in upstream:
                f_bc += c.t_fwd_sample
                b_ac += c.t_bwd_sample
            else:
                f_ac += c.t_fwd_sample
                b_bc += c.t_bwd_sample
        cc = costs[crit]
        out.append(Sample(i, f_bc, cc.t_fwd_sample, f_ac, b_bc,
                          cc.t_bwd_sample, b_ac))
    return out
