"""Fault-tolerant sharded checkpointing.

Design (scales to multi-host — documented deltas where this container's
single-controller path differs):

* **Sharded layout**: every leaf is saved as one ``.npy`` per *shard* of its
  sharding (multi-host: each host writes only its addressable shards; here
  the single process writes all of them).
* **Atomic commit**: writes go to ``step_NNNNNNNN.tmp/``; a manifest (pytree
  structure, shapes, dtypes, sharding specs, step, config fingerprint) is
  written last and the directory is atomically renamed.  A crash mid-write
  never corrupts the latest checkpoint.
* **Async**: ``save()`` snapshots device arrays to host (cheap, XLA D2H)
  and hands serialization to a background thread; training continues.
* **Elastic restore**: ``restore()`` reassembles global arrays from shard
  files and ``device_put``s them onto the *current* mesh/sharding — the
  mesh shape may differ from the one that saved (reshard-on-load).
* **Retention**: ``keep_last_n`` plus optional ``keep_every`` milestones.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, List, Optional

import jax
import numpy as np


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return ".".join(out)


class Checkpointer:
    def __init__(self, directory, *, keep_last_n: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last_n = keep_last_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[str] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Any, *, metadata: Optional[dict] = None,
             block: bool = False) -> None:
        self.wait()                     # one in-flight save at a time
        leaves, treedef = _flatten(tree)
        host_leaves = [(path, np.asarray(jax.device_get(leaf)))
                       for path, leaf in leaves]

        def _write():
            try:
                tmp = self.dir / f"step_{step:08d}.tmp"
                final = self.dir / f"step_{step:08d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                manifest = {"step": step, "metadata": metadata or {},
                            "time": time.time(), "leaves": []}
                for path, arr in host_leaves:
                    name = _path_str(path)
                    fn = name.replace("/", "_") + ".npy"
                    np.save(tmp / fn, arr)
                    manifest["leaves"].append(
                        {"path": name, "file": fn,
                         "shape": list(arr.shape), "dtype": str(arr.dtype)})
                (tmp / "manifest.json").write_text(json.dumps(manifest))
                if final.exists():
                    shutil.rmtree(final)
                os.replace(tmp, final)           # atomic commit
                self._retain()
            except Exception as e:              # pragma: no cover
                self._error = repr(e)

        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
            if self._error:
                raise RuntimeError(self._error)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err}")

    # ------------------------------------------------------------------ #
    def _retain(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last_n]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not p.is_dir():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------ #
    def restore(self, step: Optional[int], target: Any,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs); reshard onto ``shardings`` if given (elastic:
        the current mesh may differ from the saving mesh)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        files = {l["path"]: l for l in manifest["leaves"]}
        leaves, treedef = _flatten(target)
        out = []
        shard_leaves = (None if shardings is None
                        else treedef.flatten_up_to(shardings))
        for i, (path, leaf) in enumerate(leaves):
            name = _path_str(path)
            if name not in files:
                raise KeyError(f"checkpoint {step} missing leaf {name}")
            arr = np.load(d / files[name]["file"])
            want_dtype = getattr(leaf, "dtype", arr.dtype)
            arr = arr.astype(want_dtype)
            if shard_leaves is not None and shard_leaves[i] is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def manifest(self, step: int) -> dict:
        d = self.dir / f"step_{step:08d}"
        return json.loads((d / "manifest.json").read_text())
