"""Logical-axis sharding rules (the paper's C^s → physical mesh mapping).

Every model defines its parameters as a :class:`~repro.models.common.ParamSpec`
tree with *logical* axis names (``embed``, ``heads``, ``vocab``, ``experts``,
``mlp``, ``d_inner``, …).  This module is the single place those logical axes
meet *physical* mesh axes, under one axis-naming contract shared by the step
builders, the disaggregated runtime (``carve_meshes``) and the dry-run:

==========  =======================================================
mesh axis   meaning
==========  =======================================================
``pod``     slow inter-pod interconnect (DCN); outermost data axis
``data``    data parallelism / FSDP parameter sharding
``pipe``    pipeline stages (``ParallelConfig.pp``)
``seq``     context parallelism (``ParallelConfig.cp``)
``model``   tensor parallelism (``ParallelConfig.tp``)
==========  =======================================================

Per-section ``ParallelConfig(dp, tp, pp, cp)`` maps 1:1 onto a
``(data, pipe, seq, model)`` mesh via :func:`section_mesh`.

Dispatch contract (``repro.train.step.parallel_regime``): the step builders
read the ``pipe`` / ``seq`` axis sizes of the mesh they are handed and pick
the execution regime from them — ``pipe > 1`` routes the loss through
``repro.dist.pipeline.build_pp_loss``, ``seq > 1`` installs
``repro.dist.context.cp_attention`` as the model's attention
implementation, and both must agree with ``ParallelConfig.pp`` / ``.cp``
(mismatches raise instead of silently training replicated).  When the mesh
has a non-trivial ``pipe`` axis, :func:`rules_for` additionally maps the
stacked ``layers`` param dim onto it so parameters and optimizer state are
stage-partitioned at rest, matching ``build_pp_loss``'s shard_map specs.

Assignment is greedy left-to-right over a parameter's dims with two hard
invariants (property-tested): a mesh axis is never used twice in one spec,
and an axis is only assigned when the dim size divides it (divisibility
fallback → replicate).  ZeRO (:func:`zero_extend`) extends a parameter's
spec over free mesh axes for optimizer state.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.types import ArchConfig, ParallelConfig
from repro.models.common import ParamSpec, tree_map_specs

# --------------------------------------------------------------------------- #
# Axis-naming contract
# --------------------------------------------------------------------------- #
AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_PIPE = "pipe"
AXIS_SEQ = "seq"
AXIS_MODEL = "model"

#: mesh axes that carry data parallelism, outermost first
DP_AXES = (AXIS_POD, AXIS_DATA)

#: mesh axes eligible for ZeRO optimizer-state extension
ZERO_AXES = (AXIS_POD, AXIS_DATA, AXIS_MODEL)

#: logical param axis → mesh-axis candidates, tried in order
DEFAULT_RULES = {
    "embed": (AXIS_DATA,),          # FSDP: weights sharded over data
    "heads": (AXIS_MODEL,),
    "kv_heads": (AXIS_MODEL,),
    "vocab": (AXIS_MODEL,),
    "experts": (AXIS_MODEL,),       # expert parallelism when E % tp == 0
    "mlp": (AXIS_MODEL,),           # per-expert / dense MLP TP otherwise
    "d_inner": (AXIS_MODEL,),       # mamba inner-dim TP
}


def abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Version-portable ``AbstractMesh`` constructor (signature changed
    between jax releases; tests build device-free meshes through this)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def shard_map(f, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` without replication checking
    (``jax.shard_map``/``check_vma`` on jax ≥ 0.5,
    ``jax.experimental.shard_map``/``check_rep`` before)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# --------------------------------------------------------------------------- #
# Rules
# --------------------------------------------------------------------------- #
def rules_for(cfg: ArchConfig, mesh, *, teacher: bool = False) -> dict:
    """Sharding rules for one section of this arch on this mesh.

    teacher=True — forward-only frozen section: drop the FSDP rule
    (``embed`` → data).  A frozen teacher has no optimizer state to
    amortize the per-step all-gather against, so its weights stay
    replicated over the data axis and only TP shards them.

    On a mesh with a non-trivial ``pipe`` axis the stacked ``layers`` dim
    is mapped onto it: parameters and optimizer state live stage-
    partitioned at rest, matching the ``in_specs`` of
    ``repro.dist.pipeline.build_pp_loss``.  When the padded vocab divides
    the pipe axis — the same gate ``build_pp_loss`` uses for its
    vocab-parallel cross-entropy — the ``vocab`` param dim is mapped onto
    ``pipe`` too, so the embed/unembed tables rest exactly where the
    staged loss consumes them (vocab slice per stage)."""
    rules = dict(DEFAULT_RULES)
    if teacher:
        del rules["embed"]
    pp = dict(mesh.shape).get(AXIS_PIPE, 1)
    if pp > 1:
        rules["layers"] = (AXIS_PIPE,)
        if cfg.padded_vocab % pp == 0:
            rules["vocab"] = (AXIS_PIPE,) + DEFAULT_RULES["vocab"]
    return rules


def _candidates(rules: dict, name) -> Tuple[str, ...]:
    cand = rules.get(name, ())
    if cand is None:
        return ()
    if isinstance(cand, str):
        return (cand,)
    return tuple(cand)


def spec_for(spec: ParamSpec, mesh, rules: Optional[dict] = None) -> P:
    """PartitionSpec for one parameter: greedy left-to-right assignment,
    no mesh axis used twice, divisibility fallback → None (replicate)."""
    rules = DEFAULT_RULES if rules is None else rules
    axis_sizes = dict(mesh.shape)
    used: set = set()
    entries = []
    for dim, name in zip(spec.shape, spec.axes):
        entry = None
        for ax in _candidates(rules, name):
            if ax in axis_sizes and ax not in used \
                    and dim % axis_sizes[ax] == 0:
                entry = ax
                used.add(ax)
                break
        entries.append(entry)
    return P(*entries)


def zero_extend(spec: ParamSpec, base: P, mesh) -> P:
    """Extend a parameter's spec over free mesh axes (ZeRO §: optimizer
    state sharded where the weight is replicated).  The stacked ``layers``
    dim is never extended (it is the scan dim)."""
    axis_sizes = dict(mesh.shape)
    entries = [base[i] if i < len(base) else None
               for i in range(len(spec.shape))]
    used = {a for e in entries if e is not None
            for a in (e if isinstance(e, tuple) else (e,))}
    for ax in mesh.axis_names:
        if ax in used or ax not in ZERO_AXES:
            continue
        n = axis_sizes[ax]
        for i, (dim, name) in enumerate(zip(spec.shape, spec.axes)):
            if name == "layers":
                continue
            cur = entries[i]
            cur_t = () if cur is None else (
                cur if isinstance(cur, tuple) else (cur,))
            prod = n
            for a in cur_t:
                prod *= axis_sizes[a]
            if dim % prod == 0:
                entries[i] = cur_t + (ax,)
                used.add(ax)
                break
    return P(*entries)


# --------------------------------------------------------------------------- #
# Sharding trees
# --------------------------------------------------------------------------- #
def param_shardings(specs, mesh, rules: Optional[dict] = None):
    """NamedSharding tree for a ParamSpec tree."""
    return tree_map_specs(
        lambda s: NamedSharding(mesh, spec_for(s, mesh, rules)), specs)


def opt_state_shardings(specs, mesh, rules: Optional[dict] = None, *,
                        zero: bool = True):
    """AdamWState-shaped sharding tree: ``mu``/``nu``/``master`` get the
    parameter's spec, extended over free mesh axes when ``zero``."""
    from repro.optim.adamw import AdamWState

    def one(s: ParamSpec):
        base = spec_for(s, mesh, rules)
        if zero:
            base = zero_extend(s, base, mesh)
        return NamedSharding(mesh, base)

    tree = tree_map_specs(one, specs)
    # NamedSharding leaves are immutable: the three slots share one tree
    return AdamWState(step=replicated(mesh), mu=tree, nu=tree, master=tree)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# --------------------------------------------------------------------------- #
# Data-parallel helpers
# --------------------------------------------------------------------------- #
def dp_axes(mesh) -> Tuple[str, ...]:
    """The mesh axes carrying data parallelism, outermost first."""
    return tuple(a for a in mesh.axis_names if a in DP_AXES)


def axis_size(mesh, axes) -> int:
    """Product of mesh-axis sizes; axes may be a name, a tuple, or None."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    sizes = dict(mesh.shape)
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


def batch_spec(mesh, batch: int, seq_len: int) -> P:
    """[B, S] activation spec: shard batch over the dp axes; B=1 long-decode
    fallback shards the sequence instead; replicate when neither divides."""
    dp = dp_axes(mesh)
    n = axis_size(mesh, dp)
    if not dp:
        return P(None, None)
    if batch % n == 0:
        return P(dp, None)
    if seq_len % n == 0:
        return P(None, dp)
    return P(None, None)


def dp_sharding(mesh, ndim: int = 2) -> NamedSharding:
    """Activation sharding with dim 0 (batch) over the dp axes and every
    other dim replicated — the cross-section handoff layout."""
    dp = dp_axes(mesh)
    return NamedSharding(
        mesh, P(dp if dp else None, *([None] * (ndim - 1))))


def logits_sharding(mesh, batch: int, vocab: int) -> NamedSharding:
    """[B, V] logits: batch over dp, vocab over model (divisibility
    fallback → replicate per dim)."""
    dp = dp_axes(mesh)
    b_ax = dp if dp and batch % axis_size(mesh, dp) == 0 else None
    m = dict(mesh.shape).get(AXIS_MODEL, 1)
    v_ax = AXIS_MODEL if AXIS_MODEL in mesh.axis_names \
        and vocab % m == 0 else None
    return NamedSharding(mesh, P(b_ax, v_ax))


def data_shardings(mesh, batch_specs) -> dict:
    """NamedSharding tree for a batch of ShapeDtypeStructs: dim 0 (batch)
    over the dp axes when divisible, else dim 1 (sequence), else replicated.
    On a CP mesh (``seq`` axis > 1) dim 1 is additionally sequence-sharded
    over ``seq`` when divisible, matching the activation layout
    ``cp_attention`` expects."""
    dp = dp_axes(mesh)
    n = axis_size(mesh, dp)
    cp = dict(mesh.shape).get(AXIS_SEQ, 1)

    def one(leaf):
        entries = [None] * leaf.ndim
        if dp and leaf.ndim >= 1 and leaf.shape[0] % n == 0:
            entries[0] = dp
        elif dp and leaf.ndim >= 2 and leaf.shape[1] % n == 0:
            entries[1] = dp
        if cp > 1 and leaf.ndim >= 2 and entries[1] is None \
                and leaf.shape[1] % cp == 0:
            entries[1] = AXIS_SEQ
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map(one, batch_specs)


# --------------------------------------------------------------------------- #
# Decode-cache shardings
# --------------------------------------------------------------------------- #
def kv_cache_spec(mesh, shape: Tuple[int, ...], kind: str = "attn") -> P:
    """Spec for one [B, C, KV, hd] KV-cache buffer.  KV heads shard over
    ``model`` when divisible; a kv=1 (MQA) cache shards the *sequence* over
    ``model`` instead (flash-decoding split)."""
    B, C, KV, _ = shape
    dp = dp_axes(mesh)
    b_ax = dp if dp and B % axis_size(mesh, dp) == 0 else None
    m = dict(mesh.shape).get(AXIS_MODEL, 1)
    if AXIS_MODEL in mesh.axis_names and KV % m == 0:
        return P(b_ax, None, AXIS_MODEL, None)
    if AXIS_MODEL in mesh.axis_names and C % m == 0:
        return P(b_ax, AXIS_MODEL, None, None)
    return P(b_ax, None, None, None)


def _ssm_cache_spec(mesh, leaf, key: str) -> P:
    """Mamba cache leaves: ``conv`` [B, W, ch] / ``ssm`` [B, nh, hd, n]
    (possibly layer-stacked).  Batch over dp; channels/heads over model."""
    lead = leaf.ndim - (3 if key == "conv" else 4)
    shape = leaf.shape[lead:]
    dp = dp_axes(mesh)
    b_ax = dp if dp and shape[0] % axis_size(mesh, dp) == 0 else None
    m = dict(mesh.shape).get(AXIS_MODEL, 1)
    has_m = AXIS_MODEL in mesh.axis_names
    if key == "conv":
        ch_ax = AXIS_MODEL if has_m and shape[2] % m == 0 else None
        tail = (b_ax, None, ch_ax)
    else:
        h_ax = AXIS_MODEL if has_m and shape[1] % m == 0 else None
        tail = (b_ax, h_ax, None, None)
    return P(*((None,) * lead + tail))


def cache_shardings(mesh, cache_specs):
    """NamedSharding tree for a decode-cache ShapeDtypeStruct tree.  Leaf
    kind is taken from its key ('k'/'v' → attention, 'conv'/'ssm' → mamba);
    leading layer-stack dims are replicated."""
    def one(path, leaf):
        key = None
        for entry in reversed(path):
            k = getattr(entry, "key", None)
            if isinstance(k, str):
                key = k
                break
        if key in ("conv", "ssm"):
            spec = _ssm_cache_spec(mesh, leaf, key)
        else:
            lead = leaf.ndim - 4
            spec = P(*((None,) * lead
                       + tuple(kv_cache_spec(mesh, leaf.shape[lead:]))))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_specs)


# --------------------------------------------------------------------------- #
# Physical-layout helpers
# --------------------------------------------------------------------------- #
def head_pad_for(cfg: ArchConfig, tp: int) -> int:
    """Zero Q-heads to append so (H + pad) divides the TP axis while
    preserving whole KV groups ((H + pad) % KV == 0).  0 when no attention
    or already divisible."""
    H, KV = cfg.num_heads, cfg.num_kv_heads
    if H == 0 or tp <= 1 or H % tp == 0:
        return 0
    Hp = H + 1
    while Hp % tp or (KV and Hp % KV):
        Hp += 1
    return Hp - H


def section_mesh(devices: Sequence, parallel: ParallelConfig,
                 name: str = "") -> Mesh:
    """Physical mesh for one section: ``ParallelConfig(dp, tp, pp, cp)``
    maps 1:1 onto ``(data, pipe, seq, model)`` axes (sizes may be 1)."""
    n = parallel.devices
    assert len(devices) == n, (name, len(devices), n)
    group = np.array(list(devices)).reshape(
        parallel.dp, parallel.pp, parallel.cp, parallel.tp)
    return Mesh(group, (AXIS_DATA, AXIS_PIPE, AXIS_SEQ, AXIS_MODEL))
