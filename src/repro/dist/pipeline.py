"""Stage-partitioned (GPipe) training loss for PP sections (paper §3.2).

``build_pp_loss`` partitions the layer stack of an LM across the pipeline
mesh axis and returns a loss function that runs a GPipe schedule inside
``shard_map``: microbatches enter stage 0, activations hop stage→stage via
``ppermute``, and the last stage computes the CE loss (summed, then
normalized globally — numerically identical to the monolithic loss; the
MoE aux term is averaged per microbatch, an approximation that vanishes
for dense archs).

The whole schedule is differentiable — ``ppermute``/``psum`` transpose to
the reverse hops, so ``jax.grad`` of the returned function yields exactly
the 1F1B-style backward traffic pattern.

Known cost (SPMD uniformity): every stage executes the embed and the
final-norm/unembed/CE program for all microbatches, with non-last-stage
results masked out — the loss pays ``pp ×`` the unembed FLOPs.  A
ring-distributed CE (each stage scoring ``n_micro/pp`` microbatches) would
remove this; tracked in ROADMAP.md open items.

Axis naming follows ``repro.dist.sharding``: stages live on ``pipe`` when
the mesh has one, else on ``pod`` (cross-pod PP — DCN-friendly, since only
[mbs, S, D] activations cross stage boundaries per tick).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ArchConfig
from repro.dist.sharding import AXIS_DATA, AXIS_PIPE, AXIS_POD, shard_map
from repro.models import transformer as tf


def _stage_axis(mesh, axis: Optional[str]) -> str:
    if axis is not None:
        return axis
    return AXIS_PIPE if AXIS_PIPE in mesh.axis_names else AXIS_POD


def build_pp_loss(cfg: ArchConfig, mesh, n_micro: int = 1, *,
                  stage_axis: Optional[str] = None,
                  data_axis: Optional[str] = None,
                  impl: str = "auto", remat: bool = True,
                  aux_weight: float = 0.01) -> Tuple:
    """Returns ``(loss_fn, info)`` — ``loss_fn(params, batch) -> scalar``.

    params is the full (un-partitioned) ``tf.lm_specs`` tree; shard_map
    in_specs place the stacked ``layers`` dim on the stage axis and
    replicate embed/norm/unembed, so the caller passes ordinary global
    arrays and the partitioner does the placement."""
    st_ax = _stage_axis(mesh, stage_axis)
    d_ax = data_axis or (AXIS_DATA if AXIS_DATA in mesh.axis_names
                         else None)
    sizes = dict(mesh.shape)
    pp = sizes[st_ax]
    dp = sizes.get(d_ax, 1) if d_ax else 1
    pk, reps = tf.group_layout(cfg)
    assert reps % pp == 0, (
        f"{reps} layer groups do not divide {pp} pipeline stages")
    per_stage = reps // pp
    perm = [(i, i + 1) for i in range(pp - 1)]

    def stage_fwd(layers_local, x):
        aux_tot = jnp.zeros((), jnp.float32)
        for li in range(per_stage):
            group = jax.tree_util.tree_map(lambda a: a[li], layers_local)
            for j, (mixer, ffn) in enumerate(pk):
                fn = functools.partial(tf._sublayer_fwd, cfg=cfg,
                                       mixer=mixer, ffn=ffn, causal=True,
                                       segment_ids=None, impl=impl)
                if remat:
                    fn = jax.checkpoint(fn)
                x, aux = fn(group[f"sub{j}"], x)
                aux_tot = aux_tot + aux
        return x, aux_tot

    def pipeline_body(params, batch, *, d_axis):
        stage = jax.lax.axis_index(st_ax)
        layers_local = params["layers"]
        tokens = batch["tokens"]
        Bl, S = tokens.shape
        assert Bl % n_micro == 0, (Bl, n_micro)
        msz = Bl // n_micro

        def micro(tree, t):
            return jax.tree_util.tree_map(
                lambda a: a[t * msz:(t + 1) * msz], tree)

        embeds = [tf.embed_tokens(params, cfg, micro(batch, t))
                  for t in range(n_micro)]
        recv = jnp.zeros_like(embeds[0])
        aux_sum = jnp.zeros((), jnp.float32)
        outs = []
        for t in range(n_micro + pp - 1):
            inp = jnp.where(stage == 0, embeds[min(t, n_micro - 1)], recv)
            h, aux = stage_fwd(layers_local, inp)
            # aux is only meaningful while this stage holds a live
            # microbatch (ticks [stage, stage + n_micro))
            live = jnp.logical_and(t >= stage, t - stage < n_micro)
            aux_sum = aux_sum + jnp.where(live, aux, 0.0)
            outs.append(h)
            if perm:
                recv = jax.lax.ppermute(h, st_ax, perm)

        # last stage: final norm + unembed + CE sums per microbatch
        nll_sum = jnp.zeros((), jnp.float32)
        mask_sum = jnp.zeros((), jnp.float32)
        for j in range(n_micro):
            hj = tf.apply_norm(params["final_norm"], outs[pp - 1 + j], cfg)
            logits = tf.unembed(params, cfg, hj).astype(jnp.float32)
            mb = micro(batch, j)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, mb["labels"][..., None], axis=-1)[..., 0]
            m = mb.get("loss_mask")
            m = jnp.ones_like(lse) if m is None else m.astype(jnp.float32)
            nll_sum = nll_sum + jnp.sum((lse - gold) * m)
            mask_sum = mask_sum + jnp.sum(m)

        is_last = (stage == pp - 1).astype(jnp.float32)
        axes = (st_ax,) + ((d_axis,) if d_axis else ())
        total_nll = jax.lax.psum(nll_sum * is_last, axes)
        total_mask = jax.lax.psum(mask_sum * is_last, axes)
        aux_tot = jax.lax.psum(aux_sum, (st_ax,)) / n_micro
        if d_axis:
            aux_tot = jax.lax.psum(aux_tot, (d_axis,)) / dp
        return total_nll / jnp.maximum(total_mask, 1.0) \
            + aux_weight * aux_tot

    def loss_fn(params, batch):
        p_specs = {k: (P(st_ax) if k == "layers" else P())
                   for k in params}
        shard_b = d_ax is not None and \
            batch["tokens"].shape[0] % (dp * n_micro) == 0
        b_specs = {k: (P(d_ax) if shard_b else P()) for k in batch}
        body = functools.partial(pipeline_body,
                                 d_axis=d_ax if shard_b else None)
        run = shard_map(body, mesh, (p_specs, b_specs), P())
        return run(params, batch)

    info = {"stage_axis": st_ax, "data_axis": d_ax, "stages": pp,
            "groups_per_stage": per_stage, "n_micro": n_micro}
    return loss_fn, info
