"""Stage-partitioned (GPipe) training loss for PP sections (paper §3.2).

``build_pp_loss`` partitions the layer stack of an LM across the pipeline
mesh axis and returns a loss function that runs a GPipe schedule inside
``shard_map``: microbatches enter stage 0, activations hop stage→stage via
``ppermute``, and the last stage computes the CE loss (summed, then
normalized globally).  The result is numerically identical to the
*monolithic* ``tf.lm_loss`` over the full batch (n_micro = 1), including
the MoE aux term: router statistics (frac, prob) — which are linear in the
token population, unlike the aux scalar — are accumulated per MoE layer
across microbatches and DP shards and only then combined into the
load-balancing loss, so microbatch splitting does not perturb it.

The whole schedule is differentiable — ``ppermute``/``psum`` transpose to
the reverse hops, so ``jax.grad`` of the returned function yields exactly
the 1F1B-style backward traffic pattern.

End-to-end wiring: ``repro.train.step.build_train_step`` dispatches to
this builder whenever the section mesh has a non-trivial ``pipe`` axis
(``ParallelConfig.pp > 1``); the train step then takes a single
``value_and_grad`` of the staged loss instead of the plain grad-
accumulation scan, and the optimizer update is unchanged.  The shard_map
is manual over *all* mesh axes: axes not named in the specs (``seq``,
``model``) are replicated inside the body, so pp×tp / dp×pp compositions
are exact (TP then shards parameters at rest via ``rules_for`` but the
pipeline body computes each stage's layers unsharded per device).
pp×cp is rejected by the dispatcher.

Vocab-parallel cross-entropy: SPMD uniformity means every stage executes
the final-norm/unembed/CE program for all microbatches.  Instead of
masking non-last-stage results (paying ``pp ×`` the unembed FLOPs), the
unembed projection is sharded over the stage axis — each stage scores its
``padded_vocab / pp`` vocab slice against the psum-broadcast final hidden
and the slices combine through a distributed logsumexp (max via
``pmax`` of a stopped gradient, then ``log ∘ psum`` of the shifted
exponentials) plus a psum of the gold logit.  The per-device unembed dot
is ``pp ×`` smaller; the gold/embed-lookup psums are bitwise-exact (each
element lives on exactly one stage, the rest contribute 0.0) and the
distributed logsumexp matches ``jax.nn.logsumexp`` to a few ulp (the two
reassociate the log/exp differently).  Enabled whenever
``padded_vocab % pp == 0`` (``vocab_parallel="auto"``); the masked path
remains as the fallback.

Tensor parallelism inside stage bodies: when the mesh has a non-trivial
``model`` axis, the shard_map in_specs slice attention ``heads`` /
``kv_heads`` and the FFN ``mlp`` dim over it (Megatron column→row
pattern), and ``tf._sublayer_fwd`` psums the partial mixer/FFN outputs —
real compute sharding, not the at-rest-only sharding this builder had
before.  Gating is per-feature: attention TP needs ``head_pad == 0`` and
``H % tp == KV % tp == 0`` (contiguous head slices then align with KV
slices, keeping GQA groups local); FFN TP needs ``d_ff % tp == 0``;
mamba mixers and the MoE router/expert dims stay replicated.

Axis naming follows ``repro.dist.sharding``: stages live on ``pipe`` when
the mesh has one, else on ``pod`` (cross-pod PP — DCN-friendly, since only
[mbs, S, D] activations cross stage boundaries per tick).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.types import ArchConfig
from repro.dist.sharding import (AXIS_DATA, AXIS_MODEL, AXIS_PIPE,
                                 AXIS_POD, axis_size, shard_map)
from repro.models import common as cm
from repro.models import transformer as tf


def _stage_axis(mesh, axis: Optional[str]) -> str:
    if axis is not None:
        return axis
    return AXIS_PIPE if AXIS_PIPE in mesh.axis_names else AXIS_POD


def _data_axes(mesh, st_ax: str, data_axis) -> tuple:
    """DP axes of the pipeline shard_map, outermost first: all of
    (pod, data) that exist and are not the stage axis — on a multi-pod PP
    mesh the pod axis carries data parallelism too, matching
    ``sharding.dp_axes`` (dropping it would silently duplicate compute
    per pod and double the in-pipeline microbatch size)."""
    if data_axis is not None:
        return (data_axis,) if isinstance(data_axis, str) else \
            tuple(data_axis)
    return tuple(a for a in (AXIS_POD, AXIS_DATA)
                 if a in mesh.axis_names and a != st_ax)


def contiguous_microbatch(tree, t: int, msz: int):
    """Default microbatch layout: microbatch ``t`` is the ``t``-th
    contiguous [msz] slice of the (per-DP-shard) batch dim.  Under the
    shard-major global layout ``[dp, n_micro, mbs]`` the train-step data
    contract uses (see ``repro.train.step``), this selects exactly the same
    microbatches as ``_split_microbatches`` does on the monolithic path."""
    return jax.tree_util.tree_map(lambda a: a[t * msz:(t + 1) * msz], tree)


def _tp_plan(cfg: ArchConfig, mesh, st_ax: str):
    """(tp_axis, tp_attn, tp_ffn) — which stage-body dims the ``model``
    axis can shard exactly (see module docstring for the gates)."""
    tp = dict(mesh.shape).get(AXIS_MODEL, 1)
    if tp <= 1 or st_ax == AXIS_MODEL:
        return None, False, False
    has_attn = any(cfg.is_attn_layer(i) for i in range(cfg.num_layers))
    tp_attn = (has_attn and cfg.head_pad == 0 and cfg.num_heads % tp == 0
               and cfg.num_kv_heads > 0 and cfg.num_kv_heads % tp == 0)
    tp_ffn = cfg.d_ff > 0 and cfg.d_ff % tp == 0
    if not (tp_attn or tp_ffn):
        return None, False, False
    return AXIS_MODEL, tp_attn, tp_ffn


def _layer_specs(cfg: ArchConfig, st_ax: str, tp_axis, tp_attn: bool,
                 tp_ffn: bool):
    """Per-leaf shard_map in_specs for the stacked ``layers`` tree: the
    stacked dim on the stage axis, plus — under TP — ``heads``/``kv_heads``
    (attention) and ``mlp`` (FFN) dims on the model axis, so each shard
    receives its head/f slice and the stage body computes sharded."""
    def one(s):
        entries = []
        for ax in s.axes:
            if ax == "layers":
                entries.append(st_ax)
            elif tp_attn and ax in ("heads", "kv_heads"):
                entries.append(tp_axis)
            elif tp_ffn and ax == "mlp":
                entries.append(tp_axis)
            else:
                entries.append(None)
        return P(*entries)
    return cm.tree_map_specs(one, tf.lm_specs(cfg)["layers"])


def build_pp_loss(cfg: ArchConfig, mesh, n_micro: int = 1, *,
                  stage_axis: Optional[str] = None,
                  data_axis: Optional[str] = None,
                  impl: str = "auto", remat: bool = True,
                  aux_weight: float = 0.01, causal: bool = True,
                  act_hook: Optional[Callable] = None,
                  vocab_parallel="auto",
                  mb_layout: Callable = contiguous_microbatch) -> Tuple:
    """Returns ``(loss_fn, info)`` — ``loss_fn(params, batch) -> scalar``.

    params is the full (un-partitioned) ``tf.lm_specs`` tree; shard_map
    in_specs place the stacked ``layers`` dim on the stage axis (plus
    head/FFN dims on the model axis under TP, and the vocab dim of the
    embed/unembed tables on the stage axis under vocab-parallel CE), so
    the caller passes ordinary global arrays and the partitioner does the
    placement.

    causal    — False for encoder-style (ViT) sections.
    act_hook  — activation hook installed (via ``common.act_hook``) inside
                the pipeline body.  Defaults to None, which *disables* any
                hook active at trace time: sharding-constraint hooks are
                illegal inside the manual shard_map region.  Hooks passed
                here must be shard-local (dtype casts, debug taps, …).
    vocab_parallel — "auto" (on iff ``padded_vocab % pp == 0``) | True |
                False.  See module docstring for the math and exactness.
    mb_layout — external microbatch layout: ``(local_batch, t, msz) ->
                microbatch`` tree slicer, so callers with a different data
                layout than the shard-major default can thread it through.
    """
    st_ax = _stage_axis(mesh, stage_axis)
    d_ax = _data_axes(mesh, st_ax, data_axis) or None
    sizes = dict(mesh.shape)
    pp = sizes[st_ax]
    dp = axis_size(mesh, d_ax)
    pk, reps = tf.group_layout(cfg)
    assert reps % pp == 0, (
        f"{reps} layer groups do not divide {pp} pipeline stages")
    per_stage = reps // pp
    perm = [(i, i + 1) for i in range(pp - 1)]
    n_moe = per_stage * sum(1 for _, ffn in pk if ffn == "moe")
    E = max(cfg.num_experts, 1)

    if vocab_parallel == "auto":
        vp = pp > 1 and cfg.padded_vocab % pp == 0
    else:
        vp = bool(vocab_parallel)
        if vp and cfg.padded_vocab % pp:
            raise ValueError(
                f"vocab_parallel=True but padded_vocab="
                f"{cfg.padded_vocab} does not divide pp={pp}")
    Vs = cfg.padded_vocab // pp if vp else cfg.padded_vocab
    tp_axis, tp_attn, tp_ffn = _tp_plan(cfg, mesh, st_ax)

    def stage_fwd(layers_local, x):
        """Local layer groups.  Returns (x, stats [n_moe, 2, E]) — per-MoE-
        sublayer router stats, kept separate so the nonlinear aux combine
        happens only after cross-microbatch/shard averaging."""
        stats = []
        for li in range(per_stage):
            group = jax.tree_util.tree_map(lambda a: a[li], layers_local)
            for j, (mixer, ffn) in enumerate(pk):
                is_moe = ffn == "moe"
                fn = functools.partial(tf._sublayer_fwd, cfg=cfg,
                                       mixer=mixer, ffn=ffn, causal=causal,
                                       segment_ids=None, impl=impl,
                                       collect_stats=is_moe,
                                       tp_axis=tp_axis, tp_attn=tp_attn,
                                       tp_ffn=tp_ffn)
                if remat:
                    fn = jax.checkpoint(fn)
                if is_moe:
                    x, _, st = fn(group[f"sub{j}"], x)
                    stats.append(st)
                else:
                    x, _ = fn(group[f"sub{j}"], x)
        if stats:
            return x, jnp.stack(stats)
        return x, jnp.zeros((0, 2, E), jnp.float32)

    def vp_embed(params, batch, off):
        """Vocab-parallel embed lookup (tied tables): each stage holds a
        [Vs, D] row slice; a token's row lives on exactly one stage and
        every other stage contributes 0.0, so the psum is bitwise-exact."""
        tok = batch["tokens"]
        loc = jnp.clip(tok - off, 0, Vs - 1)
        x = jnp.take(params["embed"], loc, axis=0)
        mine = ((tok >= off) & (tok < off + Vs)).astype(x.dtype)
        x = jax.lax.psum(x * mine[..., None], st_ax)
        return tf.vision_scatter(params, cfg, x, batch)

    def vp_logits(params, hj, off):
        """Local-vocab-slice logits [msz, S, Vs], f32, pad-masked by the
        *global* column index (exact lse of the unpadded model)."""
        x = cm.grad_dtype_barrier(hj)
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
        logits = logits.astype(jnp.float32)
        if cfg.vocab_pad:
            valid = off + jnp.arange(Vs) < cfg.vocab_size
            logits = jnp.where(valid, logits, -1e30)
        return logits

    def pipeline_body(params, batch, *, d_axis):
        stage = jax.lax.axis_index(st_ax)
        layers_local = params["layers"]
        tokens = batch["tokens"]
        Bl, S = tokens.shape
        assert Bl % n_micro == 0, (Bl, n_micro)
        msz = Bl // n_micro
        off = stage * Vs if vp else 0
        vp_embed_table = vp and cfg.tie_embeddings

        with cm.act_hook(act_hook):
            if vp_embed_table:
                embeds = [vp_embed(params, mb_layout(batch, t, msz), off)
                          for t in range(n_micro)]
            else:
                embeds = [tf.embed_tokens(params, cfg,
                                          mb_layout(batch, t, msz))
                          for t in range(n_micro)]
            recv = jnp.zeros_like(embeds[0])
            stats_sum = jnp.zeros((n_moe, 2, E), jnp.float32)
            outs = []
            for t in range(n_micro + pp - 1):
                inp = jnp.where(stage == 0, embeds[min(t, n_micro - 1)],
                                recv)
                h, st = stage_fwd(layers_local, inp)
                # stats are only meaningful while this stage holds a live
                # microbatch (ticks [stage, stage + n_micro))
                live = jnp.logical_and(t >= stage, t - stage < n_micro)
                stats_sum = stats_sum + jnp.where(live, st,
                                                  jnp.zeros_like(st))
                outs.append(h)
                if perm:
                    recv = jax.lax.ppermute(h, st_ax, perm)

            # final norm + unembed + CE sums per microbatch.
            # vp: the last stage's final hidden is psum-broadcast to every
            # stage (bitwise: the other stages contribute zeros), each
            # stage scores its vocab slice, and the slices combine via a
            # distributed logsumexp + gold-logit psum — nll_sum comes out
            # stage-replicated.  masked fallback: only the last stage's
            # full-vocab result survives the is_last mask.
            nll_sum = jnp.zeros((), jnp.float32)
            mask_sum = jnp.zeros((), jnp.float32)
            for j in range(n_micro):
                hj = outs[pp - 1 + j]
                if vp:
                    hj = jax.lax.psum(
                        jnp.where(stage == pp - 1, hj, jnp.zeros_like(hj)),
                        st_ax)
                hj = tf.apply_norm(params["final_norm"], hj, cfg)
                mb = mb_layout(batch, j, msz)
                if vp:
                    logits = vp_logits(params, hj, off)
                    m_loc = jnp.max(logits, axis=-1)
                    mx = jax.lax.pmax(jax.lax.stop_gradient(m_loc), st_ax)
                    se = jnp.sum(jnp.exp(logits - mx[..., None]), axis=-1)
                    lse = mx + jnp.log(jax.lax.psum(se, st_ax))
                    lbl = mb["labels"]
                    lloc = jnp.clip(lbl - off, 0, Vs - 1)
                    g = jnp.take_along_axis(logits, lloc[..., None],
                                            axis=-1)[..., 0]
                    mine = ((lbl >= off) & (lbl < off + Vs)).astype(
                        jnp.float32)
                    gold = jax.lax.psum(g * mine, st_ax)
                else:
                    logits = tf.unembed(params, cfg, hj).astype(
                        jnp.float32)
                    lse = jax.nn.logsumexp(logits, axis=-1)
                    gold = jnp.take_along_axis(
                        logits, mb["labels"][..., None], axis=-1)[..., 0]
                m = mb.get("loss_mask")
                m = jnp.ones_like(lse) if m is None else m.astype(
                    jnp.float32)
                nll_sum = nll_sum + jnp.sum((lse - gold) * m)
                mask_sum = mask_sum + jnp.sum(m)

        if vp:
            # nll_sum is replicated across stages (built from psum/pmax
            # results) — reduce over the data axes only
            axes = tuple(d_axis or ())
            total_nll = jax.lax.psum(nll_sum, axes) if axes else nll_sum
            total_mask = jax.lax.psum(mask_sum, axes) if axes else mask_sum
        else:
            is_last = (stage == pp - 1).astype(jnp.float32)
            axes = (st_ax,) + tuple(d_axis or ())
            total_nll = jax.lax.psum(nll_sum * is_last, axes)
            total_mask = jax.lax.psum(mask_sum * is_last, axes)
        aux_tot = jnp.float32(0.0)
        if n_moe:
            # average the *linear* router stats over microbatches and DP
            # shards first, then combine — exact full-batch aux (each
            # stage's layers are distinct, so the stage psum is the layer
            # sum, not an average)
            stats = stats_sum / n_micro
            if d_axis:
                stats = jax.lax.psum(stats, tuple(d_axis)) / dp
            frac, prob = stats[:, 0], stats[:, 1]
            aux_local = E * jnp.sum(frac * prob) / cfg.experts_per_token
            aux_tot = jax.lax.psum(aux_local, (st_ax,))
        return total_nll / jnp.maximum(total_mask, 1.0) \
            + aux_weight * aux_tot

    layer_specs = _layer_specs(cfg, st_ax, tp_axis, tp_attn, tp_ffn)

    def loss_fn(params, batch):
        p_specs = {}
        for k in params:
            if k == "layers":
                p_specs[k] = layer_specs
            elif vp and k == "embed" and cfg.tie_embeddings:
                p_specs[k] = P(st_ax, None)
            elif vp and k == "unembed":
                p_specs[k] = P(None, st_ax)
            else:
                p_specs[k] = P()
        shard_b = d_ax is not None and \
            batch["tokens"].shape[0] % (dp * n_micro) == 0
        b_specs = {k: (P(d_ax) if shard_b else P()) for k in batch}
        body = functools.partial(pipeline_body,
                                 d_axis=d_ax if shard_b else None)
        run = shard_map(body, mesh, (p_specs, b_specs), P())
        return run(params, batch)

    info = {"stage_axis": st_ax, "data_axis": d_ax, "stages": pp,
            "groups_per_stage": per_stage, "n_micro": n_micro,
            "moe_layers_per_stage": n_moe, "vocab_parallel": vp,
            "vocab_shard": Vs, "tp_axis": tp_axis, "tp_attn": tp_attn,
            "tp_ffn": tp_ffn}
    return loss_fn, info
