"""Context-parallel attention over the CP mesh axis (paper §3.2: the
``cp`` knob of a section's ``C^s``).

Long-sequence sections (ViT over visual tokens, 500K-token decode hosts)
shard the *sequence* across devices.  Two exact execution modes:

* ``ulysses``   — DeepSpeed-Ulysses style: all-to-all reshards
  [B, S/cp, H, D] → [B, S, H/cp, D], runs full-sequence flash attention on
  a head slice, and all-to-alls back.  Comm is O(S·H·D/cp) per device;
  requires ``H % cp == 0`` and ``KV % cp == 0``.
* ``allgather`` — keeps Q sequence-sharded and all-gathers K/V (the
  fallback for MQA-style sections where KV heads don't divide cp); the
  causal mask is offset per shard.

Both modes are numerically exact (checked against the naive reference in
``tests/drivers/driver_pipeline_cp.py``) and differentiable — the flash
custom-VJP recomputes inside the shard, so the backward pass reuses the
same collectives (transposed) the forward issued.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import AXIS_MODEL, AXIS_SEQ, shard_map
from repro.kernels import ref


def _cp_axis(mesh, axis: Optional[str]) -> str:
    if axis is not None:
        return axis
    if AXIS_SEQ in mesh.axis_names and dict(mesh.shape)[AXIS_SEQ] > 1:
        return AXIS_SEQ
    return AXIS_SEQ if AXIS_SEQ in mesh.axis_names else AXIS_MODEL


def cp_attention(q, k, v, mesh, *, axis: Optional[str] = None,
                 mode: str = "ulysses", causal: bool = True,
                 window: int = 0, scale: Optional[float] = None,
                 block_q: int = 512, block_kv: int = 512):
    """Context-parallel GQA attention.

    q [B, S, H, D]; k, v [B, S, KV, D] — logically full-sequence arrays
    whose sequence dim is (or will be, via the in_specs) sharded over the
    CP axis.  Returns [B, S, H, D] with the same layout as q.
    """
    ax = _cp_axis(mesh, axis)
    cp = dict(mesh.shape)[ax]
    B, S, H, D = q.shape
    KV = k.shape[2]
    assert S % cp == 0, (S, cp)
    if mode == "ulysses" and (H % cp or KV % cp):
        # MQA / odd head counts can't head-shard: fall back to KV gather
        mode = "allgather"

    spec = P(None, ax, None, None)
    shard_len = S // cp

    def local(ql, kl, vl):
        idx = jax.lax.axis_index(ax)
        flash = functools.partial(ref.flash_attention_jnp, causal=causal,
                                  window=window, scale=scale,
                                  block_q=block_q, block_kv=block_kv)
        if mode == "allgather":
            kg = jax.lax.all_gather(kl, ax, axis=1, tiled=True)
            vg = jax.lax.all_gather(vl, ax, axis=1, tiled=True)
            return flash(ql, kg, vg, q_offset=idx * shard_len)
        # ulysses: seq-sharded -> head-sharded (full sequence per device)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=ax,
                                split_axis=2, concat_axis=1, tiled=True)
        o = flash(a2a(ql), a2a(kl), a2a(vl))
        return jax.lax.all_to_all(o, ax, split_axis=1, concat_axis=2,
                                  tiled=True)

    run = shard_map(local, mesh, (spec, spec, spec), spec)
    return run(q, k, v)
