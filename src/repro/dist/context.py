"""Context-parallel attention over the CP mesh axis (paper §3.2: the
``cp`` knob of a section's ``C^s``).

Long-sequence sections (ViT over visual tokens, 500K-token decode hosts)
shard the *sequence* across devices.  Two exact execution modes:

* ``ulysses``   — DeepSpeed-Ulysses style: all-to-all reshards
  [B, S/cp, H, D] → [B, S, H/cp, D], runs full-sequence flash attention on
  a head slice, and all-to-alls back.  Comm is O(S·H·D/cp) per device;
  requires ``H % cp == 0`` and ``KV % cp == 0``.
* ``allgather`` — keeps Q sequence-sharded and all-gathers K/V (the
  fallback for MQA-style sections where KV heads don't divide cp); the
  causal mask is offset per shard.

Both modes are numerically exact (checked against the naive reference in
``tests/drivers/driver_pipeline_cp.py``) and differentiable — the flash
custom-VJP recomputes inside the shard, so the backward pass reuses the
same collectives (transposed) the forward issued.

End-to-end wiring: ``repro.train.step.build_train_step`` dispatches on the
mesh — a non-trivial ``seq`` axis (``ParallelConfig.cp > 1``) installs
:func:`cp_attention_impl` as the model's full-sequence attention
implementation via ``repro.models.attention.attention_impl``, so every
self-attention call in the train step runs context-parallel.  The shard_map
is manual over the ``seq`` (and optionally batch/data) axes only; any other
mesh axes are replicated *inside* the attention body while the surrounding
computation stays GSPMD-sharded — exact in all compositions (cp×tp, dp×cp).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import AXIS_MODEL, AXIS_SEQ, axis_size, shard_map
from repro.kernels import ref


def _cp_axis(mesh, axis: Optional[str]) -> str:
    if axis is not None:
        return axis
    if AXIS_SEQ in mesh.axis_names and dict(mesh.shape)[AXIS_SEQ] > 1:
        return AXIS_SEQ
    return AXIS_SEQ if AXIS_SEQ in mesh.axis_names else AXIS_MODEL


def cp_attention(q, k, v, mesh, *, axis: Optional[str] = None,
                 mode: str = "ulysses", causal: bool = True,
                 window: int = 0, scale: Optional[float] = None,
                 block_q: int = 512, block_kv: int = 512,
                 batch_axes=None):
    """Context-parallel GQA attention.

    q [B, S, H, D]; k, v [B, S, KV, D] — logically full-sequence arrays
    whose sequence dim is (or will be, via the in_specs) sharded over the
    CP axis.  Returns [B, S, H, D] with the same layout as q.

    batch_axes — mesh axes (name or tuple) to keep the batch dim sharded
    over inside the shard_map (the dp axes of a section mesh); ignored when
    B doesn't divide them.  Attention is batch-parallel, so this only
    pins layout — numerics are unchanged.
    """
    ax = _cp_axis(mesh, axis)
    cp = dict(mesh.shape)[ax]
    B, S, H, D = q.shape
    KV = k.shape[2]
    assert S % cp == 0, (S, cp)
    if mode == "ulysses" and (H % cp or KV % cp):
        # MQA / odd head counts can't head-shard: fall back to KV gather
        mode = "allgather"

    b_ax = None
    if batch_axes:
        nb = axis_size(mesh, batch_axes)
        if nb > 1 and B % nb == 0:
            b_ax = batch_axes
    spec = P(b_ax, ax, None, None)
    shard_len = S // cp

    def local(ql, kl, vl):
        idx = jax.lax.axis_index(ax)
        flash = functools.partial(ref.flash_attention_jnp, causal=causal,
                                  window=window, scale=scale,
                                  block_q=block_q, block_kv=block_kv)
        if mode == "allgather":
            kg = jax.lax.all_gather(kl, ax, axis=1, tiled=True)
            vg = jax.lax.all_gather(vl, ax, axis=1, tiled=True)
            return flash(ql, kg, vg, q_offset=idx * shard_len)
        # ulysses: seq-sharded -> head-sharded (full sequence per device)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=ax,
                                split_axis=2, concat_axis=1, tiled=True)
        o = flash(a2a(ql), a2a(kl), a2a(vl))
        return jax.lax.all_to_all(o, ax, split_axis=1, concat_axis=2,
                                  tiled=True)

    run = shard_map(local, mesh, (spec, spec, spec), spec)
    return run(q, k, v)


def cp_attention_impl(mesh, *, axis: Optional[str] = None,
                      mode: str = "ulysses", batch_axes=None,
                      block_q: int = 512, block_kv: int = 512):
    """Model-pluggable CP attention entry point.

    Returns a callable with the ``repro.models.attention.attention_impl``
    contract — ``impl(q, k, v, *, causal, window, segment_q, segment_kv,
    scale)`` — that runs :func:`cp_attention` over this mesh's CP axis.
    ``build_train_step`` installs it when the section mesh has a
    non-trivial ``seq`` axis, which is how ``ParallelConfig.cp > 1``
    reaches every self-attention call of the model."""
    def impl(q, k, v, *, causal=True, window=0, segment_q=None,
             segment_kv=None, scale=None):
        if segment_q is not None or segment_kv is not None:
            raise NotImplementedError(
                "cp_attention: packed-sequence segment ids are not "
                "supported under context parallelism")
        if q.shape[1] != k.shape[1]:
            raise NotImplementedError(
                "cp_attention: cross-attention (S_q != S_kv) is not "
                "supported under context parallelism")
        return cp_attention(q, k, v, mesh, axis=axis, mode=mode,
                            causal=causal, window=window, scale=scale,
                            block_q=block_q, block_kv=block_kv,
                            batch_axes=batch_axes)
    return impl
