"""Context-parallel attention over the CP mesh axis (paper §3.2: the
``cp`` knob of a section's ``C^s``).

Long-sequence sections (ViT over visual tokens, 500K-token decode hosts)
shard the *sequence* across devices.  Three exact execution modes, all
dispatched through the kernel substrate (``repro.kernels.ops``) so the
Pallas flash kernel — or its interpret/ref tiers — runs inside the shard:

* ``ulysses``     — DeepSpeed-Ulysses style: all-to-all reshards
  [B, S/cp, H, D] → [B, S, H/cp, D], runs full-sequence flash attention on
  a head slice, and all-to-alls back.  Comm is O(S·H·D/cp) per device;
  requires ``H % cp == 0`` and ``KV % cp == 0``.  With
  ``overlap_chunks = c > 1`` the K/V a2as are issued per KV chunk and the
  partial flash outputs are merged online-softmax-exactly
  (``merge_flash_partials``): total wire bytes are unchanged but each
  collective shrinks ÷c, so on real hardware the chunk-j+1 a2a overlaps
  the chunk-j flash compute.  The a2a of a chunked *local* shard
  interleaves per-device sub-slices, so the gathered chunk's global
  positions are strided — the flash kernels take them as an explicit
  ``kv_positions`` operand, keeping causal/window masking exact.
* ``ulysses_mqa`` — head-replicated ulysses for GQA/MQA sections where
  ``KV % cp != 0``: replicate each KV head ``r = cp / gcd(KV, cp)`` times
  (so they head-shard) and run plain ulysses a2as.  Per-device wire is
  (2H/cp + 2KV/gcd)·(cp−1)/cp·B·S·D·itemsize vs the allgather mode's
  2KV·(…) — cheaper iff H/(cp·KV) + 1/gcd(KV, cp) < 1, so ``auto``
  consults the roofline comm model rather than assuming (for pure MQA,
  KV = 1, replication never wins and allgather stays optimal).
* ``allgather``   — keeps Q sequence-sharded and all-gathers K/V; the
  causal mask is offset per shard.

All modes are numerically exact (checked against the naive reference in
``tests/drivers/driver_pipeline_cp.py``, forward and backward) and
differentiable — the flash custom-VJPs recompute inside the shard, so the
backward pass reuses the same collectives (transposed) the forward issued;
the chunked path additionally differentiates through the lse merge via the
``(do, dlse)``-aware VJP.

End-to-end wiring: ``repro.train.step.build_train_step`` dispatches on the
mesh — a non-trivial ``seq`` axis (``ParallelConfig.cp > 1``) installs
:func:`cp_attention_impl` as the model's full-sequence attention
implementation via ``repro.models.attention.attention_impl``, threading
``ParallelConfig.cp_impl`` / ``cp_mode`` / ``cp_overlap_chunks`` and the
installing section's name (for error attribution).  The shard_map is
manual over the ``seq`` (and optionally batch/data) axes only; any other
mesh axes are replicated *inside* the attention body while the surrounding
computation stays GSPMD-sharded — exact in all compositions (cp×tp, dp×cp).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import AXIS_MODEL, AXIS_SEQ, axis_size, shard_map
from repro.kernels import ops as kops
from repro.kernels.flash_attention import merge_flash_partials

CP_MODES = ("auto", "ulysses", "ulysses_mqa", "allgather")


def _cp_axis(mesh, axis: Optional[str]) -> str:
    if axis is not None:
        return axis
    if AXIS_SEQ in mesh.axis_names and dict(mesh.shape)[AXIS_SEQ] > 1:
        return AXIS_SEQ
    return AXIS_SEQ if AXIS_SEQ in mesh.axis_names else AXIS_MODEL


def _ulysses_ok(H: int, KV: int, cp: int) -> bool:
    return H % cp == 0 and KV % cp == 0


def _mqa_ok(H: int, KV: int, cp: int) -> bool:
    if H % cp or H % KV:
        return False
    r = cp // math.gcd(KV, cp)
    return (H // KV) % r == 0


def resolve_cp_mode(mode: str, *, H: int, KV: int, cp: int,
                    section: Optional[str] = None) -> str:
    """Resolve ``auto`` to a concrete CP attention mode; validate explicit
    modes against the head counts (no silent fallbacks — a requested mode
    that can't run is a config error, attributed to ``section``)."""
    where = f" (section {section!r})" if section else ""
    if mode not in CP_MODES:
        raise ValueError(f"cp_attention{where}: unknown mode {mode!r}, "
                         f"expected one of {CP_MODES}")
    if cp == 1:
        return "ulysses"            # degenerate: no resharding either way
    if mode == "auto":
        if _ulysses_ok(H, KV, cp):
            return "ulysses"
        from repro.roofline.analysis import cp_attention_comm
        ag = cp_attention_comm("allgather", H=H, KV=KV, D=1, cp=cp, S=cp)
        if _mqa_ok(H, KV, cp):
            mqa = cp_attention_comm("ulysses_mqa", H=H, KV=KV, D=1,
                                    cp=cp, S=cp)
            if mqa["wire_bytes"] < ag["wire_bytes"]:
                return "ulysses_mqa"
        return "allgather"
    if mode == "ulysses" and not _ulysses_ok(H, KV, cp):
        raise ValueError(
            f"cp_attention{where}: mode='ulysses' needs H % cp == 0 and "
            f"KV % cp == 0, got H={H}, KV={KV}, cp={cp} — use "
            f"'ulysses_mqa', 'allgather', or 'auto'")
    if mode == "ulysses_mqa" and not _mqa_ok(H, KV, cp):
        raise ValueError(
            f"cp_attention{where}: mode='ulysses_mqa' needs H % cp == 0 "
            f"and cp/gcd(KV, cp) to divide H/KV, got H={H}, KV={KV}, "
            f"cp={cp}")
    return mode


def cp_attention(q, k, v, mesh, *, axis: Optional[str] = None,
                 mode: str = "auto", causal: bool = True,
                 window: int = 0, scale: Optional[float] = None,
                 block_q: int = 512, block_kv: int = 512,
                 batch_axes=None, impl: str = "auto",
                 overlap_chunks: int = 1,
                 section: Optional[str] = None):
    """Context-parallel GQA attention.

    q [B, S, H, D]; k, v [B, S, KV, D] — logically full-sequence arrays
    whose sequence dim is (or will be, via the in_specs) sharded over the
    CP axis.  Returns [B, S, H, D] with the same layout as q.

    impl — kernel tier for the in-shard flash calls
    (``repro.kernels.ops`` dispatch: auto/pallas/pallas_interpret/ref).
    overlap_chunks — ulysses only: issue the K/V a2as in this many
    per-chunk collectives and merge partial flash outputs (exact); must
    divide S/cp.  Ignored by the allgather/ulysses_mqa modes (their K/V
    movement has no chunkable a2a chain).
    batch_axes — mesh axes (name or tuple) to keep the batch dim sharded
    over inside the shard_map (the dp axes of a section mesh); ignored when
    B doesn't divide them.  Attention is batch-parallel, so this only
    pins layout — numerics are unchanged.
    """
    ax = _cp_axis(mesh, axis)
    cp = dict(mesh.shape)[ax]
    B, S, H, D = q.shape
    KV = k.shape[2]
    assert S % cp == 0, (S, cp)
    mode = resolve_cp_mode(mode, H=H, KV=KV, cp=cp, section=section)
    where = f" (section {section!r})" if section else ""
    shard_len = S // cp
    chunks = int(overlap_chunks)
    if chunks < 1:
        raise ValueError(f"cp_attention{where}: overlap_chunks={chunks} "
                         f"must be >= 1")
    if mode != "ulysses":
        chunks = 1
    if shard_len % chunks:
        raise ValueError(
            f"cp_attention{where}: overlap_chunks={chunks} must divide "
            f"the local sequence shard S/cp={shard_len}")

    b_ax = None
    if batch_axes:
        nb = axis_size(mesh, batch_axes)
        if nb > 1 and B % nb == 0:
            b_ax = batch_axes
    spec = P(b_ax, ax, None, None)

    flash = functools.partial(kops.flash_attention, causal=causal,
                              window=window, scale=scale, impl=impl,
                              block_q=block_q, block_kv=block_kv)

    def local(ql, kl, vl):
        a2a = functools.partial(jax.lax.all_to_all, axis_name=ax,
                                split_axis=2, concat_axis=1, tiled=True)
        a2a_back = functools.partial(jax.lax.all_to_all, axis_name=ax,
                                     split_axis=1, concat_axis=2,
                                     tiled=True)
        if mode == "allgather":
            idx = jax.lax.axis_index(ax)
            kg = jax.lax.all_gather(kl, ax, axis=1, tiled=True)
            vg = jax.lax.all_gather(vl, ax, axis=1, tiled=True)
            return flash(ql, kg, vg, q_offset=idx * shard_len)
        if mode == "ulysses_mqa":
            # replicate KV heads so they head-shard, then plain ulysses
            r = cp // math.gcd(KV, cp)
            kr = jnp.repeat(kl, r, axis=2)
            vr = jnp.repeat(vl, r, axis=2)
            o = flash(a2a(ql), a2a(kr), a2a(vr))
            return a2a_back(o)
        # ulysses: seq-sharded -> head-sharded (full sequence per device)
        qh = a2a(ql)
        if chunks == 1:
            o = flash(qh, a2a(kl), a2a(vl))
            return a2a_back(o)
        # overlap-pipelined: per-chunk K/V a2as + partial flash, merged
        # online-softmax-exactly.  Chunk j of every device's local shard
        # lands interleaved after the a2a — sub-slice of device d sits at
        # global positions d·(S/cp) + j·cl + [0, cl) — hence kv_positions.
        cl = shard_len // chunks
        parts_o, parts_lse = [], []
        for j in range(chunks):
            kj = a2a(jax.lax.slice_in_dim(kl, j * cl, (j + 1) * cl,
                                          axis=1))
            vj = a2a(jax.lax.slice_in_dim(vl, j * cl, (j + 1) * cl,
                                          axis=1))
            pos = (np.arange(cp)[:, None] * shard_len + j * cl
                   + np.arange(cl)[None, :]).reshape(-1)
            oj, lse_j = kops.flash_attention_lse(
                qh, kj, vj, causal=causal, window=window, scale=scale,
                kv_positions=jnp.asarray(pos, jnp.int32), impl=impl,
                block_q=block_q, block_kv=block_kv)
            parts_o.append(oj)
            parts_lse.append(lse_j)
        o, _ = merge_flash_partials(parts_o, parts_lse)
        return a2a_back(o)

    run = shard_map(local, mesh, (spec, spec, spec), spec)
    return run(q, k, v)


def cp_attention_impl(mesh, *, axis: Optional[str] = None,
                      mode: str = "auto", batch_axes=None,
                      block_q: int = 512, block_kv: int = 512,
                      impl: str = "auto", overlap_chunks: int = 1,
                      section: Optional[str] = None):
    """Model-pluggable CP attention entry point.

    Returns a callable with the ``repro.models.attention.attention_impl``
    contract — ``impl(q, k, v, *, causal, window, segment_q, segment_kv,
    scale)`` — that runs :func:`cp_attention` over this mesh's CP axis.
    ``build_train_step`` installs it when the section mesh has a
    non-trivial ``seq`` axis, which is how ``ParallelConfig.cp > 1``
    reaches every self-attention call of the model.  ``section`` names the
    installing section in unsupported-feature errors."""
    where = f" (section {section!r})" if section else ""

    def _impl(q, k, v, *, causal=True, window=0, segment_q=None,
              segment_kv=None, scale=None):
        if segment_q is not None or segment_kv is not None:
            raise NotImplementedError(
                f"cp_attention{where}: packed-sequence segment ids are "
                f"not supported under context parallelism")
        if q.shape[1] != k.shape[1]:
            raise NotImplementedError(
                f"cp_attention{where}: cross-attention (S_q != S_kv) is "
                f"not supported under context parallelism")
        return cp_attention(q, k, v, mesh, axis=axis, mode=mode,
                            causal=causal, window=window, scale=scale,
                            block_q=block_q, block_kv=block_kv,
                            batch_axes=batch_axes, impl=impl,
                            overlap_chunks=overlap_chunks,
                            section=section)
    return _impl
