"""Distributed-parallelism layer: sharding rules, context parallelism, PP.

This package is the single place where the paper's per-section parallelism
configuration ``C^s = (DP, TP, PP, CP, mbs)`` (§3.2) meets physical JAX
meshes:

* :mod:`repro.dist.sharding` — logical-axis → mesh-axis rules, the axis
  naming contract, and every ``NamedSharding`` tree the step builders use;
* :mod:`repro.dist.context`  — context-parallel attention over the CP axis;
* :mod:`repro.dist.pipeline` — stage-partitioned (GPipe) loss for PP.

Dispatch: ``repro.train.step.parallel_regime`` routes a section's config
end-to-end — mesh ``pipe`` axis > 1 → :func:`pipeline.build_pp_loss`,
mesh ``seq`` axis > 1 → :func:`context.cp_attention` (installed as the
model attention impl); mismatched or unsupported configs raise rather
than silently training with those axes replicated.
"""
from repro.dist import context, pipeline, sharding  # noqa: F401
