"""Distributed-parallelism layer: sharding rules, context parallelism, PP.

This package is the single place where the paper's per-section parallelism
configuration ``C^s = (DP, TP, PP, CP, mbs)`` (§3.2) meets physical JAX
meshes:

* :mod:`repro.dist.sharding` — logical-axis → mesh-axis rules, the axis
  naming contract, and every ``NamedSharding`` tree the step builders use;
* :mod:`repro.dist.context`  — context-parallel attention over the CP axis;
* :mod:`repro.dist.pipeline` — stage-partitioned (GPipe) loss for PP.
"""
from repro.dist import context, pipeline, sharding  # noqa: F401
