"""Deterministic synthetic data: token streams and mixed-modality batches.

The multimodal generator reproduces the data regime the paper targets
(§2.1/§4.1): a vision:text sample mix (Kimi-K2.5 uses 1:9, LongCat 1:2);
text-only samples bypass the vision section entirely.  Each sample carries
metadata (``has_image``, visual-token count) from which the cost model
builds the scheduler 6-tuples.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List

import jax.numpy as jnp
import numpy as np


def _lm_ngram_tokens(rng: np.random.Generator, batch: int, seq: int,
                     vocab: int) -> np.ndarray:
    """Markov-ish synthetic tokens so cross-entropy is *learnable* (loss
    decreases in examples/tests): token t+1 = (a·t + b) mod vocab with
    per-sequence (a, b) plus noise."""
    a = rng.integers(1, 17, (batch, 1))
    b = rng.integers(0, vocab, (batch, 1))
    t0 = rng.integers(0, vocab, (batch, 1))
    toks = [t0]
    for _ in range(seq):
        nxt = (a * toks[-1] + b) % vocab
        flip = rng.random((batch, 1)) < 0.1
        noise = rng.integers(0, vocab, (batch, 1))
        toks.append(np.where(flip, noise, nxt))
    arr = np.concatenate(toks, axis=1)
    return arr


def lm_batches(*, batch: int, seq_len: int, vocab: int, seed: int = 0
               ) -> Iterator[Dict[str, jnp.ndarray]]:
    rng = np.random.default_rng(seed)
    while True:
        toks = _lm_ngram_tokens(rng, batch, seq_len, vocab)
        yield {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
            "loss_mask": jnp.ones((batch, seq_len), jnp.float32),
        }


def routed_lm_batches(*, batch: int, seq_len: int, vocab: int,
                      specialist_ratio: float = 0.3, seed: int = 0
                      ) -> Iterator[Dict[str, jnp.ndarray]]:
    """LM batches with a per-sample ``domain`` flag (1 = specialist
    domain): the data-dependent activation signal for multi-teacher
    distillation — specialist-domain samples route to the second teacher
    section, everything else bypasses it entirely."""
    rng = np.random.default_rng(seed)
    while True:
        toks = _lm_ngram_tokens(rng, batch, seq_len, vocab)
        domain = (rng.random(batch) < specialist_ratio).astype(np.int32)
        yield {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
            "loss_mask": jnp.ones((batch, seq_len), jnp.float32),
            "domain": jnp.asarray(domain),
        }


@dataclass
class MultimodalSample:
    has_image: bool
    image_tokens: int          # visual tokens consumed by the LM
    vit_patches: int           # raw patches the ViT section processes


def sample_modalities(rng: np.random.Generator, n: int, *,
                      vision_ratio: float, image_tokens: int,
                      downsample: int = 4) -> List[MultimodalSample]:
    out = []
    for _ in range(n):
        has = rng.random() < vision_ratio
        out.append(MultimodalSample(
            has, image_tokens if has else 0,
            image_tokens * downsample if has else 0))
    return out


def vlm_batches(*, batch: int, seq_len: int, vocab: int, vision_ratio: float,
                image_tokens: int, patch_dim: int, downsample: int = 4,
                seed: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    """Mixed text/vision batches.

    Emits LM inputs plus the ViT-section inputs (raw patches) and static-
    capacity image slots (image_pos/image_valid) for the backbone."""
    rng = np.random.default_rng(seed)
    K = image_tokens
    while True:
        toks = _lm_ngram_tokens(rng, batch, seq_len, vocab)
        modal = sample_modalities(rng, batch, vision_ratio=vision_ratio,
                                  image_tokens=K, downsample=downsample)
        has = np.array([m.has_image for m in modal])
        patches = rng.standard_normal(
            (batch, K * downsample, patch_dim)).astype(np.float32)
        patches[~has] = 0.0
        pos = np.tile(np.arange(K)[None], (batch, 1))  # images lead the seq
        valid = np.tile(has[:, None], (1, K)).astype(np.int32)
        mask = np.ones((batch, seq_len), np.float32)
        mask[has, :K] = 0.0              # no LM loss on image positions
        yield {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
            "loss_mask": jnp.asarray(mask),
            "patches": jnp.asarray(patches, jnp.bfloat16),
            "image_pos": jnp.asarray(pos, jnp.int32),
            "image_valid": jnp.asarray(valid, jnp.int32),
            "has_image": jnp.asarray(has.astype(np.int32)),
        }


def audio_batches(*, batch: int, seq_len: int, vocab: int, frames: int,
                  frame_dim: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    while True:
        toks = _lm_ngram_tokens(rng, batch, seq_len, vocab)
        fr = rng.standard_normal((batch, frames, frame_dim))
        yield {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
            "loss_mask": jnp.ones((batch, seq_len), jnp.float32),
            "frames": jnp.asarray(fr, jnp.bfloat16),
        }
