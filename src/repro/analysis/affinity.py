"""Mesh-thread affinity checker (analysis pass ``affinity``).

XLA CPU deadlocks when two host threads interleave collective launches
on one device set — the rule the whole disaggregated runtime is built
around is therefore *one launching thread per section mesh*.  This pass
turns that prose CAUTION into a machine check, from two directions:

* **static wiring** (:func:`check_wiring`) — from a runtime's carved
  meshes and workers: every section has exactly one worker thread
  (named ``section-<name>``, alive), and no two section meshes share a
  device — overlapping device sets are exactly the configuration where
  two workers can interleave collective launches on one device set;
* **dispatch trace** (:func:`tracking` / :func:`check_trace`) — a cheap
  record, taken inside the executor's task wrapper, of which thread
  executed each section's dispatches; the check proves every dispatch
  of a section ran on that section's own worker thread (the
  ``SectionWorker`` run loop marks its thread, so main-thread or
  cross-worker execution is attributed precisely).

``MaestroRuntime`` wiring always satisfies the static check by
construction (``carve_sections`` slices disjoint device ranges); the
value is rejecting *hand-wired* runtimes and regressions loudly at
build time, and proving the dynamic property on real executions in
tests.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import AnalysisReport, Severity, register

# ---------------------------------------------------------------------------
# dispatch-trace mode: enabled by the `tracking()` context manager; the
# executor's task wrapper calls `record()` per executed dispatch (a
# no-op when tracing is off — one truthiness check on the hot path).
# ---------------------------------------------------------------------------
_trace_lock = threading.Lock()
_trace: Optional[List[Tuple[str, str, Optional[str]]]] = None

#: thread-local marker set by SectionWorker._run: which section's worker
#: this thread is (None on the main thread / foreign threads)
worker_section = threading.local()


def record(section: str) -> None:
    """Record one executed dispatch: (section, thread name, owning
    worker section).  Called by the executor wrapper; no-op unless
    :func:`tracking` is active."""
    if _trace is None:
        return
    t = threading.current_thread()
    owner = getattr(worker_section, "name", None)
    with _trace_lock:
        if _trace is not None:
            _trace.append((section, t.name, owner))


@contextlib.contextmanager
def tracking():
    """Enable the dispatch trace; yields the live trace list."""
    global _trace
    with _trace_lock:
        prev, _trace = _trace, []
        trace = _trace
    try:
        yield trace
    finally:
        with _trace_lock:
            _trace = prev


def check_trace(trace: List[Tuple[str, str, Optional[str]]]
                ) -> AnalysisReport:
    """Verify every recorded dispatch of a section ran on that section's
    own worker thread — the dynamic half of the one-thread-per-mesh
    rule."""
    rep = AnalysisReport("affinity")
    by_section: Dict[str, Set[Tuple[str, Optional[str]]]] = {}
    for section, thread, owner in trace:
        by_section.setdefault(section, set()).add((thread, owner))
    for section, launchers in sorted(by_section.items()):
        bad = [(t, o) for t, o in launchers if o != section]
        if bad:
            who = ", ".join(
                f"thread {t!r}" + (f" (worker of {o!r})" if o else
                                   " (not a section worker)")
                for t, o in sorted(bad))
            rep.add(Severity.ERROR, "affinity.foreign-thread", section,
                    f"dispatches of section {section!r} executed on "
                    f"{who} — every collective-bearing program of a "
                    "section mesh must launch from that section's one "
                    "SectionWorker (XLA CPU rendezvous contract)")
        if len(launchers) > 1:
            rep.add(Severity.ERROR, "affinity.multiple-threads", section,
                    f"dispatches of section {section!r} executed on "
                    f"{len(launchers)} distinct threads "
                    f"({sorted(t for t, _ in launchers)})")
        if not bad and len(launchers) == 1:
            rep.add(Severity.INFO, "affinity.trace", section,
                    f"{sum(1 for s, _, _ in trace if s == section)} "
                    f"dispatches, all on {next(iter(launchers))[0]!r}")
    return rep


# ---------------------------------------------------------------------------
# static wiring check
# ---------------------------------------------------------------------------
def _device_ids(mesh) -> Set:
    devs = getattr(mesh, "devices", None)
    if devs is None:
        return set()
    try:
        flat = devs.flatten().tolist()
    except AttributeError:
        flat = list(devs)
    return {getattr(d, "id", d) for d in flat}


@register("affinity")
def check_wiring(runtime) -> AnalysisReport:
    """Static affinity check over a runtime's wiring: disjoint section
    meshes, one live worker per section.  ``runtime`` needs ``meshes``
    (section -> mesh with ``.devices``) and ``workers`` (section ->
    SectionWorker-like); both ``MaestroRuntime`` and ``CompoundRuntime``
    (via ``.rt``) qualify."""
    rt = getattr(runtime, "rt", runtime)
    rep = AnalysisReport("affinity")
    meshes = getattr(rt, "meshes", {})
    workers = getattr(rt, "workers", {})
    owned: Dict[object, str] = {}
    for name, mesh in meshes.items():
        for dev in sorted(_device_ids(mesh), key=repr):
            if dev in owned:
                rep.add(
                    Severity.ERROR, "affinity.mesh-overlap",
                    f"{owned[dev]}|{name}",
                    f"sections {owned[dev]!r} and {name!r} share device "
                    f"{dev!r}: two worker threads would interleave "
                    "collective launches on one device set (XLA CPU "
                    "deadlock); carve disjoint meshes")
            else:
                owned[dev] = name
    for name in meshes:
        w = workers.get(name)
        if w is None:
            rep.add(Severity.ERROR, "affinity.no-worker", name,
                    f"section {name!r} has a mesh but no worker thread "
                    "— its programs would launch from arbitrary threads")
            continue
        th = getattr(w, "_thread", None)
        if th is not None and not th.is_alive():
            rep.add(Severity.ERROR, "affinity.dead-worker", name,
                    f"section {name!r}'s worker thread is not alive")
    for name in workers:
        if name not in meshes:
            rep.add(Severity.WARNING, "affinity.no-mesh", name,
                    f"worker {name!r} has no carved mesh — nothing to "
                    "check")
    if rep.ok:
        rep.add(Severity.INFO, "affinity.wiring", "runtime",
                f"{len(meshes)} section meshes pairwise disjoint, one "
                "live worker each")
    return rep
