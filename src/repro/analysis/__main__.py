"""Build-time lint entry point: ``python -m repro.analysis``.

Runs the static passes that need no devices and no compilation —
dispatch-graph deadlock (lookahead 0 AND 1, so cross-iteration FIFO
coupling is covered), spec structure via ``WorkloadSpec.validate``, the
donation signature, and schema validation of every committed HLO gate
file — over every registered workload spec, built from reduced configs.

Exit status 1 on any ERROR finding; ``benchmarks/run.py --lint``
delegates here.  The HLO gates themselves need compiled programs and run
in ``benchmarks/bench_step_roofline.py`` and
``tests/drivers/driver_hlo_gates.py``.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import Severity, check_spec, hlo_gates, lint_spec


def build_specs(which: str = "all"):
    """name -> WorkloadSpec for every registered declarative workload,
    built shape-reduced (spec construction only — no mesh, no jit)."""
    from repro.configs import get_config, reduce_config
    from repro.core.types import ParallelConfig
    from repro.distill.multi_teacher import multi_teacher_spec
    from repro.distill.workload import distill_spec
    from repro.mllm.workload import mllm_spec
    from repro.models.vlm import vit_config

    par = ParallelConfig(mbs=2)
    lm = reduce_config(get_config("granite-3-8b"))
    out = {}
    if which in ("all", "distill"):
        out["distill"] = distill_spec(
            lm, lm, teacher_parallel=par, student_parallel=par)
    if which in ("all", "multi_teacher"):
        out["multi_teacher"] = multi_teacher_spec(
            lm, lm, lm, ta_parallel=par, tb_parallel=par, s_parallel=par,
            global_batch=8, seq_len=64, mbs=2)
    if which in ("all", "mllm"):
        vlm_cfg = reduce_config(get_config("pixtral-12b")).replace(
            vision_dim=64, max_image_tokens=8)
        vit = vit_config(num_layers=2, d_model=64, num_heads=4, d_ff=128,
                         patch_dim=32, downsample=4,
                         out_dim=vlm_cfg.vision_dim)
        out["mllm"] = mllm_spec(
            vit, vlm_cfg, vit_parallel=par, lm_parallel=par,
            global_batch=8, seq_len=64, mbs=2)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis over the registered workload specs "
                    "and the committed HLO gate files")
    ap.add_argument("--spec", default="all",
                    choices=("all", "distill", "multi_teacher", "mllm"))
    ap.add_argument("--n-mb", type=int, default=2)
    ap.add_argument("--min-severity", default="info",
                    choices=("info", "warning", "error"))
    ap.add_argument("--gates-dir", default=None,
                    help="override the gate-file directory "
                         "(default: repro/analysis/gates/)")
    args = ap.parse_args(argv)
    min_sev = Severity[args.min_severity.upper()]
    failed = False

    for name, spec in sorted(build_specs(args.spec).items()):
        try:
            spec.validate()
        except (ValueError, AssertionError) as e:
            print(f"[ERROR] spec.validate ({name}): {e}")
            failed = True
            continue
        for lookahead in (0, 1):
            rep = check_spec(spec, n_mb=args.n_mb, lookahead=lookahead)
            rep.passname = f"deadlock:{name}@la{lookahead}"
            print(rep.render(min_severity=min_sev) or rep.summary())
            failed |= not rep.ok
        rep = lint_spec(spec, passname=f"donation:{name}")
        out = rep.render(min_severity=min_sev)
        if out:
            print(out)
        failed |= not rep.ok

    for path in hlo_gates.list_gates(args.gates_dir):
        try:
            gate = hlo_gates.load_gate(path)
        except (ValueError, KeyError) as e:
            print(f"[ERROR] hlo.gate-schema ({path.name}): {e}")
            failed = True
            continue
        if min_sev <= Severity.INFO:
            print(f"[INFO] hlo.gate-schema ({path.name}): "
                  f"{len(gate.checks)} checks over programs "
                  f"{list(gate.programs)}")
    print("ANALYSIS " + ("FAILED" if failed else "OK"))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
