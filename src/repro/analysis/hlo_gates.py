"""Declarative HLO sharding/efficiency gates (analysis pass ``hlo``).

The step-roofline bench and the distributed-step driver used to assert
their FLOP/wire claims with bespoke inline code.  This module turns
those assertions into *data*: a gate file (JSON, one per claim/regime
under ``repro/analysis/gates/``) declares the expected dot-FLOP and
collective profile of a set of compiled post-SPMD HLO programs, and one
engine evaluates any gate against any program dict.  New regimes add a
gate file, not code — and CI runs every gate across the pp/cp/tp and
compressed regimes (``tests/drivers/driver_hlo_gates.py``).

Gate file schema::

    {"name": "...", "description": "...",
     "symbols": {"pp": 4, "vocab": 1024},      # numeric, overridable
     "programs": ["masked", "vp"],             # HLO texts the gate needs
     "checks": [ {"kind": ..., "id": ..., ...}, ... ]}

Check kinds (value/width/target fields take a number or a ``*``/``/``
expression over symbols, e.g. ``"vocab/pp"`` or ``"0.05*pp"``):

* ``dot_flops`` — FLOPs of dots whose output last dim == ``width`` in
  ``program``, compared ``op`` ``value`` (e.g. no full-vocab dots under
  pp: ``width: "vocab", op: "==", value: 0``).
* ``dot_flops_ratio`` — ratio of two such measurements (optionally
  ``num_scale``/``den_scale`` for per-sample normalization) within
  ``rtol`` of ``target`` (e.g. unembed FLOPs drop ``pp``×).
* ``wire_total_ratio`` — total ring-model collective wire bytes of
  ``program`` over ``den_program``, compared ``op`` ``value``.
* ``wire_dtype`` — wire bytes of element dtype ``dtype`` in
  ``program``, compared ``op`` ``value`` (e.g. compressed payloads ship
  as ``u16``/``s8``; ``f32`` stays off the wire).
* ``family_dtype_wire`` — wire bytes of one collective family at one
  dtype; with ``den_program`` the measurement is the ratio against the
  same family+dtype there (e.g. f32 all-reduce ≤ 5% of baseline).
* ``collectives_subset`` — the families executed by ``program`` must be
  within ``allowed`` (the regime's declared collective profile: an
  unexpected all-gather = silent replication).
* ``collective_count`` — number of executed collectives of ``family``
  (all families when omitted) in ``program``, compared ``op`` ``value``
  (e.g. overlap-pipelined CP issues 2 + 2·chunks a2as, and XLA's
  combiner passes must not have re-merged them).
* ``collective_payload_ratio`` — ``agg`` (``min``/``max``) over the
  per-op payload bytes of ``family`` collectives in ``num_program``,
  divided by the same aggregate in ``den_program``, within ``rtol`` of
  ``target`` (e.g. the smallest a2a shrinks ÷chunks under overlap).

Every check yields a Finding (ERROR on failure, INFO with the measured
value on pass) and its measurement is returned keyed by the check id,
so callers (the bench scoreboard) read numbers from the same evaluation
that asserted them.
"""
from __future__ import annotations

import json
import pathlib
import re
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.core import AnalysisReport, Severity, register
from repro.roofline import analysis as ra

GATES_DIR = pathlib.Path(__file__).parent / "gates"

_OPS = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
}

_KINDS = ("dot_flops", "dot_flops_ratio", "wire_total_ratio",
          "wire_dtype", "family_dtype_wire", "collectives_subset",
          "collective_count", "collective_payload_ratio")

_EXPR_RE = re.compile(r"^\s*[\w.]+(\s*[*/]\s*[\w.]+)*\s*$")


@dataclass(frozen=True)
class Gate:
    name: str
    description: str
    symbols: Dict[str, float]
    programs: Tuple[str, ...]
    checks: Tuple[Dict[str, Any], ...]


def resolve(expr: Any, symbols: Dict[str, float]) -> float:
    """Resolve a numeric field: a number, a symbol name, or a left-
    associative ``*``/``/`` chain over symbols and numbers."""
    if isinstance(expr, (int, float)):
        return float(expr)
    if not isinstance(expr, str) or not _EXPR_RE.match(expr):
        raise ValueError(f"unresolvable gate expression {expr!r}")
    tokens = re.split(r"([*/])", expr.replace(" ", ""))

    def atom(tok: str) -> float:
        if tok in symbols:
            return float(symbols[tok])
        try:
            return float(tok)
        except ValueError:
            raise ValueError(
                f"unknown symbol {tok!r} in gate expression {expr!r} "
                f"(have {sorted(symbols)})") from None
    val = atom(tokens[0])
    for op, tok in zip(tokens[1::2], tokens[2::2]):
        val = val * atom(tok) if op == "*" else val / atom(tok)
    return val


def validate_gate(raw: Dict[str, Any], source: str = "<gate>") -> None:
    """Schema check, raising ValueError — run by ``--lint`` over every
    committed gate file so a malformed gate fails fast, not mid-CI."""
    for key in ("name", "description", "programs", "checks"):
        if key not in raw:
            raise ValueError(f"{source}: gate is missing {key!r}")
    symbols = dict(raw.get("symbols", {}))
    for k, v in symbols.items():
        if not isinstance(v, (int, float)):
            raise ValueError(f"{source}: symbol {k!r} is not numeric")
    programs = set(raw["programs"])
    for i, chk in enumerate(raw["checks"]):
        where = f"{source}: checks[{i}]"
        kind = chk.get("kind")
        if kind not in _KINDS:
            raise ValueError(f"{where}: unknown kind {kind!r} "
                             f"(expected one of {_KINDS})")
        refs = [chk.get("program"), chk.get("num_program"),
                chk.get("den_program")]
        for p in refs:
            if p is not None and p not in programs:
                raise ValueError(
                    f"{where}: references program {p!r} not declared in "
                    f"programs {sorted(programs)}")
        if kind in ("dot_flops", "wire_dtype", "family_dtype_wire",
                    "wire_total_ratio", "collective_count"):
            if chk.get("op") not in _OPS:
                raise ValueError(f"{where}: op {chk.get('op')!r} not in "
                                 f"{sorted(_OPS)}")
            resolve(chk.get("value", None), symbols)
        if kind == "collective_payload_ratio":
            resolve(chk.get("target", None), symbols)
            if chk.get("agg", "min") not in ("min", "max"):
                raise ValueError(f"{where}: agg {chk.get('agg')!r} must "
                                 "be 'min' or 'max'")
            if not isinstance(chk.get("family"), str):
                raise ValueError(f"{where}: collective_payload_ratio "
                                 "needs a 'family' string")
        if kind == "dot_flops":
            resolve(chk.get("width", None), symbols)
        if kind == "dot_flops_ratio":
            resolve(chk.get("target", None), symbols)
            resolve(chk.get("num_width", None), symbols)
            resolve(chk.get("den_width", None), symbols)
            for s in ("num_scale", "den_scale"):
                if s in chk:
                    resolve(chk[s], symbols)
        if kind == "collectives_subset" and not isinstance(
                chk.get("allowed"), list):
            raise ValueError(f"{where}: collectives_subset needs an "
                             "'allowed' family list")


def load_gate(path) -> Gate:
    raw = json.loads(pathlib.Path(path).read_text())
    validate_gate(raw, source=str(path))
    return Gate(raw["name"], raw["description"],
                {k: float(v) for k, v in raw.get("symbols", {}).items()},
                tuple(raw["programs"]), tuple(raw["checks"]))


def list_gates(directory=None) -> List[pathlib.Path]:
    d = pathlib.Path(directory) if directory else GATES_DIR
    return sorted(d.glob("*.json"))


@register("hlo")
def evaluate(gate: Gate, programs: Dict[str, str], *,
             symbols: Optional[Dict[str, float]] = None
             ) -> Tuple[AnalysisReport, Dict[str, float]]:
    """Evaluate one gate against named HLO texts.  ``symbols`` overrides
    the gate's symbol table (so one gate serves both the bench config
    and a driver's reduced config).  Returns (report, measurements by
    check id)."""
    syms = {**gate.symbols, **(symbols or {})}
    rep = AnalysisReport(f"hlo:{gate.name}")
    measured: Dict[str, float] = {}
    for i, chk in enumerate(gate.checks):
        cid = chk.get("id", f"{chk['kind']}#{i}")
        subject = f"{gate.name}/{cid}"
        needed = [p for p in (chk.get("program"), chk.get("num_program"),
                              chk.get("den_program")) if p is not None]
        missing = [p for p in needed if p not in programs]
        if missing:
            rep.add(Severity.ERROR, "hlo.missing-program", subject,
                    f"gate needs program(s) {missing} but the caller "
                    f"supplied {sorted(programs)}")
            continue
        kind = chk["kind"]
        note = chk.get("note", "")
        if kind == "dot_flops":
            width = int(resolve(chk["width"], syms))
            val = ra.dot_flops_matching(programs[chk["program"]], width)
            measured[cid] = val
            want = resolve(chk["value"], syms)
            if _OPS[chk["op"]](val, want):
                rep.add(Severity.INFO, "hlo.dot_flops", subject,
                        f"dot FLOPs at width {width}: {val:.4g} "
                        f"{chk['op']} {want:.4g}")
            else:
                hist = ra.dot_flops_by_width(programs[chk["program"]])
                rep.add(Severity.ERROR, "hlo.dot_flops", subject,
                        f"dot FLOPs at width {width} = {val:.4g}, "
                        f"expected {chk['op']} {want:.4g}"
                        + (f" ({note})" if note else "")
                        + f"; width histogram: "
                        f"{ {k: round(v, 3) for k, v in sorted(hist.items())} }")
        elif kind == "dot_flops_ratio":
            nw = int(resolve(chk["num_width"], syms))
            dw = int(resolve(chk["den_width"], syms))
            num = ra.dot_flops_matching(programs[chk["num_program"]], nw)
            den = ra.dot_flops_matching(programs[chk["den_program"]], dw)
            num *= resolve(chk.get("num_scale", 1), syms)
            den *= resolve(chk.get("den_scale", 1), syms)
            target = resolve(chk["target"], syms)
            rtol = float(chk.get("rtol", 0.1))
            if den == 0:
                rep.add(Severity.ERROR, "hlo.dot_flops_ratio", subject,
                        f"denominator dots at width {dw} measure 0 FLOPs"
                        f" in {chk['den_program']!r}")
                continue
            ratio = num / den
            measured[cid] = ratio
            if (1 - rtol) * target <= ratio <= (1 + rtol) * target:
                rep.add(Severity.INFO, "hlo.dot_flops_ratio", subject,
                        f"ratio {ratio:.3f} within ±{rtol:.0%} of "
                        f"{target:g}")
            else:
                rep.add(Severity.ERROR, "hlo.dot_flops_ratio", subject,
                        f"ratio {ratio:.3f} outside ±{rtol:.0%} of "
                        f"target {target:g}"
                        + (f" ({note})" if note else ""))
        elif kind == "wire_total_ratio":
            num = sum(ra.wire_bytes_by_dtype(
                programs[chk["num_program"]]).values())
            den = sum(ra.wire_bytes_by_dtype(
                programs[chk["den_program"]]).values())
            if den == 0:
                rep.add(Severity.ERROR, "hlo.wire_total_ratio", subject,
                        f"baseline {chk['den_program']!r} has no "
                        "collective wire bytes")
                continue
            ratio = num / den
            measured[cid] = ratio
            want = resolve(chk["value"], syms)
            sev = (Severity.INFO if _OPS[chk["op"]](ratio, want)
                   else Severity.ERROR)
            rep.add(sev, "hlo.wire_total_ratio", subject,
                    f"wire ratio {ratio:.3f} vs {chk['op']} {want:g}"
                    + (f" ({note})" if note and sev else ""))
        elif kind == "wire_dtype":
            wires = ra.wire_bytes_by_dtype(programs[chk["program"]])
            val = wires.get(chk["dtype"], 0.0)
            measured[cid] = val
            want = resolve(chk["value"], syms)
            if _OPS[chk["op"]](val, want):
                rep.add(Severity.INFO, "hlo.wire_dtype", subject,
                        f"{chk['dtype']} wire bytes {val:.4g} "
                        f"{chk['op']} {want:g}")
            else:
                rep.add(Severity.ERROR, "hlo.wire_dtype", subject,
                        f"{chk['dtype']} wire bytes = {val:.4g}, "
                        f"expected {chk['op']} {want:g}"
                        + (f" ({note})" if note else "")
                        + f"; by dtype: "
                        f"{ {k: round(v) for k, v in sorted(wires.items())} }")
        elif kind == "family_dtype_wire":
            def fam_wire(text):
                return sum(op.wire_bytes for op in ra.collective_ops(text)
                           if op.family == chk["family"]
                           and op.dtype == chk["dtype"])
            val = fam_wire(programs[chk["program"]])
            if "den_program" in chk:
                den = fam_wire(programs[chk["den_program"]])
                if den == 0:
                    rep.add(Severity.ERROR, "hlo.family_dtype_wire",
                            subject,
                            f"baseline {chk['den_program']!r} has no "
                            f"{chk['family']} {chk['dtype']} wire bytes")
                    continue
                val = val / den
            measured[cid] = val
            want = resolve(chk["value"], syms)
            sev = (Severity.INFO if _OPS[chk["op"]](val, want)
                   else Severity.ERROR)
            rep.add(sev, "hlo.family_dtype_wire", subject,
                    f"{chk['family']}/{chk['dtype']}"
                    + ("-ratio" if "den_program" in chk else "")
                    + f" = {val:.4g} vs {chk['op']} {want:g}"
                    + (f" ({note})" if note and sev == Severity.ERROR
                       else ""))
        elif kind == "collective_count":
            fam = chk.get("family")
            ops = [op for op in ra.collective_ops(programs[chk["program"]])
                   if fam is None or op.family == fam]
            val = sum(op.count for op in ops)
            measured[cid] = val
            want = resolve(chk["value"], syms)
            label = fam or "all-families"
            if _OPS[chk["op"]](val, want):
                rep.add(Severity.INFO, "hlo.collective_count", subject,
                        f"{label} count {val:g} {chk['op']} {want:g}")
            else:
                by_fam = {}
                for op in ra.collective_ops(programs[chk["program"]]):
                    by_fam[op.family] = by_fam.get(op.family, 0) + op.count
                rep.add(Severity.ERROR, "hlo.collective_count", subject,
                        f"{label} count = {val:g}, expected {chk['op']} "
                        f"{want:g}" + (f" ({note})" if note else "")
                        + f"; by family: {by_fam}")
        elif kind == "collective_payload_ratio":
            fam = chk["family"]
            agg = min if chk.get("agg", "min") == "min" else max

            def fam_payload(text):
                sizes = [op.payload_bytes
                         for op in ra.collective_ops(text)
                         if op.family == fam]
                return agg(sizes) if sizes else None
            num = fam_payload(programs[chk["num_program"]])
            den = fam_payload(programs[chk["den_program"]])
            if num is None or den is None or den == 0:
                rep.add(Severity.ERROR, "hlo.collective_payload_ratio",
                        subject,
                        f"no {fam} collectives to compare "
                        f"(num={num}, den={den})")
                continue
            ratio = num / den
            measured[cid] = ratio
            target = resolve(chk["target"], syms)
            rtol = float(chk.get("rtol", 0.1))
            if (1 - rtol) * target <= ratio <= (1 + rtol) * target:
                rep.add(Severity.INFO, "hlo.collective_payload_ratio",
                        subject,
                        f"{chk.get('agg', 'min')} {fam} payload ratio "
                        f"{ratio:.3f} within ±{rtol:.0%} of {target:g}")
            else:
                rep.add(Severity.ERROR, "hlo.collective_payload_ratio",
                        subject,
                        f"{chk.get('agg', 'min')} {fam} payload ratio "
                        f"{ratio:.3f} outside ±{rtol:.0%} of "
                        f"target {target:g} (num={num:g} B, den={den:g} B)"
                        + (f" ({note})" if note else ""))
        elif kind == "collectives_subset":
            fams = ra.collective_families(programs[chk["program"]])
            extra = sorted(set(fams) - set(chk["allowed"]))
            measured[cid] = float(len(extra))
            if extra:
                rep.add(Severity.ERROR, "hlo.collectives_subset", subject,
                        f"unexpected collective families {extra} "
                        f"(allowed {sorted(chk['allowed'])}; wire bytes "
                        f"{ {k: round(v) for k, v in sorted(fams.items())} })"
                        " — an undeclared all-gather usually means "
                        "silent replication")
            else:
                rep.add(Severity.INFO, "hlo.collectives_subset", subject,
                        f"families {sorted(fams)} ⊆ "
                        f"{sorted(chk['allowed'])}")
    return rep, measured


def evaluate_file(path, programs: Dict[str, str], *,
                  symbols: Optional[Dict[str, float]] = None
                  ) -> Tuple[AnalysisReport, Dict[str, float]]:
    return evaluate(load_gate(path), programs, symbols=symbols)
