"""Dispatch-graph deadlock detector (analysis pass ``deadlock``).

The streaming runtime's only synchronization primitive is the blocking
``MessageQueue.pull`` — a pull *is* the cross-section dependency edge,
and a deadlock is a wait cycle through pulls and per-section worker
FIFOs.  This pass proves, statically from a :class:`WorkloadSpec`, that
the dispatch order ``CompoundRuntime.submit_iteration`` emits can never
enter such a cycle — or reports the cycle, naming every section and
queue edge on it, *before* anything hangs in ``drain()``.

The model mirrors ``submit_iteration`` exactly:

* every section's tasks for one iteration, in per-section FIFO stream
  order — producers ``fwd0..fwdN-1`` (+ ``bwd0..bwdN-1`` when
  trainable), the critical section ``mb0..mbN-1``, then ``upd`` for
  every trainable section;
* each task is an ordered list of *events*: blocking ``pull``\\ s and
  non-blocking ``push``\\ es with the exact queue keys the runtime uses
  (``<scope>/<src>.<port>.<i>``, cotangents ``<scope>/ct.*``, and the
  grad-norm rendezvous ``<scope>/gnorm.<section>`` — pushed to every
  peer BEFORE any peer's vector is pulled, the push-before-pull pattern
  whose deadlock-freedom this pass now machine-checks);
* with ``lookahead > 0`` two consecutive iteration scopes are chained
  onto the same per-section streams, so cross-iteration FIFO coupling
  (``upd(i)`` before ``fwd(i+1)``) is part of the proof obligation.

Wait-graph semantics: an event depends on its predecessor in its
section stream (worker FIFO), and a ``pull`` additionally depends on
the matching ``push`` event.  Pushes never block, so this graph is
acyclic **iff** the workload cannot deadlock under any task timing; a
``pull`` with no matching ``push`` anywhere is a guaranteed hang and is
reported as its own error.

Activation predicates are modeled as all-active: the runtime gates the
push and the pull of an edge on the *same* dispatched-set membership
(``_dispatched``), so skipping a microbatch removes push/pull pairs
symmetrically and can only delete edges from the all-active graph —
never add one.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.analysis.core import AnalysisReport, Severity, register


@dataclass(frozen=True)
class Event:
    """One queue operation of one task: ``kind`` is ``"pull"`` or
    ``"push"``; ``src``/``dst`` the channel; ``key`` the scoped queue
    key.  ``section``/``task`` locate it on its worker stream."""
    section: str
    task: str
    kind: str
    src: str
    dst: str
    key: str

    def label(self) -> str:
        return (f"{self.section}:{self.task} {self.kind}"
                f"[{self.src}->{self.dst} {self.key}]")


def model_events(spec, n_mb: int, scopes: Sequence[str]
                 ) -> Dict[str, List[Event]]:
    """Per-section event streams (worker FIFO order) for ``scopes``
    consecutive iteration scopes of ``spec`` — the static mirror of
    ``CompoundRuntime.submit_iteration``."""
    by_name = {s.name: s for s in spec.sections}
    crits = [s.name for s in spec.sections if s.critical]
    crit = crits[0] if len(crits) == 1 else None
    trainable = [s.name for s in spec.sections if s.trainable]
    chains: Dict[str, List[Event]] = {s.name: [] for s in spec.sections}

    def pulls_consumed(s, it: str, i: int, task: str) -> List[Event]:
        return [Event(s.name, task, "pull", c.section, s.name,
                      f"{it}/{c.key}.{i}") for c in s.consumes]

    def ct_pushes(s, it: str, i: int, task: str) -> List[Event]:
        return [Event(s.name, task, "push", s.name, c.section,
                      f"{it}/ct.{c.key}.{i}") for c in s.consumes
                if by_name[c.section].trainable]

    for it in scopes:
        # producers' fwd tasks (pull consumed ports, push emitted ports)
        for s in spec.sections:
            if s.name == crit:
                continue
            for i in range(n_mb):
                tag = f"fwd{i}"
                ev = pulls_consumed(s, it, i, tag)
                for p in s.emits:
                    for cname in spec.consumers_of(s.name, p.name):
                        ev.append(Event(s.name, tag, "push", s.name,
                                        cname,
                                        f"{it}/{s.name}.{p.name}.{i}"))
                chains[s.name].extend(ev)
        # critical section's loss+grad tasks (pull ports, push cotangents)
        if crit is not None:
            s = by_name[crit]
            for i in range(n_mb):
                tag = f"mb{i}"
                chains[crit].extend(pulls_consumed(s, it, i, tag))
                chains[crit].extend(ct_pushes(s, it, i, tag))
        # trainable producers' bwd tasks (pull own cotangent, push
        # cotangents for their consumed trainable ports)
        for s in spec.sections:
            if s.name == crit or not s.trainable:
                continue
            for i in range(n_mb):
                tag = f"bwd{i}"
                for p in s.emits:
                    cons = spec.consumers_of(s.name, p.name)
                    for cname in cons[:1]:   # bwd pulls ONE cotangent
                        chains[s.name].append(Event(
                            s.name, tag, "pull", cname, s.name,
                            f"{it}/ct.{s.name}.{p.name}.{i}"))
                chains[s.name].extend(ct_pushes(s, it, i, tag))
        # grad-norm rendezvous: push to every peer BEFORE pulling any
        for name in trainable:
            peers = [n for n in trainable if n != name]
            chains[name].extend(
                Event(name, "upd", "push", name, p, f"{it}/gnorm.{name}")
                for p in peers)
            chains[name].extend(
                Event(name, "upd", "pull", p, name, f"{it}/gnorm.{p}")
                for p in peers)
    return chains


def check_events(chains: Dict[str, List[Event]],
                 passname: str = "deadlock") -> AnalysisReport:
    """Generic wait-graph check over per-section event streams: FIFO
    edges within each stream, push→pull edges across them.  Reports
    unsatisfiable pulls and wait cycles (each named edge by edge)."""
    rep = AnalysisReport(passname)
    events: List[Event] = []
    index: Dict[int, int] = {}
    for chain in chains.values():
        for ev in chain:
            index[id(ev)] = len(events)
            events.append(ev)
    n = len(events)
    adj: List[List[int]] = [[] for _ in range(n)]
    # worker-FIFO edges: an event waits for its stream predecessor
    for chain in chains.values():
        for a, b in zip(chain, chain[1:]):
            adj[index[id(a)]].append(index[id(b)])
    # push → pull matching on (src, dst, key)
    pushes: Dict[Tuple[str, str, str], List[int]] = {}
    for i, ev in enumerate(events):
        if ev.kind == "push":
            pushes.setdefault((ev.src, ev.dst, ev.key), []).append(i)
    for key, idxs in pushes.items():
        if len(idxs) > 1:
            rep.add(Severity.WARNING, "deadlock.duplicate-push",
                    f"{key[0]}->{key[1]}",
                    f"key {key[2]!r} is pushed {len(idxs)} times on one "
                    "edge — the queue would overwrite fragments")
    for i, ev in enumerate(events):
        if ev.kind != "pull":
            continue
        match = pushes.get((ev.src, ev.dst, ev.key))
        if not match:
            rep.add(Severity.ERROR, "deadlock.unsatisfied-pull",
                    f"{ev.src}->{ev.dst}",
                    f"{ev.label()} has no matching push anywhere in the "
                    f"dispatch graph — section {ev.section!r} would hang "
                    "in drain() waiting on this edge")
            continue
        for j in match:
            adj[j].append(i)
    # cycle detection (iterative DFS, first cycle reported in full)
    WHITE, GREY, BLACK = 0, 1, 2
    color = [WHITE] * n
    parent = [-1] * n
    cycle: List[int] = []
    for root in range(n):
        if color[root] != WHITE or cycle:
            continue
        stack = [(root, iter(adj[root]))]
        color[root] = GREY
        while stack and not cycle:
            node, it = stack[-1]
            advanced = False
            for nxt in it:
                if color[nxt] == WHITE:
                    color[nxt] = GREY
                    parent[nxt] = node
                    stack.append((nxt, iter(adj[nxt])))
                    advanced = True
                    break
                if color[nxt] == GREY:       # back edge: wait cycle
                    path = [node]
                    cur = node
                    while cur != nxt and parent[cur] != -1:
                        cur = parent[cur]
                        path.append(cur)
                    cycle = path[::-1]       # nxt ... node (wraps to nxt)
                    break
            if not advanced and not cycle:
                color[node] = BLACK
                stack.pop()
    if cycle:
        labels = [events[i].label() for i in cycle]
        secs = sorted({events[i].section for i in cycle})
        rep.add(Severity.ERROR, "deadlock.cycle",
                ",".join(secs),
                "dispatch graph has a wait cycle (blocking pulls + "
                "worker FIFO): " + " -> ".join(labels + [labels[0]]))
    return rep


@register("deadlock")
def check_spec(spec, *, n_mb: int = 2, lookahead: int = 0
               ) -> AnalysisReport:
    """Prove the blocking-pull order of ``spec`` acyclic per iteration
    scope (two chained scopes when ``lookahead > 0``).  ``n_mb=2``
    covers cross-microbatch FIFO coupling; larger values model the same
    edges repeated."""
    rep = AnalysisReport("deadlock")
    names = [s.name for s in spec.sections]
    if len(set(names)) != len(names):
        rep.add(Severity.ERROR, "deadlock.structure", spec.name,
                f"duplicate section names {names} — cannot model "
                "dispatch streams")
        return rep
    crits = [s.name for s in spec.sections if s.critical]
    if len(crits) != 1:
        rep.add(Severity.ERROR, "deadlock.structure", spec.name,
                f"expected exactly one critical section, got {crits}")
        return rep
    known = set(names)
    for s in spec.sections:
        for c in s.consumes:
            if c.section not in known:
                rep.add(Severity.ERROR, "deadlock.structure",
                        f"{c.section}->{s.name}",
                        f"section {s.name!r} consumes from unknown "
                        f"section {c.section!r}")
    if not rep.ok:
        return rep
    scopes = ["s0", "s1"] if lookahead > 0 else ["s0"]
    chains = model_events(spec, max(int(n_mb), 1), scopes)
    rep.extend(check_events(chains))
    return rep
