"""Static-analysis suite for the compound runtime.

Machine-checks the invariants the runtime's correctness rests on, which
used to live as prose CAUTIONs and scattered inline asserts:

* ``deadlock`` — the dispatch graph a :class:`WorkloadSpec` compiles to
  (blocking pulls + per-section worker FIFOs, incl. the grad-norm
  rendezvous and lookahead cross-iteration coupling) is acyclic;
* ``donation`` — no state tree enters two donating trajectories
  (reuse of donated trees, cross-section aliasing, params/master
  aliasing) — caught at ``install()`` instead of deep inside a jit;
* ``affinity`` — one launching thread per section mesh (disjoint carved
  meshes + one live worker statically; a dispatch trace dynamically);
* ``hlo`` — declarative sharding/efficiency gates over compiled
  post-SPMD HLO (gate files under ``repro/analysis/gates/``).

``python -m repro.analysis`` runs the build-time passes over every
registered workload spec and schema-checks the committed gate files;
``benchmarks/run.py --lint`` is the same entry point.  See
``docs/analysis.md`` for the pass catalog and severity model.
"""
from repro.analysis.core import (AnalysisReport, Finding, PASSES, Severity,
                                 register)
from repro.analysis.affinity import check_trace, check_wiring, tracking
from repro.analysis.deadlock import check_events, check_spec, model_events
from repro.analysis.donation import lint_spec, lint_state, lint_step_fn
from repro.analysis.hlo_gates import (evaluate, evaluate_file, list_gates,
                                      load_gate, validate_gate)

__all__ = [
    "AnalysisReport", "Finding", "PASSES", "Severity", "register",
    "check_trace", "check_wiring", "tracking",
    "check_events", "check_spec", "model_events",
    "lint_spec", "lint_state", "lint_step_fn",
    "evaluate", "evaluate_file", "list_gates", "load_gate",
    "validate_gate",
]
