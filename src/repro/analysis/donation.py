"""Donation linter (analysis pass ``donation``).

AdamW donation is *real* on this backend: every worker-side update jit
is compiled with ``donate_argnums`` on its optimizer-state (and
error-feedback) argument, and ``jax.device_put`` is a no-copy identity
when the target sharding already matches.  The failure mode this pass
catches at build time — instead of a crash deep inside a worker jit —
is a state tree entering more than one donating trajectory:

* **reuse** — a params/opt tree that a previous donating step already
  consumed (leaves report ``is_deleted()``) handed back to
  ``install()``;
* **cross-section aliasing** — the *same* buffer appearing in two
  sections' state: section A's ``upd`` donates it, section B's next
  jit reads a dead buffer;
* **params/master aliasing** — an optimizer state whose fp32 master
  leaves alias the live params tree (``adamw.init`` copies exactly to
  prevent this): donating the state would delete the params.

The pass also records the runtime's *donation signature* — which jits
donate which argument — as INFO findings, so the report documents the
state-flow the checks protect (generalizing the point check
``repro.optim.adamw.check_live`` from a single callsite into a lint
over the whole runtime).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax

from repro.analysis.core import AnalysisReport, Severity, register
from repro.optim.adamw import deleted_leaf_paths


def _leaf_ids(tree: Any) -> Dict[int, str]:
    """id -> keypath of every array-like leaf with a real buffer."""
    out: Dict[int, str] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if hasattr(leaf, "dtype") and hasattr(leaf, "shape"):
            out[id(leaf)] = jax.tree_util.keystr(path)
    return out


def _donation_signature(runtime) -> Dict[str, str]:
    """section -> description of its donating jits, read from the
    runtime's jit tables (built with ``donate_argnums`` in
    ``CompoundRuntime``)."""
    sig: Dict[str, str] = {}
    for name in getattr(runtime, "_update", {}):
        parts = ["update(donates: opt state)"]
        if name in getattr(runtime, "_compress_step", {}):
            parts.append("compress_step(donates: EF residual)")
        sig[name] = ", ".join(parts)
    return sig


@register("donation")
def lint_state(params: Dict[str, Any], opts: Dict[str, Any], *,
               runtime=None, passname: str = "donation",
               ef: Optional[Dict[str, Any]] = None) -> AnalysisReport:
    """Lint per-section state trees about to enter the donating update
    trajectory.  ``runtime`` (a ``CompoundRuntime``) is optional and
    only adds the donation-signature INFO findings."""
    rep = AnalysisReport(passname)
    if runtime is not None:
        for name, sig in sorted(_donation_signature(runtime).items()):
            rep.add(Severity.INFO, "donation.signature", name, sig)
    # (1) reuse of an already-donated tree
    for what, trees in (("params", params), ("opts", opts),
                        ("ef", ef or {})):
        for name, tree in trees.items():
            dead = deleted_leaf_paths(tree)
            if dead:
                rep.add(
                    Severity.ERROR, "donation.reuse",
                    f"{what}[{name}]",
                    f"{len(dead)} leaves are deleted (donated) buffers "
                    f"(first: {dead[0]!r}) — this tree was consumed by a "
                    "previous donating update step; re-place fresh state "
                    "(CompoundRuntime.place / jax.device_put of a host "
                    "copy) instead of re-using it")
    # (2) the same buffer in two sections' state (either tree kind):
    # one section's donating upd would delete the other's live state
    seen: Dict[int, str] = {}
    for what, trees in (("opts", opts), ("ef", ef or {})):
        for name, tree in trees.items():
            for lid, path in _leaf_ids(tree).items():
                owner = f"{what}[{name}]{path}"
                if lid in seen and not seen[lid].startswith(
                        f"{what}[{name}]"):
                    rep.add(
                        Severity.ERROR, "donation.cross-section-alias",
                        owner,
                        f"buffer is shared with {seen[lid]} — a donating "
                        "update in either section deletes the other's "
                        "state")
                else:
                    seen.setdefault(lid, owner)
    # (3) optimizer master/mu/nu leaves aliasing the params tree
    for name, opt in opts.items():
        if name not in params:
            continue
        p_ids = _leaf_ids(params[name])
        for lid, path in _leaf_ids(opt).items():
            if lid in p_ids:
                rep.add(
                    Severity.ERROR, "donation.params-alias",
                    f"opts[{name}]{path}",
                    f"optimizer state leaf aliases params[{name}]"
                    f"{p_ids[lid]} — donating the state would delete "
                    "live params (adamw.init copies for exactly this "
                    "reason)")
    return rep


def lint_spec(spec, passname: str = "donation") -> AnalysisReport:
    """Donation signature implied by a :class:`WorkloadSpec` alone —
    which jits the generic runtime will compile with ``donate_argnums``
    for each section.  Pure INFO: documents the state-flow the runtime
    checks protect, without building any runtime (used by the ``--lint``
    CLI)."""
    rep = AnalysisReport(passname)
    for s in spec.sections:
        if not getattr(s, "trainable", False):
            rep.add(Severity.INFO, "donation.signature", s.name,
                    "fwd_only: no donating jits")
            continue
        parts = ["update(donates: opt state)"]
        if getattr(s.parallel, "grad_compress", "none") != "none":
            parts.append("compress_step(donates: EF residual)")
        rep.add(Severity.INFO, "donation.signature", s.name,
                ", ".join(parts))
    return rep


def lint_step_fn(step_fn, passname: str = "donation") -> AnalysisReport:
    """Lint a built train/prefill/decode step's donation metadata
    (``repro.train.step`` attaches ``_donates`` to each jitted step):
    INFO when declared, WARNING for a jitted step with no declaration —
    callers then can't know which arguments not to reuse."""
    rep = AnalysisReport(passname)
    don = getattr(step_fn, "_donates", None)
    label = getattr(step_fn, "_donates_label", type(step_fn).__name__)
    if don is None:
        rep.add(Severity.WARNING, "donation.undeclared", label,
                "jitted step carries no _donates metadata — donation "
                "hazards of its arguments cannot be linted")
    else:
        rep.add(Severity.INFO, "donation.signature", label,
                f"donates argnums {tuple(don)}")
    return rep
