"""Common core of the static-analysis suite: findings, reports, passes.

Every analysis pass — the dispatch-graph deadlock detector, the
mesh-thread affinity checker, the donation linter, the declarative HLO
gates — reports through the same vocabulary:

* :class:`Finding` — one diagnosed fact, with a severity, a stable
  ``check`` identifier (``"deadlock.cycle"``, ``"donation.reuse"``,
  ``"hlo.dot_flops"``, ...), the subject it names (a section, an edge,
  a gate id) and a human message.
* :class:`AnalysisReport` — an ordered list of findings plus helpers to
  partition by severity and to ``raise_on_error`` with a message that
  quotes every error finding (the build-time integration points —
  ``WorkloadSpec.validate`` / ``CompoundRuntime.install`` — use this).
* :data:`PASSES` — the registry mapping pass names to callables; the
  CLI (``python -m repro.analysis``) and ``benchmarks/run.py --lint``
  iterate it instead of hard-coding the pass list.

Severity model (see docs/analysis.md):

* ``ERROR`` — a proven invariant violation: the workload deadlocks, a
  donated buffer is reused, a compiled program pays FLOPs/bytes a gate
  forbids.  Integration points raise; CI fails.
* ``WARNING`` — suspicious but not proven fatal (e.g. a gate whose
  program was not supplied, a pull with an unknown producer mode).
* ``INFO`` — a checked fact recorded for the report (gate measurements,
  donation signatures).  Never fails anything.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


class Severity(enum.IntEnum):
    """Ordered so ``max(findings)`` is the report verdict."""
    INFO = 0
    WARNING = 1
    ERROR = 2


@dataclass(frozen=True)
class Finding:
    """One diagnosed fact about the workload / runtime / compiled HLO."""
    severity: Severity
    check: str                 # stable id, e.g. "deadlock.cycle"
    subject: str               # what it names: section, edge, gate id
    message: str

    def __str__(self) -> str:
        return (f"[{self.severity.name}] {self.check} ({self.subject}): "
                f"{self.message}")


@dataclass
class AnalysisReport:
    """Findings of one pass (or a merge of several)."""
    passname: str
    findings: List[Finding] = field(default_factory=list)

    def add(self, severity: Severity, check: str, subject: str,
            message: str) -> Finding:
        f = Finding(severity, check, subject, message)
        self.findings.append(f)
        return f

    def extend(self, other: "AnalysisReport") -> None:
        self.findings.extend(other.findings)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == Severity.ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == Severity.WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def summary(self) -> str:
        n = {s: 0 for s in Severity}
        for f in self.findings:
            n[f.severity] += 1
        return (f"{self.passname}: {n[Severity.ERROR]} error(s), "
                f"{n[Severity.WARNING]} warning(s), "
                f"{n[Severity.INFO]} info")

    def render(self, *, min_severity: Severity = Severity.INFO) -> str:
        lines = [self.summary()]
        lines += [f"  {f}" for f in self.findings
                  if f.severity >= min_severity]
        return "\n".join(lines)

    def raise_on_error(self, exc_type=ValueError,
                       prefix: Optional[str] = None) -> None:
        """Raise ``exc_type`` quoting every ERROR finding (no-op when
        clean) — the build-time gate used by ``WorkloadSpec.validate``
        and ``CompoundRuntime.install``."""
        errs = self.errors
        if not errs:
            return
        head = prefix or f"{self.passname} failed"
        body = "\n".join(f"  {f}" for f in errs)
        raise exc_type(f"{head}:\n{body}")


#: pass registry: name -> callable returning an AnalysisReport.  The
#: callables take pass-specific arguments; the CLI knows how to drive
#: the registered ones (see repro.analysis.__main__).
PASSES: Dict[str, Callable[..., AnalysisReport]] = {}


def register(name: str):
    """Decorator: register an analysis pass under ``name``."""
    def deco(fn):
        PASSES[name] = fn
        return fn
    return deco
