"""Step builders: jitted train / prefill / decode steps with shardings.

``build_train_step`` produces the exact function the multi-pod dry-run
lowers for ``train_*`` cells; ``build_prefill_step`` / ``build_decode_step``
cover the ``prefill_*`` / ``decode_*`` / ``long_*`` cells.

Microbatching (grad accumulation) follows the per-section ``mbs`` knob from
the paper: the global batch is laid out shard-major ``[dp, n_micro, mbs]``
so the reshape into microbatches is local to every data shard (no
collectives for data staging).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.types import ArchConfig, ParallelConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.models import common as cm
from repro.models.model import Model
from repro.optim import adamw, schedules


def _act_hook_for(mesh: Mesh, batch_size: int, seq_len: int,
                  sequence_parallel: bool = False):
    dp = shd.dp_axes(mesh)
    bspec = shd.batch_spec(mesh, batch_size, seq_len)
    b_ax, s_ax = tuple(bspec)[0], tuple(bspec)[1]
    model_size = mesh.shape.get("model", 1)
    # Megatron-style sequence parallelism: the residual stream between
    # blocks is sequence-sharded over the model axis, turning the per-layer
    # TP all-reduce pair into reduce-scatter + all-gather at half the bytes
    # (and keeping norms local)
    sp_ax = ("model" if sequence_parallel and s_ax is None
             and seq_len % model_size == 0 else s_ax)

    def hook(x, kind):
        if kind == "hidden" and x.ndim == 3:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(b_ax, sp_ax, None)))
        if kind == "logits" and x.ndim == 3:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(b_ax, s_ax, "model")))
        if kind == "attn_q" and x.ndim == 4:
            h_ax = "model" if x.shape[2] % model_size == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(b_ax, s_ax, h_ax, None)))
        if kind == "moe_dispatch" and x.ndim == 4:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(b_ax, None, None, None)))
        return x

    return hook


def num_microbatches(shape: ShapeConfig, mesh: Mesh,
                     parallel: ParallelConfig) -> int:
    dp_total = shd.axis_size(mesh, shd.dp_axes(mesh))
    n = shape.global_batch // (dp_total * parallel.mbs)
    return max(n, 1)


def _split_microbatches(batch: dict, n_micro: int, dp_total: int):
    """[GB, ...] -> [n_micro, GB/n_micro, ...] with shard-major layout so
    the split is local to each data shard."""
    def split(x):
        gb = x.shape[0]
        mgb = gb // n_micro
        per = mgb // dp_total
        if per == 0 or gb % n_micro:
            return jnp.broadcast_to(x[None], (n_micro,) + x.shape)
        y = x.reshape((dp_total, n_micro, per) + x.shape[1:])
        return jnp.swapaxes(y, 0, 1).reshape(
            (n_micro, mgb) + x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def build_train_step(model: Model, mesh: Mesh, parallel: ParallelConfig,
                     shape: ShapeConfig, *, rules=None,
                     lr_schedule=None,
                     opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()):
    """Returns (jitted_step, shardings) — step(params, opt_state, batch,
    step_idx) -> (params, opt_state, metrics)."""
    cfg = model.cfg
    specs = model.specs()
    rules = rules if rules is not None else shd.rules_for(cfg, mesh)
    p_shard = shd.param_shardings(specs, mesh, rules)
    o_shard = shd.opt_state_shardings(specs, mesh, rules,
                                      zero=parallel.zero_opt)
    batch_specs = model.input_specs(shape)
    b_shard = shd.data_shardings(mesh, batch_specs)
    dp_total = shd.axis_size(mesh, shd.dp_axes(mesh))
    n_micro = num_microbatches(shape, mesh, parallel)
    lr_fn = lr_schedule or functools.partial(
        schedules.warmup_cosine, peak_lr=3e-4, warmup_steps=100,
        total_steps=10_000)
    hook = _act_hook_for(mesh, shape.global_batch // n_micro, shape.seq_len,
                         sequence_parallel=parallel.sequence_parallel)
    rep = shd.replicated(mesh)

    def loss_fn(p, mb):
        with cm.act_hook(hook):
            return model.loss(p, mb)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step_idx):
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            mbs_tree = _split_microbatches(batch, n_micro, dp_total)

            def micro(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (g_sum, l_sum), _ = jax.lax.scan(micro, (g0, jnp.float32(0)),
                                             mbs_tree)
            grads = jax.tree_util.tree_map(
                lambda g, p: (g / n_micro).astype(p.dtype), g_sum, params)
            loss = l_sum / n_micro
            metrics = {}
        lr = lr_fn(step_idx)
        new_params, new_opt, gnorm = adamw.update(grads, opt_state, lr,
                                                  opt_cfg)
        out_metrics = {"loss": loss.astype(jnp.float32),
                       "grad_norm": gnorm, "lr": lr}
        return new_params, new_opt, out_metrics

    step = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard, rep),
        out_shardings=(p_shard, o_shard,
                       {"loss": rep, "grad_norm": rep, "lr": rep}),
        donate_argnums=(0, 1))
    shardings = {"params": p_shard, "opt": o_shard, "batch": b_shard}
    return step, shardings


def build_prefill_step(model: Model, mesh: Mesh, shape: ShapeConfig, *,
                       rules=None):
    specs = model.specs()
    rules = rules if rules is not None else shd.rules_for(model.cfg, mesh)
    p_shard = shd.param_shardings(specs, mesh, rules)
    batch_specs = model.input_specs(shape)
    b_shard = shd.data_shardings(mesh, batch_specs)
    cache_specs = model.cache_specs(shape)
    c_shard = shd.cache_shardings(mesh, cache_specs)
    hook = _act_hook_for(mesh, shape.global_batch, shape.seq_len)
    logits_shard = shd.logits_sharding(mesh, shape.global_batch,
                                       model.cfg.padded_vocab)

    def prefill_step(params, batch):
        with cm.act_hook(hook):
            logits, cache = model.prefill(params, batch)
        return logits, cache

    step = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                   out_shardings=(logits_shard, c_shard))
    return step, {"params": p_shard, "batch": b_shard, "cache": c_shard}


def build_decode_step(model: Model, mesh: Mesh, shape: ShapeConfig, *,
                      rules=None):
    """serve_step for decode cells: one new token against a seq_len cache."""
    specs = model.specs()
    rules = rules if rules is not None else shd.rules_for(model.cfg, mesh)
    p_shard = shd.param_shardings(specs, mesh, rules)
    batch_specs = model.input_specs(shape)
    b_shard = shd.data_shardings(mesh, batch_specs)
    cache_specs = model.cache_specs(shape)
    c_shard = shd.cache_shardings(mesh, cache_specs)
    logits_shard = shd.logits_sharding(mesh, shape.global_batch,
                                       model.cfg.padded_vocab)
    rep = shd.replicated(mesh)

    def decode_step(params, cache, token, pos):
        logits, new_cache = model.decode(params, cache, token, pos)
        return logits, new_cache

    step = jax.jit(decode_step,
                   in_shardings=(p_shard, c_shard, b_shard["token"], rep),
                   out_shardings=(logits_shard, c_shard),
                   donate_argnums=(1,))
    return step, {"params": p_shard, "cache": c_shard,
                  "token": b_shard["token"]}
