"""Step builders: jitted train / prefill / decode steps with shardings.

``build_train_step`` produces the exact function the multi-pod dry-run
lowers for ``train_*`` cells; ``build_prefill_step`` / ``build_decode_step``
cover the ``prefill_*`` / ``decode_*`` / ``long_*`` cells.

Microbatching (grad accumulation) follows the per-section ``mbs`` knob from
the paper: the global batch is laid out shard-major ``[dp, n_micro, mbs]``
so the reshape into microbatches is local to every data shard (no
collectives for data staging).

Dispatch rules (``parallel_regime``) — how a section's
``ParallelConfig(dp, tp, pp, cp)`` reaches the compiled step:

* ``dp`` / ``tp`` are carried by the mesh's ``data`` / ``model`` axes and
  realized through GSPMD sharding constraints (``repro.dist.sharding``).
* ``pp > 1`` (mesh ``pipe`` axis > 1) → **PP regime**: the loss is the
  stage-partitioned GPipe loss from ``repro.dist.pipeline.build_pp_loss``
  (microbatching happens inside the staged schedule); the step takes one
  ``value_and_grad`` of it instead of the grad-accumulation scan.
* ``cp > 1`` (mesh ``seq`` axis > 1) → **CP regime**: the plain step, with
  ``repro.dist.context.cp_attention`` installed as the model's attention
  implementation and activations sequence-sharded over ``seq``.
* ``ParallelConfig.pp``/``.cp`` must match the mesh's ``pipe``/``seq``
  sizes, and pp×cp is unsupported — both raise instead of silently
  training with the pipe/seq devices replicated (the pre-PR-2 bug).
* ``ParallelConfig.grad_compress`` ∈ {"none", "bf16", "int8"} compresses
  the DP gradient all-reduce (``repro.optim.compression``): the loss +
  grad computation moves into a shard_map over the data axis, each shard
  accumulates its local microbatch gradients uncompressed in fp32, and
  ONE compressed all-reduce per step replaces the fp32 one (int8 carries
  an error-feedback residual across steps — the step gains a trailing
  ``ef`` argument/result, stacked ``[dp, ...]`` and donated).  Plain
  regime with a single data axis only; pp/cp/tp meshes raise.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.types import ArchConfig, ParallelConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.models import attention as att
from repro.models import common as cm
from repro.models.model import Model
from repro.optim import adamw, schedules


def parallel_regime(mesh: Mesh, parallel: ParallelConfig) -> str:
    """Validate ``parallel`` against the mesh and pick the step regime:
    ``"plain"`` | ``"cp"`` | ``"pp"`` (see module docstring).  Raises
    instead of letting a pp/cp > 1 config fall through to the replicated
    step unannounced."""
    sizes = dict(mesh.shape)
    pp = sizes.get(shd.AXIS_PIPE, 1)
    cp = sizes.get(shd.AXIS_SEQ, 1)
    if parallel.pp != pp:
        raise ValueError(
            f"ParallelConfig.pp={parallel.pp} does not match the mesh's "
            f"pipe axis ({pp}): a pp>1 section must run on a mesh carved "
            f"by section_mesh/carve_meshes, not fall back to replication")
    if parallel.cp != cp:
        raise ValueError(
            f"ParallelConfig.cp={parallel.cp} does not match the mesh's "
            f"seq axis ({cp}): a cp>1 section must run on a mesh carved "
            f"by section_mesh/carve_meshes, not fall back to replication")
    if pp > 1 and cp > 1:
        raise NotImplementedError(
            "pp×cp composition is not supported (CP's shard_map cannot "
            "nest inside the pipeline's); use pp×tp or cp×tp instead")
    from repro.dist.context import CP_MODES
    if parallel.cp_mode not in CP_MODES:
        raise ValueError(
            f"ParallelConfig.cp_mode={parallel.cp_mode!r}: expected one "
            f"of {CP_MODES}")
    if parallel.cp_impl not in ("auto", "pallas", "pallas_interpret",
                                "ref"):
        raise ValueError(
            f"ParallelConfig.cp_impl={parallel.cp_impl!r}: expected "
            f"auto/pallas/pallas_interpret/ref")
    if parallel.cp_overlap_chunks < 1:
        raise ValueError(
            f"ParallelConfig.cp_overlap_chunks="
            f"{parallel.cp_overlap_chunks}: must be >= 1")
    if parallel.cp_overlap_chunks > 1 and parallel.cp_mode in (
            "allgather", "ulysses_mqa"):
        raise ValueError(
            f"ParallelConfig.cp_overlap_chunks="
            f"{parallel.cp_overlap_chunks} only applies to the ulysses "
            f"mode's K/V a2a chain, but cp_mode={parallel.cp_mode!r} "
            f"was forced")
    return "pp" if pp > 1 else ("cp" if cp > 1 else "plain")


def _check_pp_cp_support(cfg: ArchConfig, regime: str) -> None:
    if regime == "pp" and cfg.family == "audio":
        raise NotImplementedError(
            "pipeline parallelism is not implemented for encoder-decoder "
            "(audio) sections — build_pp_loss stages tf.lm_specs stacks")
    if regime == "cp":
        if cfg.family == "audio":
            raise NotImplementedError(
                "context parallelism is not implemented for encoder-"
                "decoder (audio) sections (cross-attention)")
        if not any(cfg.is_attn_layer(i) for i in range(cfg.num_layers)):
            raise NotImplementedError(
                f"cp>1 on attention-free arch {cfg.name!r}: there is no "
                "attention to sequence-shard, the seq axis would be "
                "silently replicated")


def _act_hook_for(mesh: Mesh, batch_size: int, seq_len: int,
                  sequence_parallel: bool = False):
    dp = shd.dp_axes(mesh)
    bspec = shd.batch_spec(mesh, batch_size, seq_len)
    b_ax, s_ax = tuple(bspec)[0], tuple(bspec)[1]
    model_size = mesh.shape.get("model", 1)
    cp = dict(mesh.shape).get(shd.AXIS_SEQ, 1)
    if cp > 1 and s_ax is None and seq_len % cp == 0:
        # CP: keep activations sequence-sharded over the seq axis between
        # attention calls — cp_attention's shard_map in_specs match this
        # layout, so only attention itself reshards
        s_ax = shd.AXIS_SEQ
    # Megatron-style sequence parallelism: the residual stream between
    # blocks is sequence-sharded over the model axis, turning the per-layer
    # TP all-reduce pair into reduce-scatter + all-gather at half the bytes
    # (and keeping norms local)
    sp_ax = ("model" if sequence_parallel and s_ax is None
             and seq_len % model_size == 0 else s_ax)

    def hook(x, kind):
        if kind == "hidden" and x.ndim == 3:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(b_ax, sp_ax, None)))
        if kind == "logits" and x.ndim == 3:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(b_ax, s_ax, "model")))
        if kind == "attn_q" and x.ndim == 4:
            h_ax = "model" if x.shape[2] % model_size == 0 else None
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(b_ax, s_ax, h_ax, None)))
        if kind == "moe_dispatch" and x.ndim == 4:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(b_ax, None, None, None)))
        return x

    return hook


def num_microbatches(shape: ShapeConfig, mesh: Mesh,
                     parallel: ParallelConfig) -> int:
    """Grad-accumulation depth for this (shape × mesh × C^s) cell.

    Raises at build time when the global batch cannot be laid out as
    ``[dp_total, n_micro, mbs]`` — the pre-PR-2 behaviour silently
    *duplicated* the full batch into every microbatch instead."""
    dp_total = shd.axis_size(mesh, shd.dp_axes(mesh))
    denom = dp_total * parallel.mbs
    n = shape.global_batch // denom
    # undersized global batches (< dp_total*mbs) stay legal — the batch is
    # replicated / seq-sharded, not microbatched; anything larger must lay
    # out exactly as [dp_total, n_micro, mbs]
    if shape.global_batch > denom and shape.global_batch % denom:
        raise ValueError(
            f"global_batch={shape.global_batch} is not a multiple of "
            f"dp_total*mbs={dp_total}*{parallel.mbs}: grad accumulation "
            "would train on duplicated data with an inflated effective "
            "batch; adjust ShapeConfig.global_batch or ParallelConfig.mbs")
    return max(n, 1)


def _split_microbatches(batch: dict, n_micro: int, dp_total: int):
    """[GB, ...] -> [n_micro, GB/n_micro, ...] with shard-major layout so
    the split is local to each data shard.  Raises on non-divisible
    batches (never silently duplicates data)."""
    def split(x):
        gb = x.shape[0]
        mgb = gb // n_micro
        per = mgb // dp_total
        if per == 0 or gb % n_micro or mgb % dp_total:
            raise ValueError(
                f"cannot split batch dim {gb} into {n_micro} microbatches "
                f"× {dp_total} DP shards: global_batch must be a multiple "
                "of dp_total*mbs")
        y = x.reshape((dp_total, n_micro, per) + x.shape[1:])
        return jnp.swapaxes(y, 0, 1).reshape(
            (n_micro, mgb) + x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def build_train_step(model: Model, mesh: Mesh, parallel: ParallelConfig,
                     shape: ShapeConfig, *, rules=None,
                     lr_schedule=None,
                     opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()):
    """Returns (jitted_step, shardings) — step(params, opt_state, batch,
    step_idx) -> (params, opt_state, metrics).

    Dispatches on the mesh's ``pipe``/``seq`` axes (see module docstring):
    plain / CP / PP regimes all yield loss and parameter updates matching
    the monolithic reference within fp32 tolerance (driver-verified)."""
    cfg = model.cfg
    regime = parallel_regime(mesh, parallel)
    _check_pp_cp_support(cfg, regime)
    compress = parallel.grad_compress or "none"
    if compress != "none":
        from repro.optim import compression as gcomp
        if compress not in gcomp.METHODS:
            raise ValueError(
                f"ParallelConfig.grad_compress={compress!r}: expected one "
                f"of {gcomp.METHODS}")
        sizes = dict(mesh.shape)
        if regime != "plain" or any(
                sizes.get(a, 1) > 1 for a in (shd.AXIS_PIPE, shd.AXIS_SEQ,
                                              shd.AXIS_MODEL)):
            raise NotImplementedError(
                "grad_compress requires the plain regime on a dp-only "
                "mesh: the compressed all-reduce runs in a shard_map over "
                "the data axis and cannot nest inside pp/cp schedules or "
                "compose with tp activation sharding")
        if len(shd.dp_axes(mesh)) > 1:
            raise NotImplementedError(
                "grad_compress supports a single data axis (got a multi-"
                "pod dp mesh); compress per pod or disable")
        _dp = shd.axis_size(mesh, shd.dp_axes(mesh))
        if shape.global_batch % max(_dp, 1):
            raise NotImplementedError(
                f"grad_compress needs the global batch "
                f"({shape.global_batch}) to divide the data axis ({_dp}) "
                "so every shard owns a real slice of the batch")
    if regime == "pp" and parallel.sequence_parallel:
        raise NotImplementedError(
            "sequence_parallel is a GSPMD activation-layout knob and "
            "cannot apply inside the PP regime's manual shard_map; "
            "disable it for pp>1 sections")
    specs = model.specs()
    rules = rules if rules is not None else shd.rules_for(cfg, mesh)
    p_shard = shd.param_shardings(specs, mesh, rules)
    o_shard = shd.opt_state_shardings(specs, mesh, rules,
                                      zero=parallel.zero_opt)
    batch_specs = model.input_specs(shape)
    b_shard = shd.data_shardings(mesh, batch_specs)
    dp_total = shd.axis_size(mesh, shd.dp_axes(mesh))
    n_micro = num_microbatches(shape, mesh, parallel)
    lr_fn = lr_schedule or functools.partial(
        schedules.warmup_cosine, peak_lr=3e-4, warmup_steps=100,
        total_steps=10_000)
    rep = shd.replicated(mesh)

    if regime == "pp":
        from repro.dist import pipeline as pl
        # the staged loss microbatches internally with the same shard-major
        # layout contract as _split_microbatches, and equals the monolithic
        # full-batch loss (CE globally normalized, MoE aux exact)
        pp_loss, _ = pl.build_pp_loss(
            cfg, mesh, n_micro, impl=model.impl, remat=model.remat,
            causal=(cfg.family != "vit"),
            mb_layout=pl.contiguous_microbatch)
        grad_fn = jax.value_and_grad(pp_loss)

        def train_step(params, opt_state, batch, step_idx):
            loss, grads = grad_fn(params, batch)
            lr = lr_fn(step_idx)
            new_params, new_opt, gnorm = adamw.update(grads, opt_state, lr,
                                                      opt_cfg)
            return new_params, new_opt, {"loss": loss.astype(jnp.float32),
                                         "grad_norm": gnorm, "lr": lr}
    else:
        hook = _act_hook_for(mesh, shape.global_batch // n_micro,
                             shape.seq_len,
                             sequence_parallel=parallel.sequence_parallel)
        if regime == "cp":
            from repro.dist import context as cpx
            cp_impl = cpx.cp_attention_impl(
                mesh, batch_axes=shd.dp_axes(mesh) or None,
                mode=parallel.cp_mode, impl=parallel.cp_impl,
                overlap_chunks=parallel.cp_overlap_chunks)
        else:
            cp_impl = None

        def loss_fn(p, mb):
            impl_ctx = (att.attention_impl(cp_impl) if cp_impl is not None
                        else contextlib.nullcontext())
            with cm.act_hook(hook), impl_ctx:
                return model.loss(p, mb)

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def train_step(params, opt_state, batch, step_idx):
            if n_micro == 1:
                (loss, metrics), grads = grad_fn(params, batch)
            else:
                mbs_tree = _split_microbatches(batch, n_micro, dp_total)

                def micro(carry, mb):
                    g_acc, l_acc = carry
                    (loss, _), grads = grad_fn(params, mb)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, g: a + g.astype(jnp.float32), g_acc,
                        grads)
                    return (g_acc, l_acc + loss), None

                g0 = jax.tree_util.tree_map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), params)
                (g_sum, l_sum), _ = jax.lax.scan(micro,
                                                 (g0, jnp.float32(0)),
                                                 mbs_tree)
                grads = jax.tree_util.tree_map(
                    lambda g, p: (g / n_micro).astype(p.dtype), g_sum,
                    params)
                loss = l_sum / n_micro
                metrics = {}
            lr = lr_fn(step_idx)
            new_params, new_opt, gnorm = adamw.update(grads, opt_state, lr,
                                                      opt_cfg)
            out_metrics = {"loss": loss.astype(jnp.float32),
                           "grad_norm": gnorm, "lr": lr}
            return new_params, new_opt, out_metrics

    if compress != "none":
        da = (shd.dp_axes(mesh) or (shd.AXIS_DATA,))[0]
        grad_fn = jax.value_and_grad(
            lambda p, mb: model.loss(p, mb), has_aux=True)

        def sharded_loss_grad(params, batch_local, ef_local):
            """Runs on one data shard: local microbatch grad accumulation
            (fp32, uncompressed), then the single compressed mean-reduce
            across the data axis.  ``ef_local`` is the shard's [1, ...]
            slice of the stacked error-feedback residual."""
            with cm.act_hook(None):
                if n_micro == 1:
                    (loss, _), g = grad_fn(params, batch_local)
                    g = jax.tree_util.tree_map(
                        lambda x: x.astype(jnp.float32), g)
                else:
                    local = jax.tree_util.tree_map(
                        lambda x: x.reshape(
                            (n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                        batch_local)

                    def micro(carry, mb):
                        g_acc, l_acc = carry
                        (l, _), g = grad_fn(params, mb)
                        g_acc = jax.tree_util.tree_map(
                            lambda a, b: a + b.astype(jnp.float32),
                            g_acc, g)
                        return (g_acc, l_acc + l), None

                    g0 = jax.tree_util.tree_map(
                        lambda x: jnp.zeros(x.shape, jnp.float32), params)
                    (g_sum, l_sum), _ = jax.lax.scan(
                        micro, (g0, jnp.float32(0)), local)
                    g = jax.tree_util.tree_map(lambda x: x / n_micro,
                                               g_sum)
                    loss = l_sum / n_micro
            mean_loss = jax.lax.psum(loss, da) / dp_total
            ef = gcomp.ErrorFeedback(jax.tree_util.tree_map(
                lambda x: x[0], ef_local))
            red, new_ef = gcomp.ef_compress_tree(g, ef, da, compress)
            red = jax.tree_util.tree_map(
                lambda r, p: r.astype(p.dtype), red, params)
            new_ef_stacked = jax.tree_util.tree_map(
                lambda x: x[None], new_ef.residual)
            return mean_loss, red, new_ef_stacked

        run = shd.shard_map(
            sharded_loss_grad, mesh,
            (P(), jax.tree_util.tree_map(lambda _: P(da), b_shard),
             P(da)),
            (P(), P(), P(da)))

        def train_step(params, opt_state, batch, step_idx, ef):  # noqa: F811
            loss, grads, new_ef = run(params, batch, ef)
            lr = lr_fn(step_idx)
            new_params, new_opt, gnorm = adamw.update(grads, opt_state,
                                                      lr, opt_cfg)
            return (new_params, new_opt,
                    {"loss": loss.astype(jnp.float32),
                     "grad_norm": gnorm, "lr": lr}, new_ef)

        ef_shard = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P(da)), dict(p_shard))
        step = jax.jit(
            train_step,
            in_shardings=(p_shard, o_shard, b_shard, rep, ef_shard),
            out_shardings=(p_shard, o_shard,
                           {"loss": rep, "grad_norm": rep, "lr": rep},
                           ef_shard),
            donate_argnums=(0, 1, 4))
        # donation metadata for the analysis pass (repro.analysis
        # .donation.lint_step_fn): which argnums this jit consumes
        step._donates = (0, 1, 4)
        step._donates_label = "train_step[compressed](params, opt, ef)"

        def ef_init(params):
            """Zero-initialized stacked [dp, ...] error-feedback residual,
            placed on the data axis."""
            z = jax.tree_util.tree_map(
                lambda p: jnp.zeros((dp_total,) + p.shape, jnp.float32),
                params)
            return jax.device_put(z, ef_shard)

        shardings = {"params": p_shard, "opt": o_shard, "batch": b_shard,
                     "ef": ef_shard, "ef_init": ef_init}
        return step, shardings

    step = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, b_shard, rep),
        out_shardings=(p_shard, o_shard,
                       {"loss": rep, "grad_norm": rep, "lr": rep}),
        donate_argnums=(0, 1))
    step._donates = (0, 1)
    step._donates_label = "train_step(params, opt)"
    shardings = {"params": p_shard, "opt": o_shard, "batch": b_shard}
    return step, shardings


def _check_no_pp_cp_serving(mesh: Mesh, kind: str) -> None:
    sizes = dict(mesh.shape)
    if sizes.get(shd.AXIS_PIPE, 1) > 1 or sizes.get(shd.AXIS_SEQ, 1) > 1:
        raise NotImplementedError(
            f"{kind} cells do not support pipe/seq mesh axes > 1: serving "
            "shards long contexts over the model axis instead "
            "(kv_cache_spec flash-decoding split)")


def build_prefill_step(model: Model, mesh: Mesh, shape: ShapeConfig, *,
                       rules=None):
    _check_no_pp_cp_serving(mesh, "prefill")
    specs = model.specs()
    rules = rules if rules is not None else shd.rules_for(model.cfg, mesh)
    p_shard = shd.param_shardings(specs, mesh, rules)
    batch_specs = model.input_specs(shape)
    b_shard = shd.data_shardings(mesh, batch_specs)
    cache_specs = model.cache_specs(shape)
    c_shard = shd.cache_shardings(mesh, cache_specs)
    hook = _act_hook_for(mesh, shape.global_batch, shape.seq_len)
    logits_shard = shd.logits_sharding(mesh, shape.global_batch,
                                       model.cfg.padded_vocab)

    def prefill_step(params, batch):
        with cm.act_hook(hook):
            logits, cache = model.prefill(params, batch)
        return logits, cache

    step = jax.jit(prefill_step, in_shardings=(p_shard, b_shard),
                   out_shardings=(logits_shard, c_shard))
    step._donates = ()
    step._donates_label = "prefill_step"
    return step, {"params": p_shard, "batch": b_shard, "cache": c_shard}


def build_decode_step(model: Model, mesh: Mesh, shape: ShapeConfig, *,
                      rules=None):
    """serve_step for decode cells: one new token against a seq_len cache."""
    _check_no_pp_cp_serving(mesh, "decode")
    specs = model.specs()
    rules = rules if rules is not None else shd.rules_for(model.cfg, mesh)
    p_shard = shd.param_shardings(specs, mesh, rules)
    batch_specs = model.input_specs(shape)
    b_shard = shd.data_shardings(mesh, batch_specs)
    cache_specs = model.cache_specs(shape)
    c_shard = shd.cache_shardings(mesh, cache_specs)
    logits_shard = shd.logits_sharding(mesh, shape.global_batch,
                                       model.cfg.padded_vocab)
    rep = shd.replicated(mesh)

    def decode_step(params, cache, token, pos):
        logits, new_cache = model.decode(params, cache, token, pos)
        return logits, new_cache

    step = jax.jit(decode_step,
                   in_shardings=(p_shard, c_shard, b_shard["token"], rep),
                   out_shardings=(logits_shard, c_shard),
                   donate_argnums=(1,))
    step._donates = (1,)
    step._donates_label = "decode_step(cache)"
    return step, {"params": p_shard, "cache": c_shard,
                  "token": b_shard["token"]}
