"""Training loop: checkpoint/restart, straggler detection, metrics.

Fault-tolerance model (scales to multi-host):

* periodic async checkpoints (atomic commit, retention) + resume-on-start;
* emergency checkpoint on KeyboardInterrupt/SIGTERM;
* **straggler mitigation**: per-step wall-time EMA; steps slower than
  ``straggler_factor`` × the rolling median are logged and counted — on a
  real cluster this signal feeds the scheduler's DP re-balancing and the
  "hot spare" swap; here it drives metrics and tests.  (Data-dependent
  stragglers — heavy multimodal samples — are handled upstream by the
  wavefront scheduler's DP partitioning.)
"""
from __future__ import annotations

import signal
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer


@dataclass
class StragglerMonitor:
    factor: float = 2.0
    window: int = 32
    times: List[float] = field(default_factory=list)
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 8:
            med = statistics.median(self.times)
            if dt > self.factor * med:
                self.flagged += 1
                return True
        return False


@dataclass
class TrainResult:
    steps_run: int
    final_step: int
    losses: List[float]
    step_times: List[float]
    stragglers: int
    resumed_from: Optional[int]


def train(step_fn: Callable, *, params, opt_state, batches: Iterator,
          num_steps: int, checkpointer: Optional[Checkpointer] = None,
          checkpoint_every: int = 50, log_every: int = 10,
          shardings: Optional[Dict] = None,
          straggler_factor: float = 2.0,
          log_fn: Callable[[str], None] = print) -> TrainResult:
    """Run ``num_steps`` of ``step_fn(params, opt, batch, step_idx)``.

    Resumes from the latest checkpoint when one exists."""
    start_step = 0
    resumed = None
    if checkpointer is not None:
        latest = checkpointer.latest_step()
        if latest is not None:
            state = checkpointer.restore(
                latest, {"params": params, "opt": opt_state},
                None if shardings is None else
                {"params": shardings.get("params"),
                 "opt": shardings.get("opt")})
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            resumed = latest
            log_fn(f"resumed from step {latest}")

    mon = StragglerMonitor(factor=straggler_factor)
    losses: List[float] = []
    times: List[float] = []
    interrupted = {"flag": False}

    def _sigterm(signum, frame):            # pragma: no cover
        interrupted["flag"] = True

    old = None
    try:
        old = signal.signal(signal.SIGTERM, _sigterm)
    except ValueError:                       # non-main thread
        pass

    # last *completed* step index; start_step - 1 ⇒ "no step ran yet", so
    # the final save below never writes a spurious step past num_steps
    # when the loop body never executes (e.g. resuming at num_steps)
    step = start_step - 1
    try:
        for step in range(start_step, num_steps):
            batch = next(batches)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch,
                                                 jnp.int32(step))
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            times.append(dt)
            losses.append(loss)
            if mon.observe(dt):
                log_fn(f"[straggler] step {step}: {dt*1e3:.0f}ms "
                       f"(median {statistics.median(mon.times)*1e3:.0f}ms)")
            if step % log_every == 0:
                log_fn(f"step {step}: loss={loss:.4f} "
                       f"gnorm={float(metrics.get('grad_norm', 0)):.3f} "
                       f"{dt*1e3:.0f}ms")
            if checkpointer is not None and (step + 1) % checkpoint_every \
                    == 0:
                checkpointer.save(step + 1,
                                  {"params": params, "opt": opt_state})
            if interrupted["flag"]:          # pragma: no cover
                log_fn("SIGTERM — emergency checkpoint")
                break
    except KeyboardInterrupt:                # pragma: no cover
        log_fn("interrupted — emergency checkpoint")
    finally:
        if checkpointer is not None and step >= start_step:
            # only when at least one step actually ran: a zero-step run
            # (resume at num_steps) must not write a num_steps+1 artifact
            checkpointer.save(step + 1, {"params": params,
                                         "opt": opt_state}, block=True)
            checkpointer.wait()
        if old is not None:
            signal.signal(signal.SIGTERM, old)

    return TrainResult(len(losses), max(step + 1, start_step), losses,
                       times, mon.flagged, resumed)
